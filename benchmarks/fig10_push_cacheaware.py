"""Figure 10: optimized PIM speedup for push-primitive.

Cache-aware PIM (§5.1.3) + the command-bandwidth limit study (§5.1.4).
Paper anchors: cache-aware PIM avg 1.20x / max 1.39x; cache-aware GPU up to
1.68x; with 4x command bandwidth PIM exceeds cache-aware GPU for all inputs,
up to 2.02x.
"""
from __future__ import annotations

import dataclasses

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import push
from repro.core.primitives.graphs import paper_inputs

from .common import Table


def run(table: Table | None = None) -> dict[str, float]:
    t = table or Table("Fig 10 — push: cache-aware PIM + command bandwidth")
    out: dict[str, float] = {}
    ca, ca4 = [], []
    for g in paper_inputs():
        r = push.evaluate(g, PIM, GPU)
        pim4 = dataclasses.replace(PIM, command_bw_mult=4.0)
        cold = int(g.n_edges * (1.0 - r.predictor_hit_rate))
        t4 = push.pim_time(g, pim4, n_updates=max(1, cold),
                           row_hit_frac=push.COLD_ROW_HIT).time_ns
        feed = push.gpu_feed_time_ns(g, GPU)
        t4 = max(t4, feed) + 0.15 * min(t4, feed)
        s4 = r.gpu_ns / t4
        label = f"push[{g.name}]"
        out[f"{label} cache-aware"] = r.speedup_cache_aware
        out[f"{label} cache-aware-gpu"] = r.speedup_gpu_cache_aware
        out[f"{label} cache-aware+4xBW"] = s4
        ca.append(r.speedup_cache_aware)
        ca4.append(s4)
        t.add(f"{label} cache-aware PIM", r.pim_cache_aware_ns,
              f"{r.speedup_cache_aware:.2f}x (pred-hit "
              f"{r.predictor_hit_rate:.0%})")
        t.add(f"{label} cache-aware GPU", r.gpu_cache_aware_ns,
              f"{r.speedup_gpu_cache_aware:.2f}x")
        t.add(f"{label} cache-aware PIM + 4x cmd-BW", t4, f"{s4:.2f}x")
    t.anchor("cache-aware PIM average", sum(ca) / len(ca), 1.20)
    t.anchor("cache-aware PIM max", max(ca), 1.39)
    t.anchor("cache-aware+4xBW max", max(ca4), 2.02)
    if table is None:
        t.emit()
    return out


if __name__ == "__main__":
    run()
