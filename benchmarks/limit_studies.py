"""§5.1.4 limit studies: PIM register count and command bandwidth swept
across the primitives they gate (beyond the two points Figures 8/10 show).

Registers gate broadcast primitives (chunk length amortizes activations);
command bandwidth gates single-bank primitives (push).  The table shows
where each primitive saturates — the "careful attention to these
decisions" argument of §5.1.4 made quantitative.
"""
from __future__ import annotations

import dataclasses

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import push, vector_sum, wavesim
from repro.core.primitives.graphs import powerlaw

from .common import Table

REGS = (8, 16, 32, 64, 128)
CMD_BW = (1.0, 2.0, 4.0, 8.0)


def run(table: Table | None = None) -> dict[str, float]:
    t = table or Table("Limit studies — registers x command bandwidth")
    out: dict[str, float] = {}
    wp = wavesim.Problem()
    vp = vector_sum.Problem(n=64 << 20)
    for regs in REGS:
        sv = wavesim.speedup_volume(wp, PIM, GPU, arch_aware=True, regs=regs)
        sf = wavesim.speedup_flux(wp, PIM, GPU, arch_aware=True, regs=regs)
        vs = vector_sum.speedup(vp, PIM, GPU, arch_aware=True, regs=regs)
        out[f"regs{regs}"] = sf
        t.add(f"registers={regs} (arch-aware)", 0.0,
              f"volume {sv:.2f}x | flux {sf:.2f}x | vector-sum {vs:.2f}x")
    # saturation point for flux (the register-hungry primitive)
    gains = [out[f"regs{r}"] for r in REGS]
    sat = next((REGS[i] for i in range(1, len(gains))
                if gains[i] / gains[i - 1] < 1.05), REGS[-1])
    t.add("flux register saturation", 0.0,
          f"{sat} registers (<5% marginal gain beyond)")

    g = powerlaw(1_000_000, 10_000_000, alpha=0.6,
                 name="powerlaw-1M-10M", measured_l2_hit=0.20)
    r = push.evaluate(g, PIM, GPU, predictor_sample=120_000)
    cold = int(g.n_edges * (1.0 - r.predictor_hit_rate))
    feed = push.gpu_feed_time_ns(g, GPU)
    for bw in CMD_BW:
        pimx = dataclasses.replace(PIM, command_bw_mult=bw)
        tc = push.pim_time(g, pimx, n_updates=max(1, cold),
                           row_hit_frac=push.COLD_ROW_HIT).time_ns
        tc = max(tc, feed) + 0.15 * min(tc, feed)
        s = r.gpu_ns / tc
        out[f"cmdbw{bw}"] = s
        t.add(f"push cache-aware, command-BW x{bw:.0f}", tc, f"{s:.2f}x")
    t.add("push command-BW saturation", 0.0,
          "beyond 4x the data bus / activation throughput binds "
          f"(x4 -> x8 gain: {out['cmdbw8.0'] / out['cmdbw4.0']:.2f}x)")
    if table is None:
        t.emit()
    return out


if __name__ == "__main__":
    run()
