"""Headline claim: average PIM speedup 1.12x (baseline) -> 2.49x (optimized).

The averaging set is the primitives under study with each primitive's
*targeted* optimization (§5.2): wavesim with architecture-aware activation
(+64 registers for flux), ss-gemm with sparsity-aware PIM, push with
cache-aware PIM + 4x command bandwidth.  vector-sum (the known-amenable
comparison point) is reported both in and out of the average since the
paper's set is not itemized.
"""
from __future__ import annotations

import dataclasses

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import push, ss_gemm, vector_sum, wavesim
from repro.core.primitives.graphs import paper_inputs

from .common import Table
from .fig6_baseline_pim import SS_GEMM_N


def run(table: Table | None = None) -> dict[str, float]:
    t = table or Table("Headline — average PIM speedup, baseline vs optimized")
    base: dict[str, float] = {}
    opt: dict[str, float] = {}

    wp = wavesim.Problem()
    base["wavesim-volume"] = wavesim.speedup_volume(wp, PIM, GPU)
    opt["wavesim-volume"] = wavesim.speedup_volume(wp, PIM, GPU,
                                                   arch_aware=True)
    base["wavesim-flux"] = wavesim.speedup_flux(wp, PIM, GPU)
    opt["wavesim-flux"] = wavesim.speedup_flux(wp, PIM, GPU, arch_aware=True,
                                               regs=64)
    for n in SS_GEMM_N:
        sp = ss_gemm.Problem(n=n)
        r = ss_gemm.speedups(sp, PIM, GPU)
        base[f"ss-gemm-N{n}"] = r["baseline"]
        opt[f"ss-gemm-N{n}"] = r["sparsity_aware"]
    pim4 = dataclasses.replace(PIM, command_bw_mult=4.0)
    for g in paper_inputs():
        r = push.evaluate(g, PIM, GPU)
        base[f"push[{g.name}]"] = r.speedup_baseline
        cold = int(g.n_edges * (1.0 - r.predictor_hit_rate))
        t4 = push.pim_time(g, pim4, n_updates=max(1, cold),
                           row_hit_frac=push.COLD_ROW_HIT).time_ns
        feed = push.gpu_feed_time_ns(g, GPU)
        t4 = max(t4, feed) + 0.15 * min(t4, feed)
        opt[f"push[{g.name}]"] = r.gpu_ns / t4

    vb = vector_sum.speedup(vector_sum.Problem(n=64 * 1024 * 1024), PIM, GPU)
    vo = vector_sum.speedup(vector_sum.Problem(n=64 * 1024 * 1024), PIM, GPU,
                            arch_aware=True)

    avg_b = sum(base.values()) / len(base)
    avg_o = sum(opt.values()) / len(opt)
    avg_b_v = (sum(base.values()) + vb) / (len(base) + 1)
    avg_o_v = (sum(opt.values()) + vo) / (len(opt) + 1)
    t.anchor("average baseline (studied primitives)", avg_b, 1.12)
    t.anchor("average optimized (studied primitives)", avg_o, 2.49)
    t.add("average baseline (incl vector-sum)", 0.0, f"{avg_b_v:.2f}x")
    t.add("average optimized (incl vector-sum)", 0.0, f"{avg_o_v:.2f}x")
    t.add("improvement ratio", 0.0,
          f"{avg_o / avg_b:.2f}x (paper 2.49/1.12 = 2.22x)")
    if table is None:
        t.emit()
    return {"avg_baseline": avg_b, "avg_optimized": avg_o}


if __name__ == "__main__":
    run()
