"""§Roofline table: three terms per (arch x shape) from dry-run artifacts.

Reads artifacts/dryrun/*__single.json (the 16x16 production pod).  Columns:
compute/memory/collective terms (ms), dominant bound, MODEL_FLOPS/HLO_FLOPS
usefulness ratio, and roofline fraction (useful-compute time / dominant
term).
"""
from __future__ import annotations

import pathlib

from repro.launch.dryrun import ARTIFACTS
from repro.roofline.analysis import from_artifact


def rows(mesh: str = "single"):
    out = []
    for path in sorted(ARTIFACTS.glob(f"*__{mesh}.json")):
        try:
            out.append(from_artifact(path))
        except Exception as exc:
            print(f"# skip {path.name}: {exc}")
    return out


def main() -> None:
    rl = rows()
    if not rl:
        raise FileNotFoundError(
            f"no dry-run artifacts in {ARTIFACTS}; run "
            "PYTHONPATH=src python -m repro.launch.dryrun --all")
    print("# Roofline — per (arch x shape), single-pod 16x16 "
          "(v5e: 197 TF/s bf16, 819 GB/s HBM, 4x50 GB/s ICI)")
    print("arch,shape,mesh,compute_ms,memory_ms,collective_ms,bound,"
          "useful_ratio,roofline_frac")
    for r in sorted(rl, key=lambda r: (r.arch, r.shape)):
        print(r.row())
    print()

    # multi-pod scaling: per-device terms at 512 chips vs 256 (the pod
    # axis carries data parallelism only — compute/memory per device
    # should halve for train cells while collectives stay ~flat, i.e.
    # weak-scaling headroom toward 1000+ nodes).
    single = {(r.arch, r.shape): r for r in rl}
    print("# Multi-pod scaling — 2x16x16 vs 16x16, per-device terms")
    print("arch,shape,compute_ratio,collective_ratio,note")
    for path in sorted(ARTIFACTS.glob("*__multi.json")):
        try:
            m = from_artifact(path)
        except Exception:
            continue
        s = single.get((m.arch, m.shape))
        if s is None or not s.compute_ns:
            continue
        cr = m.compute_ns / s.compute_ns
        xr = (m.collective_ns / s.collective_ns
              if s.collective_ns else float("nan"))
        note = ("data-parallel weak scaling" if cr < 0.7
                else "batch-bound (replicated work)")
        print(f"{m.arch},{m.shape},{cr:.2f},{xr:.2f},{note}")
    print()


if __name__ == "__main__":
    main()
