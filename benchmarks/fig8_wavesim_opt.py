"""Figure 8: optimized PIM speedup for wavesim primitives.

Architecture-aware row activation (§5.1.1) x register limit study (§5.1.4).
Paper anchors: volume 1.5x -> 2.04x with arch-aware (activation overhead
eliminated; more registers don't help further); flux shows no arch-aware
benefit at 16 registers but reaches up to 2.63x at 64.
"""
from __future__ import annotations

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import wavesim

from .common import Table

REGS = (16, 32, 64)


def run(table: Table | None = None) -> dict[str, float]:
    t = table or Table("Fig 8 — wavesim: arch-aware activation x registers")
    out: dict[str, float] = {}
    wp = wavesim.Problem()
    anchors = {("volume", 16, True): 2.04, ("flux", 64, True): 2.63}
    for prim, speedup_fn, time_fn in (
            ("volume", wavesim.speedup_volume, wavesim.pim_time_volume),
            ("flux", wavesim.speedup_flux, wavesim.pim_time_flux)):
        for regs in REGS:
            for aa in (False, True):
                s = speedup_fn(wp, PIM, GPU, arch_aware=aa, regs=regs)
                st = time_fn(wp, PIM, arch_aware=aa, regs=regs)
                name = f"wavesim-{prim} regs={regs} {'arch-aware' if aa else 'baseline'}"
                out[name] = s
                paper = anchors.get((prim, regs, aa))
                if paper is not None:
                    t.anchor(name, s, paper, time_ns=st.time_ns)
                else:
                    t.add(name, st.time_ns,
                          f"{s:.2f}x (act-stall {st.act_stall_frac:.0%})")
    if table is None:
        t.emit()
    return out


if __name__ == "__main__":
    run()
