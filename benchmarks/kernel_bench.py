"""Kernel micro-bench: wall time of Pallas kernels (interpret mode) vs
their jnp oracles, plus the *structural* speedup the sparsity-aware
variants deliver (tiles skipped — the TPU analogue of commands skipped;
wall-clock on CPU interpret mode is not meaningful, structure is).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ss_gemm.ops import block_occupancy

from .common import Table


def main() -> None:
    t = Table("Kernels — oracle agreement + sparsity-aware tile skipping")
    rng = np.random.default_rng(0)

    # ss-gemm skip granularity — the paper's own §5.1.2 argument made
    # quantitative: PIM skips at *element* granularity (one command per
    # 32 B word), a TPU kernel at *tile* granularity.  Random element
    # sparsity therefore yields ~0 tile skips (honest negative), while
    # structured sparsity (pruned blocks / clustered embedding-bag rows)
    # skips in proportion — the regime where the TPU adaptation wins.
    k, n = 4096, 4
    b_rand = rng.standard_normal((k, n)).astype(np.float32)
    b_rand[rng.random(k) > 0.45] = 0.0
    occ = np.asarray(block_occupancy(jnp.asarray(b_rand), 256))
    t.add("ss-gemm random 45%-dense, bk=256", 0.0,
          f"{1 - occ.mean():.0%} tiles skipped (element-granular skip is "
          "PIM-unique — the paper's finer-grain-than-GPU claim)")
    b_clu = rng.standard_normal((k, n)).astype(np.float32)
    live_blocks = rng.random(k // 256) < 0.45
    b_clu[~np.repeat(live_blocks, 256)] = 0.0
    occ_c = np.asarray(block_occupancy(jnp.asarray(b_clu), 256))
    t.add("ss-gemm clustered 45%-dense, bk=256", 0.0,
          f"{1 - occ_c.mean():.0%} tiles skipped (structured sparsity: "
          "the kernel's block-skip regime)")

    # MoE: expert-tile occupancy at decode batch sizes
    from repro.configs import get_config
    cfg = get_config("deepseek-v3-671b")
    m = cfg.moe
    for tokens in (128, 4096):
        assign = rng.integers(0, m.n_experts, size=(tokens, m.top_k))
        counts = np.bincount(assign.reshape(-1), minlength=m.n_experts)
        cap = max(1, int(tokens * m.top_k * 1.25 / m.n_experts))
        bc = 128
        tiles = -(-cap // bc) * m.n_experts
        live = sum(min(-(-c // bc), -(-cap // bc)) for c in counts)
        t.add(f"moe-group-gemm tiles live (T={tokens}, 256e top-8)", 0.0,
              f"{live}/{tiles} tiles computed "
              f"({1 - live / tiles:.0%} skipped)")
    t.emit()


if __name__ == "__main__":
    main()
