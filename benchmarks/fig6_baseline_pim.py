"""Figure 6: commercial PIM speedup relative to GPU (baseline PIM).

Paper observations reproduced here:
  * vector-sum attains over 2.6x;
  * primitives under study land between ~0.23x and ~1.66x;
  * ss-gemm slows down increasingly with N; push degrades as L2 hit grows.
"""
from __future__ import annotations

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import push, ss_gemm, vector_sum, wavesim
from repro.core.primitives.graphs import paper_inputs

from .common import Table

SS_GEMM_N = (2, 4, 8, 16)


def run(table: Table | None = None) -> dict[str, float]:
    t = table or Table("Fig 6 — baseline PIM speedup vs GPU")
    out: dict[str, float] = {}

    vp = vector_sum.Problem(n=64 * 1024 * 1024)
    st = vector_sum.pim_time(vp, PIM)
    s = vector_sum.speedup(vp, PIM, GPU)
    out["vector-sum"] = s
    t.anchor("vector-sum", s, ">2.6", time_ns=st.time_ns)

    wp = wavesim.Problem()
    sv = wavesim.speedup_volume(wp, PIM, GPU)
    out["wavesim-volume"] = sv
    t.anchor("wavesim-volume", sv, 1.5,
             time_ns=wavesim.pim_time_volume(wp, PIM).time_ns)
    sf = wavesim.speedup_flux(wp, PIM, GPU)
    out["wavesim-flux"] = sf
    t.anchor("wavesim-flux", sf, "flux baseline (Fig 8 leftmost)",
             time_ns=wavesim.pim_time_flux(wp, PIM).time_ns)

    paper_base = {2: 1.66, 4: 0.75, 8: 0.43, 16: 0.23}
    for n in SS_GEMM_N:
        sp = ss_gemm.Problem(n=n)
        r = ss_gemm.speedups(sp, PIM, GPU)
        out[f"ss-gemm-N{n}"] = r["baseline"]
        t.anchor(f"ss-gemm-N{n}", r["baseline"], paper_base[n],
                 time_ns=ss_gemm.pim_time(sp, PIM).time_ns)

    for g in paper_inputs():
        r = push.evaluate(g, PIM, GPU)
        out[f"push[{g.name}]"] = r.speedup_baseline
        t.anchor(f"push[{g.name}] L2-HR~{g.measured_l2_hit:.0%}",
                 r.speedup_baseline, "<1 (degradation)",
                 time_ns=r.pim_baseline_ns)

    if table is None:
        t.emit()
    return out


if __name__ == "__main__":
    run()
