"""Figure 9: optimized PIM speedup for ss-gemm (sparsity-aware PIM, §5.1.2).

Paper anchors: sparsity-aware PIM lifts speedup above 3x for the skinniest
case and turns the N=8 slowdown (0.43x) into a 1.07x speedup.  Benefits
taper as N (GPU reuse) increases.
"""
from __future__ import annotations

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import ss_gemm

from .common import Table
from .fig6_baseline_pim import SS_GEMM_N


def run(table: Table | None = None) -> dict[str, float]:
    t = table or Table("Fig 9 — ss-gemm: sparsity-aware PIM")
    out: dict[str, float] = {}
    anchors = {2: ">3", 8: 1.07}
    for n in SS_GEMM_N:
        sp = ss_gemm.Problem(n=n)
        r = ss_gemm.speedups(sp, PIM, GPU)
        st = ss_gemm.pim_time(sp, PIM, sparsity_aware=True,
                              density=r["density"])
        name = f"ss-gemm-N{n} sparsity-aware"
        out[name] = r["sparsity_aware"]
        paper = anchors.get(n)
        if paper is not None:
            t.anchor(name, r["sparsity_aware"], paper, time_ns=st.time_ns)
        else:
            t.add(name, st.time_ns,
                  f"{r['sparsity_aware']:.2f}x (element density "
                  f"{r['density']:.2f}, row-zero {r['row_zero_frac']:.2f})")
    if table is None:
        t.emit()
    return out


if __name__ == "__main__":
    run()
