"""Serving throughput + KV memory accounting: seed per-token host loop vs
device-resident engine, dense vs paged KV cache, prefix cache on vs off.

The seed ``Batcher`` ran decode as a per-token Python loop — eager
dispatch, host argmax, a fresh padded batch per round, O(n^2) queue drain.
The engine replaces that with slot-based continuous batching over a jitted
``lax.scan`` (repro.serve.scheduler); the paged mode additionally replaces
the per-slot ``max_len`` KV stripes with a block pool (repro.serve.kvpool)
so admission is on free pages and retired slots return memory.  Every row
therefore reports KV utilization (live tokens / allocated token capacity)
next to tokens/sec — the dense layout's stranded-stripe waste is the
number the paged pool exists to fix.  ``--prefix-cache`` runs a
repeated-system-prompt workload through the shared-prefix radix cache
(repro.serve.prefixcache) and reports the token hit rate plus prefill
tokens computed vs skipped.

  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--paged]
                                                  [--prefix-cache]
                                                  [--arch A]

``--prefill-chunk N`` serves through chunked prefill (page-aligned chunks
interleaved with decode segments); the full mode's ``chunked_compare``
runs a long+short mixed workload both ways and asserts chunking bounds
the worst-case join stall (``max_join_s`` — the decode pause every live
slot suffers while a prompt joins) without losing tokens.

``--speculate K`` serves through self-speculative decoding (draft-k
n-gram lookup + one multi-token verify per step, bit-identical greedy
output); the full mode's ``spec_compare`` runs the repetitive-
continuation workload both ways **in the steady serving state** — the
timed drain reuses the warm batcher's compiled executables, because a
fresh Batcher re-jits its join/segment closures and a compile-dominated
measurement says nothing about serving throughput — and asserts the
speculative engine reaches >= 1.5x tokens/sec at a live acceptance rate.

``--optimistic`` serves through optimistic admission (prompt-only pages
at admit, growth on demand, page-level preemption with recompute-on-
resume under pool pressure); the smoke forces exhaustion through the
chaos injector (repro.serve.chaos) and gates ``preemptions > 0`` plus
``recomputed_ok``, while the full mode's ``preempt_compare`` runs
reservation vs optimistic at the same undersized pool and asserts the
optimistic engine holds strictly more live slots at strictly higher KV
utilization with bit-identical greedy tokens.

``--overload`` serves with the degradation controller on while the chaos
injector exhausts the pool and injects a deadline-stamped low-priority
queue burst: the smoke gates ``cancellations > 0``, ``shed_requests >
0``, recovery to HEALTHY and zero orphaned pages; the full mode's
``overload_compare`` (also standalone via ``--overload-compare``) runs a
deadline-carrying 3x-capacity burst controller-on vs controller-off and
asserts the controller wins on deadline attainment at bit-identical
completed tokens.

Every row now also reports the request-latency trajectory (TTFT p50/p95
and time-per-output-token p50/p95, measured at host sync points), the
queue-wait p50/p95, the speculative ``acceptance_rate`` (0 with
speculation off), the preemption counters (0 in reservation mode) and
the overload counters (cancellations, sheds, deadline attainment,
degradation time-in-state — all zero/HEALTHY with the controller off).

``--smoke`` is the CI sanity mode (~5 s): engine only, asserts a nonzero
throughput (with ``--paged``: the paged engine, plus 100% page
reclamation; with ``--prefix-cache``: additionally a nonzero prefix hit
rate on the shared-prompt workload; with ``--prefill-chunk``: that chunk
continuations actually ran).  The full mode asserts the engine beats the
seed loop >= 3x, that at equal KV memory the paged pool either admits
more concurrent requests than dense or matches dense throughput within
10% while reclaiming every retired slot's pages, and that the prefix
cache cuts prefill tokens computed by exactly its hit rate without
losing concurrency.

Every invocation also appends its rows to ``BENCH_serve.json`` at the
repo root — the machine-readable perf trajectory future PRs regress
against (tokens/sec, KV utilization, prefix hit rate, prefill tokens
computed vs skipped).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config              # noqa: E402
from repro.models import param as pm              # noqa: E402
from repro.models.model_zoo import Model          # noqa: E402
from repro.serve.chaos import ChaosInjector       # noqa: E402
from repro.serve.engine import ServeConfig        # noqa: E402
from repro.serve.scheduler import Batcher         # noqa: E402


BENCH_JSON = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, "BENCH_serve.json"))


def write_bench_json(rows: dict, path: str = BENCH_JSON) -> None:
    """Merge ``rows`` into the machine-readable perf trajectory.  Keys are
    stable row names (e.g. ``smoke-paged+prefix``) so successive PRs
    overwrite their own mode's numbers and diffs stay meaningful; the
    backend is stamped per row, so rows retained from a run on different
    hardware keep their provenance."""
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    data["schema"] = 1
    data.setdefault("rows", {}).update(
        {k: dict({m: (round(v, 4) if isinstance(v, float) else v)
                  for m, v in row.items()},
                 backend=jax.default_backend())
         for k, row in rows.items()})
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def full_bench_rows(r: dict, capacity: dict, prefix: dict,
                    chunked: dict | None = None,
                    spec: dict | None = None,
                    preempt: dict | None = None,
                    overload: dict | None = None) -> dict:
    """The full-mode trajectory rows, assembled once for both entry
    points (CLI main and the benchmarks.run table hook)."""
    rows = {
        "full-dense": {k: r[k] for k in
                       ("engine_tok_s", "seed_tok_s", "speedup",
                        "kv_util_mean", "peak_live_slots")},
        "full-capacity-paged": capacity["paged"],
        "full-capacity-dense": capacity["dense"],
        "full-prefix-on": prefix["cache-on"],
        "full-prefix-off": prefix["cache-off"],
    }
    if chunked is not None:
        rows["full-chunked-on"] = chunked["chunked"]
        rows["full-chunked-off"] = chunked["unchunked"]
    if spec is not None:
        rows["full-spec-on"] = spec["spec-on"]
        rows["full-spec-off"] = spec["spec-off"]
    if preempt is not None:
        rows["full-preempt-optimistic"] = preempt["optimistic"]
        rows["full-preempt-reserve"] = preempt["reserve"]
    if overload is not None:
        rows["full-overload-on"] = overload["controller-on"]
        rows["full-overload-off"] = overload["controller-off"]
    return rows


def make_requests(vocab: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [(rid, rng.integers(0, vocab,
                               size=int(rng.integers(4, 12))).tolist())
            for rid in range(n)]


def make_shared_requests(vocab: int, n: int, prefix_len: int, seed: int = 0):
    """Repeated-system-prompt workload: every request carries the same
    ``prefix_len``-token system prefix plus a short random tail — the
    traffic shape the prefix cache exists for."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=prefix_len).tolist()
    return [(rid, system + rng.integers(
        0, vocab, size=int(rng.integers(2, 8))).tolist())
        for rid in range(n)]


def make_repetitive_requests(vocab: int, n: int, prompt_len: int = 12,
                             seed: int = 0):
    """Repetitive-continuation workload: every request is the same
    constant-token prompt.  The reduced random-init model's greedy
    continuation locks into short cycles on this shape, which is exactly
    the high-acceptance regime self-speculative decoding targets — the
    n-gram drafter proposes the cycle and the verify accepts nearly all
    of it.  (Chaotic continuations still decode correctly, just at ~1
    token per verify step; this workload measures the win, the parity
    tests pin the correctness.)"""
    rng = np.random.default_rng(seed)
    tok = int(rng.integers(0, vocab))
    return [(rid, [tok] * prompt_len) for rid in range(n)]


def make_long_mixed_requests(vocab: int, n: int, long_len: int,
                             n_long: int = 2, seed: int = 0):
    """Head-of-line workload: a few ``long_len``-token prompts scattered
    among short ones — the traffic shape whose unchunked join stalls
    every live slot's decode for the whole long prefill."""
    rng = np.random.default_rng(seed)
    longs = set(rng.choice(n, size=min(n_long, n), replace=False).tolist())
    return [(rid, rng.integers(
        0, vocab, size=long_len if rid in longs
        else int(rng.integers(4, 12))).tolist()) for rid in range(n)]


def seed_batcher_run(model, params, cfg: ServeConfig, requests, max_new):
    """The seed Batcher.run loop, verbatim semantics: padded batch rounds,
    eager per-token decode with host-side argmax, list.pop(0) drain."""
    queue = [(rid, list(p)) for rid, p in requests]
    results = {}
    while queue:
        batch = [queue.pop(0) for _ in range(min(cfg.batch, len(queue)))]
        width = max(len(p) for _, p in batch)
        toks = jnp.zeros((cfg.batch, width), jnp.int32)
        for i, (_, p) in enumerate(batch):
            toks = toks.at[i, :len(p)].set(jnp.asarray(p, jnp.int32))
        logits, caches = model.prefill(
            params, {"tokens": toks}, cfg.max_len, dtype=cfg.dtype)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs = [[] for _ in batch]
        length = jnp.asarray(width, jnp.int32)
        for _ in range(max_new):
            for i in range(len(batch)):
                outs[i].append(int(tok[i, 0]))
            logits, caches = model.decode_step(
                params, tok, caches, length, dtype=cfg.dtype)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            length = length + 1
        for (rid, _), out in zip(batch, outs):
            results[rid] = out
    return results


def engine_run(model, params, cfg: ServeConfig, requests, max_new,
               chaos=None, telemetry=None):
    """Returns (results, batcher) — the batcher carries the KV-utilization
    samples and, in paged mode, the page pool.  ``telemetry`` is an
    optional :class:`repro.serve.telemetry.Tracer` the run records into
    (warmup runs pass none, so a trace holds only the measured drain)."""
    b = Batcher(model, params, cfg, chaos=chaos, telemetry=telemetry)
    for rid, p in requests:
        b.submit(rid, p)
    return b.run(max_new=max_new), b


def _lat_row(batcher) -> dict:
    """The request-latency keys every trajectory row carries: TTFT and
    time-per-output-token p50/p95, as observed at host sync points."""
    lat = batcher.latency_stats()
    return {k: lat[k] for k in ("ttft_p50_s", "ttft_p95_s",
                                "tpot_p50_s", "tpot_p95_s")}


def bench(arch: str = "qwen2-0.5b", *, batch: int = 4, requests: int = 12,
          max_new: int = 24, max_len: int = 96, sync_every: int = 8,
          smoke: bool = False, paged: bool = False, page_size: int = 16,
          total_pages: int | None = None, prefix_cache: bool = False,
          shared_prefix: int = 0, prefill_chunk: int | None = None,
          speculate_k: int | None = None,
          admission_mode: str = "reserve", chaos=None,
          trace_out: str | None = None, attr_out: str | None = None,
          ttft_slo: float | None = None, tpot_slo: float | None = None,
          overload: bool = False, overload_opts: dict | None = None,
          seed: int = 0) -> dict:
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    scfg = ServeConfig(max_len=max_len, batch=batch, sync_every=sync_every,
                       paged=paged, page_size=page_size,
                       total_pages=total_pages, prefix_cache=prefix_cache,
                       prefill_chunk=prefill_chunk, speculate_k=speculate_k,
                       admission_mode=admission_mode,
                       ttft_slo_s=ttft_slo, tpot_slo_s=tpot_slo,
                       overload=overload, **(overload_opts or {}))
    if prefix_cache and not shared_prefix:
        shared_prefix = 2 * page_size      # two full shareable pages
    if speculate_k:
        # the workload speculation exists for: repetitive continuations.
        # Takes priority over the shared-prefix workload — a constant-
        # token prompt *is* a shared (and chunkable) prefix, so sized to
        # ``shared_prefix`` it still exercises --prefix-cache hits and
        # --prefill-chunk continuations while keeping the drafter's
        # high-acceptance regime (the smoke gates acceptance_rate > 0).
        reqs = make_repetitive_requests(
            cfg.vocab, requests, prompt_len=max(12, shared_prefix),
            seed=seed)
    elif shared_prefix:
        reqs = make_shared_requests(cfg.vocab, requests, shared_prefix,
                                    seed)
    else:
        reqs = make_requests(cfg.vocab, requests, seed)

    # engine: one warmup drain compiles the join/segment executables; the
    # timed drain is the steady serving state (same shapes, zero retraces).
    # Smoke mode skips the warmup — it only sanity-checks liveness.
    if not smoke:
        engine_run(model, params, scfg, reqs, max_new)
    tracer = None
    if trace_out:
        from repro.serve.telemetry import Tracer
        tracer = Tracer()
    t0 = time.perf_counter()
    got, batcher = engine_run(model, params, scfg, reqs, max_new,
                              chaos=chaos, telemetry=tracer)
    dt_engine = time.perf_counter() - t0
    if tracer is not None:
        tracer.to_perfetto(trace_out)
        print(f"[serve_bench] wrote Perfetto trace -> {trace_out} "
              f"({len(tracer.events)} events)")
    toks = sum(len(v) for v in got.values())
    util = batcher.kv_utilization()
    pstats = batcher.prefix_stats()
    jstats = batcher.join_stats()
    sstats = batcher.spec_stats()
    kstats = batcher.preempt_stats()
    lat = batcher.latency_stats()
    slo = batcher.slo_stats()
    out = {"arch": arch, "tokens": toks, "paged": paged,
           "prefix_cache": prefix_cache,
           "engine_tok_s": toks / dt_engine, "engine_s": dt_engine,
           "kv_util_mean": util["mean_util"],
           "kv_util_peak": util["peak_util"],
           "peak_live_slots": util["peak_live_slots"],
           "prefix_hit_rate": pstats["hit_rate"],
           "prefill_computed": pstats["prefill_computed"],
           "prefill_skipped": pstats["prefill_skipped"],
           "chunk_joins": jstats["chunk_joins"],
           "max_join_s": jstats["max_join_s"],
           "acceptance_rate": sstats["acceptance_rate"],
           "tokens_per_step": sstats["tokens_per_step"],
           "preemptions": kstats["preemptions"],
           "recomputed_ok": bool(kstats["recomputed_ok"]),
           "preempted_token_recompute": kstats["recompute_tokens"],
           "queue_wait_p50_s": lat["queue_wait_p50_s"],
           "queue_wait_p95_s": lat["queue_wait_p95_s"],
           "ttft_p50_s": lat["ttft_p50_s"], "ttft_p95_s": lat["ttft_p95_s"],
           "tpot_p50_s": lat["tpot_p50_s"], "tpot_p95_s": lat["tpot_p95_s"],
           "slo_enabled": slo["enabled"],
           "slo_attainment": slo["slo_attainment"]}
    # overload-protection trajectory: cancellation/shed tallies, deadline
    # attainment, watchdog trips and the degradation ladder's time-in-
    # state — all-zero/HEALTHY when the controller is off, so every row
    # is comparable across modes
    ostats = batcher.overload_stats()
    tis = ostats["controller"]["time_in_state"]
    out.update({
        "cancellations": ostats["cancellations"],
        "shed_requests": ostats["shed_requests"],
        "deadline_attainment": ostats["deadline_attainment"],
        "watchdog_trips": ostats["watchdog_trips"],
        "recovered_to_healthy":
            bool(ostats["controller"]["recovered_to_healthy"]),
        "overload_state": ostats["controller"]["state"],
        "time_healthy_s": tis["HEALTHY"],
        "time_degraded_s": tis["DEGRADED"],
        "time_shedding_s": tis["SHEDDING"]})
    if tracer is not None:
        # bottleneck attribution over the measured drain's trace: the
        # wave-level dominant components ride on the row; the full
        # per-request decomposition goes to --attr-out when asked for
        from repro.serve.attribution import attribution_report
        rep = attribution_report(tracer)
        out["dominant_ttft_component"] = rep["dominant_ttft_component"]
        out["dominant_tpot_component"] = rep["dominant_tpot_component"]
        if attr_out:
            with open(attr_out, "w") as f:
                json.dump(rep, f, indent=1)
            print(f"[serve_bench] wrote attribution report -> {attr_out} "
                  f"({rep['requests']} requests)")
    if paged:
        # a drained pool holds no mapped pages: everything is back on the
        # free list except prefix pages parked evictable-cached (zero
        # reserved cost — reclaimed on pressure) and pages a preempted-
        # then-retired slot left parked dead (allocatable capacity)
        out["pages_reclaimed"] = (
            batcher.pool.free_pages + batcher.pool.cached_pages
            + batcher.pool.preempted_pages == batcher.pool.n_pages
            and int(batcher.pool.refcount.sum()) == 0)

    if not smoke:
        t0 = time.perf_counter()
        ref = seed_batcher_run(model, params, scfg, reqs, max_new)
        dt_seed = time.perf_counter() - t0
        seed_toks = sum(len(v) for v in ref.values())
        out.update({"seed_tok_s": seed_toks / dt_seed, "seed_s": dt_seed,
                    "speedup": (toks / dt_engine) / (seed_toks / dt_seed)})
    return out


def capacity_compare(arch: str = "qwen2-0.5b", *, requests: int = 16,
                     max_new: int = 24, max_len: int = 96,
                     page_size: int = 16, seed: int = 0) -> dict:
    """Equal-KV-memory comparison: the dense slot table spends
    ``batch * max_len`` tokens of capacity on 4 slots; the paged pool
    spends the same tokens on pages and admits into 8 slots, so short
    requests run 2x as concurrently.  Returns both engines' peak live
    slots, throughput and utilization."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    reqs = make_requests(cfg.vocab, requests, seed)
    dense_batch = 4
    kv_tokens = dense_batch * max_len                 # equal KV memory
    dense_cfg = ServeConfig(max_len=max_len, batch=dense_batch)
    paged_cfg = ServeConfig(max_len=max_len, batch=2 * dense_batch,
                            paged=True, page_size=page_size,
                            total_pages=kv_tokens // page_size)

    res = {}
    for name, scfg in (("dense", dense_cfg), ("paged", paged_cfg)):
        engine_run(model, params, scfg, reqs, max_new)      # warmup
        t0 = time.perf_counter()
        got, b = engine_run(model, params, scfg, reqs, max_new)
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in got.values())
        util = b.kv_utilization()
        res[name] = {"tok_s": toks / dt, "s": dt,
                     "kv_util_mean": util["mean_util"],
                     "peak_live_slots": util["peak_live_slots"],
                     **_lat_row(b)}
        if name == "paged":
            res[name]["pages_reclaimed"] = (b.pool.free_pages
                                            == b.pool.n_pages)
    return res


def prefix_compare(arch: str = "qwen2-0.5b", *, requests: int = 12,
                   max_new: int = 16, max_len: int = 96,
                   page_size: int = 8, prefix_len: int = 32,
                   seed: int = 0) -> dict:
    """Prefix cache on vs off at equal pool size on a repeated-system-
    prompt workload.  On a hit, admission needs free pages only for the
    suffix + budget — the shared prefix pages are already resident — so
    the same pool admits more concurrent requests, and the join prefills
    proportionally fewer tokens (computed drops by exactly the hit
    tokens)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    reqs = make_shared_requests(cfg.vocab, requests, prefix_len, seed)
    # pool sized so cache-off fits ~2 whole requests but the shared-prefix
    # path fits several more (prefix pages counted once, not per request)
    pages_per_req = -(-(prefix_len + 8 + max_new) // page_size)
    pool_pages = 2 * pages_per_req + 2
    base = dict(max_len=max_len, batch=8, sync_every=8, paged=True,
                page_size=page_size, total_pages=pool_pages)

    res = {}
    for name, on in (("cache-off", False), ("cache-on", True)):
        scfg = ServeConfig(**base, prefix_cache=on)
        engine_run(model, params, scfg, reqs, max_new)      # warmup
        t0 = time.perf_counter()
        got, b = engine_run(model, params, scfg, reqs, max_new)
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in got.values())
        util = b.kv_utilization()
        p = b.prefix_stats()
        res[name] = {"tok_s": toks / dt, "s": dt,
                     "kv_util_mean": util["mean_util"],
                     "peak_live_slots": util["peak_live_slots"],
                     "prefix_hit_rate": p["hit_rate"],
                     "prefill_computed": p["prefill_computed"],
                     "prefill_skipped": p["prefill_skipped"],
                     **_lat_row(b)}
    return res


def chunked_compare(arch: str = "qwen2-0.5b", *, requests: int = 8,
                    max_new: int = 16, max_len: int | None = None,
                    page_size: int = 16, chunk: int = 32,
                    long_len: int = 120, seed: int = 0) -> dict:
    """Chunked vs unchunked prefill on a long+short mixed workload at
    equal config.  The number under test is ``max_join_s``: every refill
    join stalls all live slots' decode for its duration, so an unchunked
    120-token prompt makes one long pause while the chunked engine takes
    several short page-aligned bites interleaved with decode segments —
    bounded join latency at identical token output (greedy)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    if max_len is None:
        # the long prompts must fit whatever --max-new the caller picked
        max_len = long_len + max_new + 2 * page_size
    reqs = make_long_mixed_requests(cfg.vocab, requests, long_len,
                                    seed=seed)
    base = dict(max_len=max_len, batch=4, sync_every=8, paged=True,
                page_size=page_size)

    res = {}
    for name, ch in (("unchunked", None), ("chunked", chunk)):
        scfg = ServeConfig(**base, prefill_chunk=ch)
        engine_run(model, params, scfg, reqs, max_new)      # warmup
        t0 = time.perf_counter()
        got, b = engine_run(model, params, scfg, reqs, max_new)
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in got.values())
        j = b.join_stats()
        res[name] = {"tok_s": toks / dt, "s": dt, "tokens": toks,
                     "joins": j["joins"], "chunk_joins": j["chunk_joins"],
                     "max_join_s": j["max_join_s"],
                     "mean_join_s": j["mean_join_s"],
                     **_lat_row(b),
                     "tokens_by_rid": {r: v for r, v in got.items()}}
    # greedy parity is part of the bench contract, not just the tests
    assert (res["chunked"]["tokens_by_rid"]
            == res["unchunked"]["tokens_by_rid"]), \
        "chunked prefill changed sampled tokens"
    for r in res.values():
        del r["tokens_by_rid"]
    return res


def spec_compare(arch: str = "qwen2-0.5b", *, requests: int = 8,
                 max_new: int = 32, max_len: int = 96, page_size: int = 16,
                 batch: int = 4, k: int = 4, seed: int = 0) -> dict:
    """Self-speculative decoding on vs off on the repetitive-continuation
    workload, measured in the **steady serving state**: each engine's
    batcher drains one warmup wave (compiling its join + verify/decode
    executables), then the timed wave re-submits the same requests into
    the *same* batcher — a fresh Batcher would re-jit its closures and
    time compilation, not serving.  The number under test is tokens/sec
    at bit-identical greedy output: the verify step costs more than a
    one-token decode step (Lq = k+1), so speculation only wins where the
    drafter's acceptance rate is high — which this workload's cyclic
    continuations provide (the chaotic-workload case is covered by the
    parity tests, not benched as a win)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    reqs = make_repetitive_requests(cfg.vocab, requests, seed=seed)
    base = dict(max_len=max_len, batch=batch, sync_every=8, paged=True,
                page_size=page_size)
    wave2 = 10 ** 6      # rid offset of the timed wave

    res = {}
    for name, sk in (("spec-off", None), ("spec-on", k)):
        scfg = ServeConfig(**base, speculate_k=sk)
        b = Batcher(model, params, scfg)
        for rid, p in reqs:
            b.submit(rid, p)
        b.run(max_new=max_new)                     # warmup wave: compiles
        # restart the measurement state so the row's TTFT/TPOT
        # percentiles and acceptance_rate describe the steady-state
        # wave, not a blend with the compile-laden warmup
        b.reset_stats()
        for rid, p in reqs:
            b.submit(rid + wave2, p)
        t0 = time.perf_counter()
        b.run(max_new=max_new)                     # steady-state wave
        dt = time.perf_counter() - t0
        got = {r - wave2: v for r, v in b.results.items() if r >= wave2}
        toks = sum(len(v) for v in got.values())
        s = b.spec_stats()
        res[name] = {"tok_s": toks / dt, "s": dt, "tokens": toks,
                     "speculate_k": sk or 0,
                     "acceptance_rate": s["acceptance_rate"],
                     "tokens_per_step": s["tokens_per_step"],
                     **_lat_row(b),
                     "tokens_by_rid": got}
    # bit-exact greedy parity is the contract speculation rides on
    assert (res["spec-on"]["tokens_by_rid"]
            == res["spec-off"]["tokens_by_rid"]), \
        "speculative decoding changed sampled tokens"
    for r in res.values():
        del r["tokens_by_rid"]
    return res


def preempt_compare(arch: str = "qwen2-0.5b", *, requests: int = 9,
                    max_new: int = 14, max_len: int = 96,
                    page_size: int = 8, pool_pages: int = 10,
                    batch: int = 6, sync_every: int = 4,
                    seed: int = 1) -> dict:
    """Reservation vs optimistic admission at the same undersized pool.
    Reservation admits on the worst case (prompt + max_new + margin), so
    the tight pool serializes requests whose actual footprints would have
    fit together; optimistic admission takes prompt-only pages, grows
    slots on demand, and preempts the policy victim (lowest priority,
    most pages, least progress) when growth hits pool pressure —
    recompute-on-resume keeps greedy output bit-identical.  The numbers
    under test: optimistic must run strictly more concurrent slots at
    strictly higher mean KV utilization, with at least one preemption
    actually exercised and every preempted request recomputed to the
    same tokens."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    rng = np.random.default_rng(seed)
    reqs = [(rid, rng.integers(0, cfg.vocab,
                               size=int(rng.integers(8, 14))).tolist())
            for rid in range(requests)]
    base = dict(max_len=max_len, batch=batch, sync_every=sync_every,
                paged=True, page_size=page_size, total_pages=pool_pages)

    res = {}
    for name, mode in (("reserve", "reserve"), ("optimistic", "optimistic")):
        scfg = ServeConfig(**base, admission_mode=mode)
        engine_run(model, params, scfg, reqs, max_new)      # warmup
        t0 = time.perf_counter()
        got, b = engine_run(model, params, scfg, reqs, max_new)
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in got.values())
        util = b.kv_utilization()
        k = b.preempt_stats()
        lat = b.latency_stats()
        res[name] = {"tok_s": toks / dt, "s": dt, "tokens": toks,
                     "kv_util_mean": util["mean_util"],
                     "peak_live_slots": util["peak_live_slots"],
                     "preemptions": k["preemptions"],
                     "recompute_tokens": k["recompute_tokens"],
                     "recomputed_ok": bool(k["recomputed_ok"]),
                     "queue_wait_p50_s": lat["queue_wait_p50_s"],
                     "queue_wait_p95_s": lat["queue_wait_p95_s"],
                     **_lat_row(b),
                     "tokens_by_rid": {r: v for r, v in got.items()}}
    # recompute-on-resume keeps greedy decode bit-identical to the
    # never-preempted run — the contract optimism rides on
    assert (res["optimistic"]["tokens_by_rid"]
            == res["reserve"]["tokens_by_rid"]), \
        "preemption/resume changed sampled tokens"
    for r in res.values():
        del r["tokens_by_rid"]
    o, rsv = res["optimistic"], res["reserve"]
    assert o["preemptions"] > 0, \
        "undersized-pool workload triggered no preemptions"
    assert o["recomputed_ok"], "a preempted request never completed"
    assert o["peak_live_slots"] > rsv["peak_live_slots"], \
        "optimistic admission did not raise concurrency at equal pool"
    assert o["kv_util_mean"] > rsv["kv_util_mean"], \
        "optimistic admission did not raise KV utilization at equal pool"
    return res


def overload_compare(arch: str = "qwen2-0.5b", *, wave: int = 4,
                     burst_factor: int = 3, max_new: int = 12,
                     max_len: int = 96, page_size: int = 8,
                     pool_pages: int = 12, batch: int = 4,
                     sync_every: int = 4, seed: int = 3) -> dict:
    """Degradation controller on vs off under a deadline-carrying
    ``burst_factor``x-capacity queue burst at the same undersized pool.

    Calibration avoids wall-clock flakiness: an unloaded reference
    batcher (ample pool, no deadlines) first drains the wave alone in
    the steady state, and every measured request's deadline is 2x that
    unloaded drain — reachable for the protected wave, unreachable for
    a burst serialized behind ``burst_factor``x the capacity.  The
    controller-off engine admits everything optimistically and thrashes:
    burst requests are deadline-cancelled (scored misses) once expiry or
    the remaining-budget projection catches them.  The controller-on
    engine trips SHEDDING on pool pressure and answers the burst with
    retryable RETRY_AFTER rejections — *excluded* from attainment (a
    fast rejection is not a latency violation) — so the wave's deadlines
    survive.  Gates: controller-on beats controller-off on deadline
    attainment, both sides drain with zero orphaned pages
    (``KVPool.check`` + full partition accounting), and every request
    that *completes* is bit-identical to the unloaded reference run
    (degradation changes when and whether work runs, never its
    tokens)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    rng = np.random.default_rng(seed)
    n = wave * (1 + burst_factor)
    # >= page_size-token prompts: admission maps 2+ pages per slot, so a
    # full slot table alone puts the pool well past degrade_pressure
    reqs = [(rid, rng.integers(0, cfg.vocab,
                               size=int(rng.integers(page_size + 2,
                                                     2 * page_size))
                               ).tolist()) for rid in range(n)]
    wave_reqs, burst_reqs = reqs[:wave], reqs[wave:]
    wave2 = 10 ** 6          # rid offset of each batcher's warmup wave

    # unloaded reference: ample pool, no deadlines — the parity oracle
    # (greedy tokens are schedule-independent) and the deadline
    # calibration, both measured on a *warm* batcher (a fresh one would
    # time jit compilation, not serving)
    ref_cfg = ServeConfig(max_len=max_len, batch=batch,
                          sync_every=sync_every, paged=True,
                          page_size=page_size)
    rb = Batcher(model, params, ref_cfg)
    for rid, p in reqs:
        rb.submit(rid + wave2, p)
    rb.run(max_new=max_new)                    # warmup: compiles
    rb.reset_stats()
    for rid, p in wave_reqs:
        rb.submit(rid, p)
    t0 = time.perf_counter()
    rb.run(max_new=max_new)
    t_wave = time.perf_counter() - t0          # unloaded wave drain
    for rid, p in burst_reqs:
        rb.submit(rid, p)
    ref_all = dict(rb.run(max_new=max_new))    # parity oracle, all rids
    deadline = 2.0 * t_wave

    base = dict(max_len=max_len, batch=batch, sync_every=sync_every,
                paged=True, page_size=page_size, total_pages=pool_pages,
                admission_mode="optimistic")
    res = {}
    for name, on in (("controller-off", False), ("controller-on", True)):
        scfg = ServeConfig(**base, overload=on,
                           overload_degrade_pressure=0.5,
                           overload_shed_pressure=0.65,
                           overload_up_rounds=1, overload_down_rounds=2)
        b = Batcher(model, params, scfg)
        for rid, p in reqs:                    # warmup at full load: the
            b.submit(rid + wave2, p)           # timed run replays warm
        b.run(max_new=max_new)                 # shapes, no compiles
        b.reset_stats()
        for rid, p in wave_reqs:
            b.submit(rid, p, priority=0, deadline_s=deadline)
        for rid, p in burst_reqs:
            b.submit(rid, p, priority=-1, deadline_s=deadline)
        t0 = time.perf_counter()
        got = {rid: out for rid, out in b.run(max_new=max_new).items()
               if rid < wave2}
        dt = time.perf_counter() - t0
        b.pool.check()                         # no orphans, exact refcounts
        assert (b.pool.free_pages + b.pool.cached_pages
                + b.pool.preempted_pages == b.pool.n_pages), \
            f"{name}: pages unaccounted for after drain"
        # every request that completed did so bit-identically to the
        # unloaded reference — overload protection never changes tokens
        bad = [rid for rid, out in got.items() if out != ref_all[rid]]
        assert not bad, f"{name}: tokens diverged for rids {bad}"
        o = b.overload_stats()
        res[name] = {"tok_s": sum(len(v) for v in got.values()) / dt,
                     "s": dt, "completed": len(got),
                     "deadline_attainment": o["deadline_attainment"],
                     "deadline_met": o["deadline_met"],
                     "deadline_total": o["deadline_total"],
                     "cancellations": o["cancellations"],
                     "shed_requests": o["shed_requests"],
                     "rejections": len(o["rejections"]),
                     "preemptions": b.preemptions,
                     "controller_state": o["controller"]["state"],
                     **_lat_row(b)}
    off, on_ = res["controller-off"], res["controller-on"]
    assert on_["deadline_attainment"] > off["deadline_attainment"], \
        (f"degradation controller did not improve deadline attainment: "
         f"on {on_['deadline_attainment']:.2f} vs "
         f"off {off['deadline_attainment']:.2f}")
    assert on_["shed_requests"] > 0, \
        "controller-on burst produced no RETRY_AFTER sheds"
    return res


def prefill_kernel_timing(arch: str = "qwen2-0.5b", *, b: int = 4,
                          lq: int = 32, pages: int = 64,
                          page_size: int = 16, reps: int = 3) -> dict:
    """Pallas flash-prefill kernel (interpret off-TPU) vs the XLA gather
    ref on one suffix-prefill shape — reported for trajectory only (the
    interpreter is expected to lose off-TPU; the kernel path is routed in
    on real backends)."""
    from repro.kernels.paged_attn import (paged_prefill_attn_pallas,
                                          paged_prefill_attn_ref)
    cfg = get_config(arch).reduced()
    hq, hkv = cfg.n_heads, cfg.kv_heads
    d = cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, lq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((pages, page_size, hkv, d)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((pages, page_size, hkv, d)),
                    jnp.float32)
    p_max = pages // b
    tbl = jnp.asarray(rng.permutation(pages)[:b * p_max]
                      .reshape(b, p_max).astype(np.int32))
    off = jnp.asarray(rng.integers(0, (p_max - 2) * page_size - lq,
                                   size=b).astype(np.int32))
    ln = off + lq

    def timed(fn):
        fn(q, k, v, tbl, off, ln).block_until_ready()    # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(q, k, v, tbl, off, ln)
        out.block_until_ready()
        return (time.perf_counter() - t0) / reps

    return {"kernel_interpret_s": timed(paged_prefill_attn_pallas),
            "xla_ref_s": timed(jax.jit(paged_prefill_attn_ref)),
            "backend": jax.default_backend()}


def autotune_compare(arch: str = "qwen2-0.5b", *, ops=None, b: int = 2,
                     lq: int = 8, pages: int = 16, page_size: int = 8,
                     budget: int | None = 8, reps: int = 3, seed: int = 0,
                     tuned_out: str | None = None) -> dict:
    """Generalize ``prefill_kernel_timing`` across the whole paged_attn
    family: sweep every launch config the kernels accept per op (grid
    order; row-fold tiling on prefill/verify), analytically prune with
    the roofline traffic model, benchmark survivors through the kernel
    telemetry hooks, and report one ``autotune-<op>`` row per op with
    the per-candidate measurements attached.  Winners optionally persist
    to ``tuned_out`` in the tuned-shape cache schema so the row is also
    the provenance record for the committed cache."""
    from repro.kernels.paged_attn import autotune as at
    cfg = get_config(arch).reduced()
    geom = at.Geometry(hq=cfg.n_heads, hkv=cfg.kv_heads,
                       d=cfg.resolved_head_dim, page_size=page_size)
    res = at.autotune(tuple(ops or at.OPS), geom=geom, b=b, lq=lq,
                      pages=pages, budget=budget, reps=reps, seed=seed)
    rows: dict = {}
    for op, r in res.items():
        assert r["winner"] is not None, f"{op}: no winner selected"
        assert r["winner_wall_s"] <= r["default_wall_s"], \
            f"{op}: winner slower than the default it was measured against"
        assert r["achieved_gbps"] > 0, f"{op}: no timed telemetry recorded"
        rows[f"autotune-{op}"] = {
            "geometry": geom.key(),
            "op": op,
            "winner": r["winner"],
            "winner_wall_s": r["winner_wall_s"],
            "default_wall_s": r["default_wall_s"],
            "achieved_gbps": r["achieved_gbps"],
            "op_byte": r["op_byte"],
            "n_candidates": len(r["candidates"]),
            "n_pruned": len(r["pruned"]),
            "n_parity_dropped": len(r["parity_dropped"]),
            "candidates": [
                {"config": c["config"], "wall_s": round(c["wall_s"], 6),
                 "achieved_gbps": round(c["achieved_gbps"], 4)}
                for c in r["candidates"]],
        }
    if tuned_out:
        at.save_entries(res, tuned_out)
    return rows


def roofline_probe(arch: str = "qwen2-0.5b", *, b: int = 2, lq: int = 8,
                   pages: int = 16, page_size: int = 8) -> dict:
    """Eagerly drive decode / prefill / verify once through the kernel
    route so the attention telemetry holds *timed* calls: the jitted
    serving path records its traffic at trace time but never wall time
    (by design — no sync in the hot loop), so achieved GB/s would stay 0
    without an eager probe.  Returns the three ``op.kernel`` snapshot
    rows."""
    from repro.kernels.decode_attn import decode_attn_policy
    from repro.kernels.paged_attn import (attn_telemetry, paged_attn,
                                          paged_prefill_attn,
                                          paged_verify_attn)
    cfg = get_config(arch).reduced()
    hq, hkv = cfg.n_heads, cfg.kv_heads
    d = cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    kp = jnp.asarray(rng.standard_normal((pages, page_size, hkv, d)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pages, page_size, hkv, d)),
                     jnp.float32)
    p_max = pages // b
    tbl = jnp.asarray(rng.permutation(pages)[:b * p_max]
                      .reshape(b, p_max).astype(np.int32))
    off = jnp.asarray(rng.integers(page_size, (p_max - 1) * page_size - lq,
                                   size=b).astype(np.int32))
    ln = off + lq
    q1 = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    qk = jnp.asarray(rng.standard_normal((b, lq, hq, d)), jnp.float32)
    tel = attn_telemetry()
    was = tel.enabled
    tel.enable()
    with decode_attn_policy(mode="kernel", interpret=True):
        paged_attn(q1, kp, vp, tbl, ln, interpret=True)
        paged_prefill_attn(qk, kp, vp, tbl, off, ln)
        paged_verify_attn(qk, kp, vp, tbl, off, ln)
    snap = tel.snapshot()
    if not was:
        tel.disable()
    return {k: snap[k] for k in ("decode.kernel", "prefill.kernel",
                                 "verify.kernel") if k in snap}


def print_roofline() -> None:
    """Dump the live roofline/amenability accounting accumulated by the
    run so far: per-(op, route) traffic, op/byte and achieved GB/s, then
    the paper's amenability verdict over the measured op mix."""
    from repro.kernels.paged_attn import amenability_reports, attn_telemetry
    snap = attn_telemetry().snapshot()
    if not snap:
        return
    print("[roofline] analytic traffic per (op, route) — dead pages "
          "subtracted; GB/s over eagerly-timed calls only")
    for key, row in snap.items():
        print(f"  {key:<16} {row['calls']:>4} calls "
              f"({row['traced_calls']} traced), "
              f"{row['bytes'] / 1e6:8.2f} MB, "
              f"op/byte {row['op_byte']:6.2f}, "
              f"achieved {row['achieved_gbps']:.3f} GB/s")
    for _op, rep in sorted(amenability_reports().items()):
        print(rep.summary())


def run(table) -> None:
    """Hook for benchmarks.run: engine-vs-seed, dense-vs-paged and
    prefix-cache rows plus the paged-attention roofline; also refreshes
    BENCH_serve.json."""
    from repro.kernels.paged_attn import attn_telemetry
    tel = attn_telemetry()
    tel.reset()
    tel.enable()
    r = bench(requests=8, max_new=16, batch=4)
    table.add("serve seed per-token loop", r["seed_s"] * 1e9,
              f"{r['seed_tok_s']:.1f} tok/s")
    table.add("serve device-resident engine", r["engine_s"] * 1e9,
              f"{r['engine_tok_s']:.1f} tok/s ({r['speedup']:.1f}x, "
              f"KV util {r['kv_util_mean']:.0%})")
    c = capacity_compare(requests=12, max_new=16)
    table.add("serve paged KV pool (equal KV mem)",
              c["paged"]["s"] * 1e9,
              f"{c['paged']['tok_s']:.1f} tok/s, "
              f"{c['paged']['peak_live_slots']} live slots vs "
              f"{c['dense']['peak_live_slots']} dense, "
              f"KV util {c['paged']['kv_util_mean']:.0%} vs "
              f"{c['dense']['kv_util_mean']:.0%}")
    p = prefix_compare(requests=12, max_new=16)
    on, off = p["cache-on"], p["cache-off"]
    table.add("serve prefix cache (shared prompt)",
              on["s"] * 1e9,
              f"{on['tok_s']:.1f} tok/s, hit rate "
              f"{on['prefix_hit_rate']:.0%}, prefill "
              f"{on['prefill_computed']} vs {off['prefill_computed']} "
              f"tokens, {on['peak_live_slots']} vs "
              f"{off['peak_live_slots']} live slots")
    ch = chunked_compare(requests=8, max_new=16)
    con, coff = ch["chunked"], ch["unchunked"]
    table.add("serve chunked prefill (long prompts)",
              con["s"] * 1e9,
              f"{con['tok_s']:.1f} tok/s, max join stall "
              f"{con['max_join_s'] * 1e3:.0f}ms vs "
              f"{coff['max_join_s'] * 1e3:.0f}ms unchunked "
              f"({con['chunk_joins']} chunk joins)")
    sc = spec_compare(requests=8, max_new=32)
    son, soff = sc["spec-on"], sc["spec-off"]
    table.add("serve self-speculative decode (repetitive)",
              son["s"] * 1e9,
              f"{son['tok_s']:.1f} tok/s vs {soff['tok_s']:.1f} off "
              f"({son['tok_s'] / max(soff['tok_s'], 1e-9):.1f}x, accept "
              f"{son['acceptance_rate']:.0%}, "
              f"{son['tokens_per_step']:.1f} tok/step)")
    pr = preempt_compare()
    po, prs = pr["optimistic"], pr["reserve"]
    table.add("serve optimistic admission (undersized pool)",
              po["s"] * 1e9,
              f"{po['tok_s']:.1f} tok/s, {po['peak_live_slots']} vs "
              f"{prs['peak_live_slots']} live slots, KV util "
              f"{po['kv_util_mean']:.0%} vs {prs['kv_util_mean']:.0%} "
              f"({po['preemptions']} preemptions)")
    ov = overload_compare()
    oon, ooff = ov["controller-on"], ov["controller-off"]
    table.add("serve overload protection (3x burst + deadlines)",
              oon["s"] * 1e9,
              f"attainment {oon['deadline_attainment']:.0%} vs "
              f"{ooff['deadline_attainment']:.0%} uncontrolled "
              f"({oon['shed_requests']} shed, "
              f"{oon['cancellations']} cancelled)")
    for key, row in sorted(roofline_probe().items()):
        table.add(f"paged-attn roofline {key}", row["wall_s"] * 1e9,
                  f"{row['achieved_gbps']:.3f} GB/s achieved, "
                  f"op/byte {row['op_byte']:.2f}, "
                  f"{row['bytes'] / 1e6:.2f} MB moved")
    tel.disable()
    write_bench_json(full_bench_rows(r, c, p, ch, sc, pr, ov))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV-cache block pool")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix radix cache (needs --paged); runs "
                         "a repeated-system-prompt workload and reports "
                         "hit rate + prefill tokens computed vs skipped")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill (needs --paged): admit prompts "
                         "in page-aligned chunks of this many tokens, "
                         "interleaved with decode segments")
    ap.add_argument("--speculate", type=int, default=None,
                    help="self-speculative decoding (needs --paged): "
                         "draft this many tokens per step from the "
                         "slot's own history and verify them in one "
                         "multi-token paged attention call (greedy, "
                         "bit-identical output); runs the repetitive-"
                         "continuation workload and reports the "
                         "acceptance rate")
    ap.add_argument("--optimistic", action="store_true",
                    help="optimistic admission + page-level preemption "
                         "(needs --paged): admit on prompt pages only, "
                         "grow on demand, preempt the policy victim on "
                         "pool pressure with recompute-on-resume; the "
                         "smoke forces pool exhaustion via the chaos "
                         "injector and gates preemptions > 0 + bit-safe "
                         "recompute, the full mode runs preempt_compare")
    ap.add_argument("--overload", action="store_true",
                    help="overload protection (needs --paged): serve "
                         "with the degradation controller on while the "
                         "chaos injector exhausts the pool and injects a "
                         "deadline-stamped low-priority queue burst; the "
                         "smoke gates cancellations > 0, shed > 0, "
                         "recovery to HEALTHY and zero orphaned pages")
    ap.add_argument("--overload-compare", action="store_true",
                    help="standalone controller-on vs controller-off "
                         "comparison under a deadline-carrying 3x-"
                         "capacity burst (the overload_compare gate: "
                         "controller-on must win on deadline attainment "
                         "at bit-identical completed tokens).  Runs "
                         "instead of the serve bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity: engine only, tiny sizes, ~5s")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record the measured drain's request-lifecycle "
                         "trace and write it as Chrome/Perfetto "
                         "trace_event JSON (open at ui.perfetto.dev)")
    ap.add_argument("--attr-out", default=None, metavar="PATH",
                    help="write the per-request latency-attribution "
                         "report (TTFT/TPOT decomposed into queue / "
                         "prefill / recompute / stall components) as "
                         "JSON; needs --trace-out")
    ap.add_argument("--ttft-slo", type=float, default=None, metavar="S",
                    help="TTFT SLO in seconds: rows gain slo_attainment "
                         "(smokes default to a generous 60s so the gate "
                         "is deterministic)")
    ap.add_argument("--tpot-slo", type=float, default=None, metavar="S",
                    help="per-output-token SLO in seconds (see "
                         "--ttft-slo)")
    ap.add_argument("--autotune-compare", action="store_true",
                    help="standalone kernel-autotune sweep across decode/"
                         "prefill/verify: enumerate launch configs, prune "
                         "on the analytic roofline score, benchmark the "
                         "survivors and write per-candidate rows (config, "
                         "wall time, achieved GB/s, op/byte) into "
                         "BENCH_serve.json; with --smoke the sweep is "
                         "bounded for CI (<=4 measured candidates per op, "
                         "2 reps).  Runs instead of the serve bench")
    ap.add_argument("--tuned-out", default=None, metavar="PATH",
                    help="with --autotune-compare: also persist the "
                         "winners to this tuned-shape cache file")
    args = ap.parse_args()
    if args.tuned_out and not args.autotune_compare:
        ap.error("--tuned-out requires --autotune-compare")
    if args.autotune_compare:
        rows = autotune_compare(
            args.arch,
            page_size=min(args.page_size, 8) if args.smoke
            else args.page_size,
            budget=4 if args.smoke else 8,
            reps=2 if args.smoke else 3,
            tuned_out=args.tuned_out)
        write_bench_json(rows)
        for name, row in sorted(rows.items()):
            print(f"[{name}] winner {row['winner']} "
                  f"{row['winner_wall_s'] * 1e3:.2f}ms "
                  f"(default {row['default_wall_s'] * 1e3:.2f}ms), "
                  f"{row['achieved_gbps']:.3f} GB/s over "
                  f"{row['n_candidates']} measured / "
                  f"{row['n_pruned']} pruned candidates")
        if args.tuned_out:
            print(f"[autotune] winners persisted to {args.tuned_out}")
        return
    if args.overload_compare:
        res = overload_compare(args.arch)
        write_bench_json({"full-overload-on": res["controller-on"],
                          "full-overload-off": res["controller-off"]})
        for name in ("controller-off", "controller-on"):
            row = res[name]
            print(f"[overload_compare] {name}: attainment "
                  f"{row['deadline_attainment']:.0%} "
                  f"({row['deadline_met']}/{row['deadline_total']}), "
                  f"{row['completed']} completed, "
                  f"{row['shed_requests']} shed, "
                  f"{row['cancellations']} cancelled, "
                  f"{row['preemptions']} preemptions")
        return
    if args.attr_out and not args.trace_out:
        ap.error("--attr-out requires --trace-out (attribution walks "
                 "the recorded trace)")
    if args.prefix_cache and not args.paged:
        ap.error("--prefix-cache requires --paged")
    if args.optimistic and not args.paged:
        ap.error("--optimistic requires --paged")
    if args.overload and not args.paged:
        ap.error("--overload requires --paged")
    if args.speculate is not None:
        if not args.paged:
            ap.error("--speculate requires --paged")
        if args.speculate < 1:
            ap.error("--speculate must be >= 1")
    if args.prefill_chunk is not None:
        if not args.paged:
            ap.error("--prefill-chunk requires --paged")
        if args.prefill_chunk <= 0:
            ap.error("--prefill-chunk must be positive")
        if args.prefill_chunk % args.page_size:
            ap.error(f"--prefill-chunk must be a multiple of --page-size "
                     f"({args.page_size})")
    if args.smoke:
        smoke_ps = min(args.page_size, 8)
        chunk = args.prefill_chunk
        if chunk is not None:
            # the smoke shrinks the page size; re-align the chunk to it
            chunk = max(smoke_ps, chunk - chunk % smoke_ps)
        chaos = None
        overload_opts = None
        if args.overload:
            # the overload drill: the injector drains the free list at
            # round 1 (pool pressure 1.0 before anything admits) and
            # injects a deadline-stamped low-priority 8-request burst at
            # the same round, so the controller — with single-round
            # hysteresis at smoke sizes — climbs to SHEDDING by round 2
            # and sheds the burst with RETRY_AFTER *before* the
            # projection sweep could deadline-cancel it (round 1 has no
            # latency samples yet, so projections abstain).  Pages come
            # back at round 5, pressure collapses, and the ladder must
            # walk back to HEALTHY — the recovery the smoke gates on.
            chaos = ChaosInjector(exhaust_at={1: 0}, release_at=(5,),
                                  burst_at={1: 8}, burst_deadline_s=5.0,
                                  check_invariants=True)
            overload_opts = dict(overload_degrade_pressure=0.5,
                                 overload_shed_pressure=0.8,
                                 overload_up_rounds=1,
                                 overload_down_rounds=1,
                                 # keep the 4-request wave: only the
                                 # synthetic burst is sheddable
                                 overload_queue_keep=4)
        elif args.optimistic:
            # forced pool exhaustion right after the first admissions
            # (mid-growth, while slots still need pages): the injector
            # raids the free list at round 2 and hands it back at round
            # 5, guaranteeing at least one preemption even at smoke
            # sizes; per-round pool/prefix invariant checks ride along
            chaos = ChaosInjector(exhaust_at={2: 0}, release_at=(5,),
                                  check_invariants=True)
        r = bench(args.arch, batch=2, requests=4,
                  # speculation needs enough output for the drafter's
                  # cycle lookup to engage (acceptance_rate is gated > 0);
                  # preemption needs enough decode rounds for growth
                  # demand to hit the chaos-starved pool
                  max_new=12 if args.speculate else
                          10 if args.optimistic or args.overload else 4,
                  # chunked prompts carry a 2*chunk shared prefix — scale
                  # the window so any valid chunk size fits; speculative
                  # requests need prompt + max_new + k to fit
                  max_len=2 * chunk + 32 if chunk else
                          48 if (args.speculate or args.optimistic
                                 or args.overload) else 32,
                  sync_every=4, smoke=True, paged=args.paged,
                  page_size=smoke_ps, prefix_cache=args.prefix_cache,
                  prefill_chunk=chunk, speculate_k=args.speculate,
                  # tight pool so slot growth actually contends while
                  # the chaos injector holds pages back
                  total_pages=(10 if args.optimistic or args.overload
                               else None),
                  admission_mode=("optimistic"
                                  if args.optimistic or args.overload
                                  else "reserve"),
                  chaos=chaos, trace_out=args.trace_out,
                  attr_out=args.attr_out,
                  overload=args.overload, overload_opts=overload_opts,
                  # generous default SLOs keep smoke attainment at a
                  # deterministic 1.0 across runners while still
                  # exercising the whole monitor path
                  ttft_slo=(args.ttft_slo if args.ttft_slo is not None
                            else 60.0),
                  tpot_slo=(args.tpot_slo if args.tpot_slo is not None
                            else 60.0),
                  # at the smoke's tiny default prompts a chunk never
                  # splits — make every prompt long enough to take 2+
                  # bites (the shared prefix also feeds --prefix-cache)
                  shared_prefix=2 * chunk if chunk else 0)
        assert r["engine_tok_s"] > 0, r
        if args.paged:
            assert r["pages_reclaimed"], "retired pages were not reclaimed"
        if args.optimistic:
            assert r["preemptions"] > 0, \
                "chaos-starved pool forced no preemptions"
            assert r["recomputed_ok"], \
                "a preempted request did not complete via recompute"
        if args.prefix_cache:
            assert r["prefix_hit_rate"] > 0, \
                "shared-prompt workload produced no prefix-cache hits"
            assert r["prefill_skipped"] > 0, r
        if chunk:
            assert r["chunk_joins"] > 0, \
                "chunked smoke ran no chunk continuations"
        if args.speculate:
            assert r["acceptance_rate"] > 0, \
                "speculative smoke accepted no drafts on the " \
                "repetitive-continuation workload"
        if args.overload:
            assert r["cancellations"] > 0, \
                "overload smoke cancelled nothing"
            assert r["shed_requests"] > 0, \
                "SHEDDING never shed the chaos burst"
            assert r["recovered_to_healthy"], \
                "controller never walked back to HEALTHY after the burst"
        mode = ("overload" if args.overload
                else "preempt" if args.optimistic
                else "spec" if args.speculate
                else "chunked" if chunk
                else "paged+prefix" if args.prefix_cache
                else "paged" if args.paged else "dense")
        write_bench_json({f"smoke-{mode}": {
            "tok_s": r["engine_tok_s"], "tokens": r["tokens"],
            "kv_util_mean": r["kv_util_mean"],
            "prefix_hit_rate": r["prefix_hit_rate"],
            "prefill_computed": r["prefill_computed"],
            "prefill_skipped": r["prefill_skipped"],
            "chunk_joins": r["chunk_joins"],
            "acceptance_rate": r["acceptance_rate"],
            "tokens_per_step": r["tokens_per_step"],
            "preemptions": r["preemptions"],
            "recomputed_ok": r["recomputed_ok"],
            "preempted_token_recompute": r["preempted_token_recompute"],
            "ttft_p50_s": r["ttft_p50_s"], "ttft_p95_s": r["ttft_p95_s"],
            "tpot_p50_s": r["tpot_p50_s"], "tpot_p95_s": r["tpot_p95_s"],
            "slo_attainment": r["slo_attainment"],
            "cancellations": r["cancellations"],
            "shed_requests": r["shed_requests"],
            "deadline_attainment": r["deadline_attainment"],
            "watchdog_trips": r["watchdog_trips"],
            "recovered_to_healthy": r["recovered_to_healthy"],
            "time_healthy_s": r["time_healthy_s"],
            "time_degraded_s": r["time_degraded_s"],
            "time_shedding_s": r["time_shedding_s"],
            "pages_reclaimed": bool(r.get("pages_reclaimed", False))}})
        dom = (f", dominant TTFT {r['dominant_ttft_component']}"
               if "dominant_ttft_component" in r else "")
        ovl = (f", shed {r['shed_requests']}, cancelled "
               f"{r['cancellations']}, deadline attainment "
               f"{r['deadline_attainment']:.0%}, recovered="
               f"{r['recovered_to_healthy']}" if args.overload else "")
        print(f"[serve_bench --smoke] {mode}: {r['tokens']} tokens, "
              f"{r['engine_tok_s']:.1f} tok/s, "
              f"KV util {r['kv_util_mean']:.0%}, "
              f"prefix hit rate {r['prefix_hit_rate']:.0%}, "
              f"acceptance {r['acceptance_rate']:.0%}, "
              f"preemptions {r['preemptions']}{ovl}, "
              f"SLO attainment {r['slo_attainment']:.0%}{dom} "
              f"on {jax.default_backend()}")
        return
    from repro.kernels.paged_attn import attn_telemetry
    attn_telemetry().enable()      # roofline accounting over the full run
    r = bench(args.arch, batch=args.batch, requests=args.requests,
              max_new=args.max_new, max_len=args.max_len,
              sync_every=args.sync_every, paged=args.paged,
              page_size=args.page_size, prefix_cache=args.prefix_cache,
              prefill_chunk=args.prefill_chunk,
              speculate_k=args.speculate, trace_out=args.trace_out,
              attr_out=args.attr_out, ttft_slo=args.ttft_slo,
              tpot_slo=args.tpot_slo)
    mode = ("spec" if args.speculate
            else "paged+prefix" if args.prefix_cache
            else "paged" if args.paged else "dense")
    print(f"[serve_bench] arch={r['arch']} mode={mode} "
          f"tokens={r['tokens']} backend={jax.default_backend()}")
    print(f"  seed per-token loop : {r['seed_tok_s']:8.1f} tok/s "
          f"({r['seed_s']:.2f}s)")
    print(f"  device-resident loop: {r['engine_tok_s']:8.1f} tok/s "
          f"({r['engine_s']:.2f}s)")
    print(f"  speedup             : {r['speedup']:.2f}x")
    print(f"  KV utilization      : mean {r['kv_util_mean']:.1%}, "
          f"peak {r['kv_util_peak']:.1%} "
          f"(live tokens / allocated capacity)")
    if r["slo_enabled"]:
        print(f"  SLO attainment      : {r['slo_attainment']:.1%} "
              f"(ttft<={args.ttft_slo}s, tpot<={args.tpot_slo}s)")
    if "dominant_ttft_component" in r:
        print(f"  dominant TTFT cost  : {r['dominant_ttft_component']}")
    assert r["speedup"] >= 3.0, \
        f"serving regressed: engine only {r['speedup']:.2f}x the seed loop"

    c = capacity_compare(args.arch, max_new=args.max_new,
                         max_len=args.max_len, page_size=args.page_size)
    d, p = c["dense"], c["paged"]
    print(f"[capacity @ equal KV memory] dense: {d['tok_s']:.1f} tok/s, "
          f"peak {d['peak_live_slots']} live slots, "
          f"KV util {d['kv_util_mean']:.1%}")
    print(f"                             paged: {p['tok_s']:.1f} tok/s, "
          f"peak {p['peak_live_slots']} live slots, "
          f"KV util {p['kv_util_mean']:.1%}, "
          f"reclaimed={p['pages_reclaimed']}")
    assert (p["peak_live_slots"] > d["peak_live_slots"]
            or (p["tok_s"] >= 0.9 * d["tok_s"] and p["pages_reclaimed"])), \
        "paged pool shows no capacity or throughput win over dense"

    pc = prefix_compare(args.arch, max_new=args.max_new,
                        max_len=args.max_len)
    on, off = pc["cache-on"], pc["cache-off"]
    total = off["prefill_computed"] + off["prefill_skipped"]
    print(f"[prefix cache @ equal pool]  off: {off['tok_s']:.1f} tok/s, "
          f"prefill {off['prefill_computed']} tokens, "
          f"peak {off['peak_live_slots']} live slots")
    print(f"                              on: {on['tok_s']:.1f} tok/s, "
          f"prefill {on['prefill_computed']} tokens "
          f"(hit rate {on['prefix_hit_rate']:.1%}), "
          f"peak {on['peak_live_slots']} live slots")
    assert on["prefill_skipped"] > 0, "shared-prompt workload never hit"
    # computed drops by exactly the hit tokens: same total prompt work
    assert on["prefill_computed"] + on["prefill_skipped"] == total, pc
    assert on["peak_live_slots"] >= off["peak_live_slots"], \
        "prefix sharing lost concurrency at equal pool size"

    ch = chunked_compare(args.arch, max_new=args.max_new)
    con, coff = ch["chunked"], ch["unchunked"]
    print(f"[chunked prefill @ long+short] off: {coff['tok_s']:.1f} tok/s, "
          f"max join stall {coff['max_join_s'] * 1e3:.0f}ms "
          f"({coff['joins']} joins)")
    print(f"                                on: {con['tok_s']:.1f} tok/s, "
          f"max join stall {con['max_join_s'] * 1e3:.0f}ms "
          f"({con['joins']} joins, {con['chunk_joins']} continuations)")
    assert con["chunk_joins"] > 0, "long prompts were never chunked"
    # each chunked join does strictly less work than the one long join,
    # but max-of-few-wall-clock-samples is noisy — gate the mean hard and
    # give the max a 25% scheduling-noise allowance
    assert con["mean_join_s"] < coff["mean_join_s"], \
        "chunked prefill did not shrink the mean join stall"
    assert con["max_join_s"] < 1.25 * coff["max_join_s"], \
        "chunked prefill did not bound the worst-case join stall"

    sc = spec_compare(args.arch, k=args.speculate or 4)
    son, soff = sc["spec-on"], sc["spec-off"]
    spec_x = son["tok_s"] / max(soff["tok_s"], 1e-9)
    print(f"[self-speculative @ repetitive] off: {soff['tok_s']:.1f} tok/s")
    print(f"                                 on: {son['tok_s']:.1f} tok/s "
          f"({spec_x:.2f}x, k={son['speculate_k']}, acceptance "
          f"{son['acceptance_rate']:.1%}, "
          f"{son['tokens_per_step']:.2f} tok/step)")
    assert son["acceptance_rate"] > 0, \
        "repetitive-continuation workload accepted no drafts"
    assert spec_x >= 1.5, \
        f"speculative decoding only {spec_x:.2f}x on the repetitive-" \
        "continuation workload (want >= 1.5x)"

    pr = preempt_compare(args.arch)
    po, prs = pr["optimistic"], pr["reserve"]
    print(f"[preempt @ undersized pool] reserve: {prs['tok_s']:.1f} tok/s, "
          f"peak {prs['peak_live_slots']} live slots, "
          f"KV util {prs['kv_util_mean']:.1%}")
    print(f"                         optimistic: {po['tok_s']:.1f} tok/s, "
          f"peak {po['peak_live_slots']} live slots, "
          f"KV util {po['kv_util_mean']:.1%} "
          f"({po['preemptions']} preemptions, "
          f"{po['recompute_tokens']} tokens recomputed)")

    ov = overload_compare(args.arch)
    oon, ooff = ov["controller-on"], ov["controller-off"]
    print(f"[overload @ 3x burst + deadlines] off: attainment "
          f"{ooff['deadline_attainment']:.0%} "
          f"({ooff['deadline_met']}/{ooff['deadline_total']}, "
          f"{ooff['cancellations']} cancelled)")
    print(f"                                   on: attainment "
          f"{oon['deadline_attainment']:.0%} "
          f"({oon['deadline_met']}/{oon['deadline_total']}, "
          f"{oon['shed_requests']} shed with RETRY_AFTER)")

    kt = prefill_kernel_timing(args.arch)
    print(f"[prefill kernel]  pallas(interpret={kt['backend'] != 'tpu'}): "
          f"{kt['kernel_interpret_s'] * 1e3:.1f}ms / call, xla ref: "
          f"{kt['xla_ref_s'] * 1e3:.1f}ms / call on {kt['backend']}")
    roofline_probe(args.arch)
    print_roofline()
    write_bench_json(full_bench_rows(r, c, pc, ch, sc, pr, ov))


if __name__ == "__main__":
    main()
