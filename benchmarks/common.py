"""Shared benchmark plumbing: CSV row emission + paper-anchor comparison."""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float          # modeled execution time (us) where relevant
    derived: str                # the figure's metric (speedup etc.)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


class Table:
    """Collects rows for one paper table/figure and prints CSV."""

    def __init__(self, title: str):
        self.title = title
        self.rows: list[Row] = []
        self._t0 = time.perf_counter()

    def add(self, name: str, time_ns: float, derived: str) -> None:
        self.rows.append(Row(name, time_ns / 1e3, derived))

    def anchor(self, name: str, value: float, paper: float | str,
               time_ns: float = 0.0) -> None:
        if isinstance(paper, (int, float)):
            delta = (value / paper - 1.0) * 100.0
            derived = f"{value:.2f}x (paper {paper}x, {delta:+.0f}%)"
        else:
            derived = f"{value:.2f}x (paper: {paper})"
        self.add(name, time_ns, derived)

    def emit(self) -> None:
        dt = time.perf_counter() - self._t0
        print(f"# {self.title}  [{dt:.1f}s]")
        print("name,us_per_call,derived")
        for row in self.rows:
            print(row.csv())
        print()
