"""Benchmark driver: one table per paper figure + framework perf tables.

``PYTHONPATH=src python -m benchmarks.run [--fast]``

Prints ``name,us_per_call,derived`` CSV per table.  Paper-anchor rows embed
the paper's number and our delta.  Framework tables (roofline / planner)
read the dry-run artifacts if present (see src/repro/launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import sys

from .common import Table
from . import (fig6_baseline_pim, fig8_wavesim_opt, fig9_ssgemm_sparsity,
               fig10_push_cacheaware, headline)


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--fast", action="store_true",
                        help="skip the slower LRU-predictor tables")
    args = parser.parse_args()

    t = Table("Fig 6 — baseline PIM speedup vs GPU")
    fig6_baseline_pim.run(t)
    t.emit()

    t = Table("Fig 8 — wavesim: arch-aware activation x registers")
    fig8_wavesim_opt.run(t)
    t.emit()

    t = Table("Fig 9 — ss-gemm: sparsity-aware PIM")
    fig9_ssgemm_sparsity.run(t)
    t.emit()

    if not args.fast:
        t = Table("Fig 10 — push: cache-aware PIM + command bandwidth")
        fig10_push_cacheaware.run(t)
        t.emit()

        t = Table("Headline — average PIM speedup, baseline vs optimized")
        headline.run(t)
        t.emit()

        try:
            from . import serve_bench
            t = Table("Serving — per-token loop vs device engine vs "
                      "paged KV pool")
            serve_bench.run(t)
            t.emit()
        except Exception as exc:
            print(f"# serve bench skipped: {exc}", file=sys.stderr)

        from . import limit_studies
        t = Table("Limit studies — registers x command bandwidth (§5.1.4)")
        limit_studies.run(t)
        t.emit()

    # Framework-side tables are emitted if their inputs exist.
    try:
        from . import roofline_table
        roofline_table.main()
    except Exception as exc:  # dry-run artifacts may not exist yet
        print(f"# roofline table skipped: {exc}", file=sys.stderr)

    try:
        from . import kernel_bench
        kernel_bench.main()
    except Exception as exc:
        print(f"# kernel bench skipped: {exc}", file=sys.stderr)


if __name__ == "__main__":
    main()
