"""Bottleneck attribution, SLO monitor and flight recorder (PR 8).

The attribution contract is *exactness*: every request's TTFT and TPOT
decompositions must sum to the measured latency within float tolerance
(``RequestAttribution.check``), on plain runs and on the chaos run whose
preemptions exercise the recompute/requeue components.  The SLO monitor
must report deterministic attainment at generous/unmeetable targets and
stay vacuous when unconfigured.  The flight recorder must capture a
loadable debug bundle when a PageError escapes the run loop — with every
ring event at or before the failure round — while staying out of the
zero-overhead-off contract (no gauge wiring, no device syncs).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.attribution import (TPOT_COMPONENTS, TTFT_COMPONENTS,
                                     attribution_report, explain)
from repro.serve.chaos import ChaosInjector
from repro.serve.engine import ServeConfig
from repro.serve.kvpool import PageError
from repro.serve.scheduler import Batcher
from repro.serve.telemetry import Tracer


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=6, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, total_pages=10,
            admission_mode="optimistic")


def _requests(cfg, n=5, lo=8, hi=14, seed=1):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, cfg.vocab,
                             size=int(rng.integers(lo, hi))).tolist())
            for i in range(n)]


@pytest.fixture(scope="module")
def chaos_run(setup):
    """Traced preemption-heavy run: exhaustion at round 2, release at 5."""
    cfg, model, params = setup
    chaos = ChaosInjector(exhaust_at={2: 0}, release_at=(5,),
                          check_invariants=True)
    b = Batcher(model, params, ServeConfig(**BASE, telemetry=True),
                chaos=chaos)
    for rid, p in _requests(cfg):
        b.submit(rid, p)
    results = b.run(max_new=10)
    return results, b


# ---------------------------------------------------------------------------
# per-request attribution: exact partitions
# ---------------------------------------------------------------------------

def test_explain_components_sum_to_measured(chaos_run):
    results, b = chaos_run
    tr = b.telemetry
    explained = 0
    for rid in results:
        a = explain(tr, rid)
        assert a is not None, f"rid {rid} produced tokens but no explain"
        a.check(tol=1e-6)            # exact-partition contract
        assert set(a.ttft) == set(TTFT_COMPONENTS)
        assert set(a.tpot) == set(TPOT_COMPONENTS)
        explained += 1
    assert explained == len(results)


def test_explain_components_nonnegative(chaos_run):
    _, b = chaos_run
    for rid in b.telemetry.rids():
        a = explain(b.telemetry, rid)
        for comp, v in {**a.ttft, **a.tpot}.items():
            assert v >= -1e-9, f"rid {rid} {comp} negative: {v}"


def test_explain_preempted_request_pays_recompute(chaos_run):
    # at least one preempted request must show queue/recompute cost
    # somewhere (the forced exhaustion parks it mid-flight)
    _, b = chaos_run
    preempted = {e["rid"] for e in b.telemetry.events
                 if e["kind"] == "PREEMPT"}
    assert preempted
    costs = []
    for rid in preempted:
        a = explain(b.telemetry, rid)
        assert a.preemptions >= 1
        costs.append(a.ttft["queue_wait_s"]
                     + a.ttft["preempt_recompute_s"]
                     + a.tpot["preempt_recompute_s"]
                     + a.tpot["requeue_s"])
    assert max(costs) > 0.0


def test_explain_unknown_rid_is_none(chaos_run):
    _, b = chaos_run
    assert explain(b.telemetry, 999_999) is None


def test_explain_spec_run_carves_verify_overhead(setup):
    cfg, model, params = setup
    b = Batcher(model, params,
                ServeConfig(max_len=96, batch=4, dtype=jnp.float32,
                            sync_every=4, paged=True, page_size=8,
                            speculate_k=3, telemetry=True))
    tok = int(np.random.default_rng(0).integers(0, cfg.vocab))
    for rid in range(3):
        b.submit(rid, [tok] * 12)
    results = b.run(max_new=12)
    assert b.spec_steps > 0
    for rid in results:
        a = explain(b.telemetry, rid)
        a.check(tol=1e-6)
        # the drafter does not hit 100% acceptance on the whole run, so
        # some verify work was wasted — and it must stay a slice of (not
        # exceed) the decode-segment time it was carved from
        assert a.tpot["verify_overhead_s"] >= 0.0
        assert (a.tpot["verify_overhead_s"] + a.tpot["decode_segment_s"]
                <= a.decode_s + 1e-9)


# ---------------------------------------------------------------------------
# wave-level report
# ---------------------------------------------------------------------------

def test_attribution_report_shape_and_shares(chaos_run):
    results, b = chaos_run
    rep = attribution_report(b.telemetry)
    assert rep["requests"] == len(results)
    assert rep["dominant_ttft_component"] in TTFT_COMPONENTS
    assert rep["dominant_tpot_component"] in TPOT_COMPONENTS
    for section, comps in (("ttft", TTFT_COMPONENTS),
                           ("tpot", TPOT_COMPONENTS)):
        assert set(rep[section]) == set(comps)
        shares = sum(rep[section][c]["share"] for c in comps)
        assert shares == pytest.approx(1.0, abs=1e-6)
    # ranked: dominant component has the largest total
    dom = rep["dominant_ttft_component"]
    assert all(rep["ttft"][dom]["total_s"] >= rep["ttft"][c]["total_s"]
               for c in TTFT_COMPONENTS)
    # per-request entries sorted by descending TTFT, JSON-serializable
    ttfts = [r["ttft_s"] for r in rep["per_request"]]
    assert ttfts == sorted(ttfts, reverse=True)
    json.dumps(rep)


def test_attribution_report_empty_tracer():
    rep = attribution_report(Tracer())
    assert rep["requests"] == 0
    assert rep["dominant_ttft_component"] is None
    assert rep["per_request"] == []


# ---------------------------------------------------------------------------
# SLO monitor
# ---------------------------------------------------------------------------

def test_slo_disabled_is_vacuous(chaos_run):
    _, b = chaos_run
    s = b.slo_stats()
    assert s["enabled"] is False
    assert s["slo_attainment"] == 1.0
    assert s["classes"] == {}


def _slo_run(setup, **slo_kw):
    cfg, model, params = setup
    b = Batcher(model, params, ServeConfig(**BASE, **slo_kw))
    for (rid, p), prio in zip(_requests(cfg, n=4), (0, 0, 1, 1)):
        b.submit(rid, p, priority=prio)
    b.run(max_new=6)
    return b


def test_slo_generous_attains_everything(setup):
    b = _slo_run(setup, ttft_slo_s=3600.0, tpot_slo_s=3600.0)
    s = b.slo_stats()
    assert s["enabled"] is True
    assert s["slo_attainment"] == 1.0
    assert set(s["classes"]) == {0, 1}
    for cls in s["classes"].values():
        assert cls["ttft_attainment"] == 1.0
        assert cls["ttft_total"] > 0
    assert s["burn_rate_ttft"] == 0.0
    assert s["burn_rate_tpot"] == 0.0


def test_slo_unmeetable_attains_nothing(setup):
    b = _slo_run(setup, ttft_slo_s=1e-12, tpot_slo_s=1e-12,
                 slo_target=0.9)
    s = b.slo_stats()
    assert s["slo_attainment"] == 0.0
    # every recent sample violates: burn = 1.0 / (1 - 0.9) = 10x budget
    assert s["burn_rate_ttft"] == pytest.approx(10.0)


def test_slo_counters_survive_in_registry(setup):
    b = _slo_run(setup, ttft_slo_s=3600.0)
    m = b.metrics
    total = sum(m.value(f"slo.ttft_total.c{c}") for c in (0, 1))
    assert total == m.count("lat.ttft_s") == 4


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

class _PoolFault(ChaosInjector):
    """Raise a real allocator PageError at the first live round >= at."""

    def __init__(self, at=2):
        super().__init__()
        self.at = at
        self.fired = False

    def on_round(self, b):
        super().on_round(b)
        if not self.fired and b.round >= self.at and b.pool is not None:
            live = [i for i, rid in enumerate(b.slot_rid)
                    if rid is not None]
            if live:
                self.fired = True
                b.pool.reserve(live[0], 1)


def _crash_run(setup, tmp_path=None, **cfg_kw):
    cfg, model, params = setup
    path = str(tmp_path / "bundle.json") if tmp_path is not None else None
    b = Batcher(model, params,
                ServeConfig(**BASE, flight_path=path, **cfg_kw),
                chaos=_PoolFault())
    for rid, p in _requests(cfg, n=3):
        b.submit(rid, p)
    with pytest.raises(PageError):
        b.run(max_new=6)
    return b, path


def test_flight_bundle_on_page_error(setup, tmp_path):
    b, path = _crash_run(setup, tmp_path)
    bundle = b.last_flight_bundle
    assert bundle is not None
    assert bundle["schema"] == 1
    assert "PageError" in bundle["error"]
    assert bundle["events"], "ring captured nothing"
    # the ring holds the run *up to* the fault: nothing postdates it
    for e in bundle["events"]:
        assert e["round"] <= bundle["round"]
    # pool snapshot partitions cover every page exactly once
    pool = bundle["pool"]
    covered = (len(pool["free"]) + len(pool["cached"])
               + len(pool["preempted"]) + len(pool["held"])
               + sum(len(p) for p in pool["slot_pages"]))
    assert covered == pool["n_pages"]
    assert len(bundle["slot_table"]["slot_rid"]) == BASE["batch"]
    # the on-disk bundle is the same loadable JSON
    with open(path) as f:
        disk = json.load(f)
    assert disk["error"] == bundle["error"]
    assert disk["round"] == bundle["round"]
    json.dumps(bundle)


def test_flight_recorder_ring_is_bounded(setup, tmp_path):
    b, _ = _crash_run(setup, tmp_path, flight_events=4)
    assert len(b.last_flight_bundle["events"]) <= 4


def test_flight_recorder_opt_out(setup):
    b, _ = _crash_run(setup, flight_recorder=False)
    assert b.flight is None
    assert b.last_flight_bundle is None


def test_flight_recorder_does_not_break_off_contract(setup):
    # always-on flight ring must not wire gauges or perturb tokens:
    # the zero-overhead-off tests in test_telemetry cover parity; here
    # just pin the wiring invariants on a default Batcher
    cfg, model, params = setup
    b = Batcher(model, params, ServeConfig(**BASE))
    assert b.telemetry is None
    assert b.flight is not None          # recorder armed by default
    assert b.pool.gauge_cb is None       # but no per-mutation callback


def test_tracer_ring_mode_keeps_tail():
    tr = Tracer(ring=3)
    for i in range(10):
        tr.event("SUBMIT", i, round=i)
    tail = tr.tail()
    assert len(tail) == 3
    assert [e["rid"] for e in tail] == [7, 8, 9]
