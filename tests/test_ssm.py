"""Mamba2/SSD correctness: chunked algorithm vs naive recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssd_chunked, ssd_step


def naive_recurrence(xdt, a, b, c):
    """O(L) state recurrence oracle: h_t = exp(a_t) h_{t-1} + x_t B_t^T."""
    bs, l, h, p = xdt.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    state = np.zeros((bs, h, p, n))
    ys = np.zeros((bs, l, h, p))
    a = np.asarray(a, np.float64)
    for t in range(l):
        da = np.exp(a[:, t])                     # [B, H]
        bh = np.repeat(np.asarray(b)[:, t], hg, axis=1)   # [B, H, N]
        ch = np.repeat(np.asarray(c)[:, t], hg, axis=1)
        state = state * da[:, :, None, None] + \
            np.asarray(xdt)[:, t][..., None] * bh[:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, ch)
    return ys, state


def test_ssd_chunked_matches_recurrence():
    rng = np.random.default_rng(0)
    bs, l, h, p, g, n = 2, 32, 4, 8, 2, 16
    xdt = jnp.asarray(rng.standard_normal((bs, l, h, p)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((bs, l, h))) * 0.5,
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal((bs, l, g, n)) * 0.5, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bs, l, g, n)) * 0.5, jnp.float32)
    y, final = ssd_chunked(xdt, a, b, c, chunk=8)
    y_ref, final_ref = naive_recurrence(xdt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), final_ref, rtol=2e-3,
                               atol=2e-3)


def test_ssd_step_matches_chunked_tail():
    """Decode recurrence continues exactly from the chunked final state."""
    rng = np.random.default_rng(1)
    bs, l, h, p, g, n = 1, 16, 2, 4, 1, 8
    xdt = jnp.asarray(rng.standard_normal((bs, l + 1, h, p)) * 0.4,
                      jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((bs, l + 1, h))) * 0.4 + 0.1,
                     jnp.float32)
    a_neg = jnp.asarray(-np.abs(rng.standard_normal(h)) - 0.1, jnp.float32)
    a = dt * a_neg[None, None, :]
    b = jnp.asarray(rng.standard_normal((bs, l + 1, g, n)) * 0.4, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bs, l + 1, g, n)) * 0.4, jnp.float32)
    xdt_scaled = xdt * 1.0
    y_full, _ = ssd_chunked(xdt_scaled[:, :l + 1] * dt[..., None],
                            a[:, :l + 1], b[:, :l + 1], c[:, :l + 1],
                            chunk=4)
    _, state_l = ssd_chunked(xdt_scaled[:, :l] * dt[:, :l, :, None],
                             a[:, :l], b[:, :l], c[:, :l], chunk=4)
    new_state, y_step = ssd_step(state_l, xdt_scaled[:, l], dt[:, l], a_neg,
                                 b[:, l], c[:, l])
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, l]),
                               rtol=3e-3, atol=3e-3)
