"""Chunked prefill must be invisible in the tokens: admitting long prompts
in page-aligned chunks (PREFILLING slots frozen between chunks, decode
segments interleaved) produces bit-exact greedy output vs the unchunked
paged engine across every boundary case — chunk edges on page edges,
prompts shorter than one chunk, prefix-cache hits leaving a sub-chunk
suffix, EOS retiring one slot while another is mid-prefill — while the
join-latency stats prove the work was actually split."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig
from repro.serve.scheduler import Batcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=3, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, total_pages=36)


def _run(model, params, requests, max_new=10, eos_id=None, **kw):
    b = Batcher(model, params, ServeConfig(**{**BASE, **kw}), eos_id=eos_id)
    for rid, p in requests:
        b.submit(rid, p)
    return b.run(max_new=max_new), b


def _mixed_requests(cfg, sizes, seed=1, system=0):
    rng = np.random.default_rng(seed)
    sys_toks = rng.integers(0, cfg.vocab, size=system).tolist()
    return [(i, sys_toks + rng.integers(0, cfg.vocab, size=n).tolist())
            for i, n in enumerate(sizes)]


def _assert_parity(ref, got, requests):
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])


def _assert_drained(b):
    assert b.pool.used_pages == 0
    assert int(b.pool.refcount.sum()) == 0
    b.pool.check()


def test_chunked_parity_long_and_short_mixed(setup):
    """A 40-token prompt chunked 16 tokens at a time among short prompts:
    same tokens as the unchunked engine, more (smaller) joins."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [40, 5, 23, 4])
    ref, b0 = _run(model, params, requests)
    got, b1 = _run(model, params, requests, prefill_chunk=16)
    _assert_parity(ref, got, requests)
    assert b1.chunk_joins > 0
    assert b1.join_stats()["joins"] > b0.join_stats()["joins"]
    _assert_drained(b1)


def test_chunk_boundary_on_page_boundary(setup):
    """Chunk edges landing exactly on page edges (and a prompt that is an
    exact multiple of the chunk, so the last chunk is full-width): the
    final chunk commits with zero remainder."""
    cfg, model, params = setup
    # 32 = 2 chunks of 16 = 4 pages of 8 exactly; 48 = 3 chunks exactly
    requests = _mixed_requests(cfg, [32, 48, 16], seed=3)
    ref, _ = _run(model, params, requests)
    got, b = _run(model, params, requests, prefill_chunk=16)
    _assert_parity(ref, got, requests)
    assert b.chunk_joins > 0
    _assert_drained(b)


def test_prompt_shorter_than_one_chunk(setup):
    """Prompts below the chunk size commit on their first join — chunking
    is a no-op (no continuation rounds, same join count)."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [7, 3, 11, 5], seed=5)
    ref, b0 = _run(model, params, requests)
    got, b1 = _run(model, params, requests, prefill_chunk=16)
    _assert_parity(ref, got, requests)
    assert b1.chunk_joins == 0
    assert b1.join_stats()["joins"] == b0.join_stats()["joins"]


def test_prefix_hit_leaves_subchunk_suffix(setup):
    """A prefix-cache hit can shrink a long prompt's uncached suffix below
    one chunk: the hit rows commit immediately at their resumed depth
    while the cache still reports skipped prefill work."""
    cfg, model, params = setup
    # 24 shared tokens = 3 full pages; suffixes 2..9 tokens < chunk 16
    requests = _mixed_requests(cfg, [2, 9, 5, 3], seed=7, system=24)
    ref, _ = _run(model, params, requests)
    got, b = _run(model, params, requests, prefill_chunk=16,
                  prefix_cache=True)
    _assert_parity(ref, got, requests)
    s = b.prefix_stats()
    assert s["hits"] >= 3 and s["prefill_skipped"] > 0
    b.prefix.check()
    assert b.pool.used_pages == 0


def test_eos_mid_batch_while_other_slot_prefilling(setup):
    """A short request hits EOS and retires while the long prompt is
    still PREFILLING: the retirement frees pages at the segment edge, the
    frozen slot is untouched, and tokens match the unchunked engine."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [56, 4, 5], seed=9)
    free, _ = _run(model, params, requests, max_new=12)
    eos = free[1][2]          # a short request's early token as EOS
    ref, _ = _run(model, params, requests, max_new=12, eos_id=eos)
    assert any(len(v) < 12 for v in ref.values())
    got, b = _run(model, params, requests, max_new=12, eos_id=eos,
                  prefill_chunk=8)
    _assert_parity(ref, got, requests)
    assert b.chunk_joins > 0
    _assert_drained(b)


def test_chunked_kernel_route_matches_xla(setup):
    """Chunked suffix prefill through the Pallas flash-prefill kernel
    (interpret on CPU) changes no sampled ids vs the XLA gather path —
    the engine-level pin on the kernel's causal-at-depth math."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [21, 4], seed=13)
    got_x, _ = _run(model, params, requests, max_new=4, batch=2,
                    prefill_chunk=8, attn_mode="xla")
    got_k, _ = _run(model, params, requests, max_new=4, batch=2,
                    prefill_chunk=8, attn_mode="kernel")
    _assert_parity(got_x, got_k, requests)


def test_prefill_chunk_validation(setup):
    """Misconfigured chunking is rejected up front: non-paged engines,
    non-positive sizes, and chunks that straddle page boundaries."""
    cfg, model, params = setup
    with pytest.raises(ValueError, match="paged"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2,
                                           prefill_chunk=16))
    for bad in (0, -8, 12):     # 12 % page_size(8) != 0
        with pytest.raises(ValueError, match="prefill_chunk"):
            Batcher(model, params,
                    ServeConfig(max_len=32, batch=2, paged=True,
                                page_size=8, prefill_chunk=bad))
