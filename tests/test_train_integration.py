"""End-to-end integration: train loop learns, survives failures, and the
serve path generates; the planner renders; compression trains."""
import jax
import numpy as np
import pytest

from repro.launch.train import run as train_run
from repro.launch.serve import run as serve_run


def test_train_loss_decreases(tmp_path):
    out = train_run("qwen2-0.5b", steps=12, batch=4, seq=64, reduced=True,
                    lr=3e-3, log_every=100)
    losses = out["losses"]
    assert len(losses) == 12
    assert losses[-1] < losses[0] - 0.05, \
        f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_train_failure_recovery_deterministic(tmp_path):
    """A run with an injected failure + restore must end at the same loss
    as an uninterrupted run (checkpoint + deterministic data)."""
    kw = dict(steps=10, batch=2, seq=32, reduced=True, lr=1e-3,
              ckpt_every=5, log_every=100)
    clean = train_run("qwen2-0.5b", ckpt_dir=str(tmp_path / "a"), **kw)
    faulty = train_run("qwen2-0.5b", ckpt_dir=str(tmp_path / "b"),
                       fail_at=(7,), **kw)
    assert np.isclose(clean["final_loss"], faulty["final_loss"],
                      rtol=1e-4), (clean["final_loss"],
                                   faulty["final_loss"])


def test_train_with_grad_compression():
    out = train_run("qwen2-0.5b", steps=8, batch=2, seq=32, reduced=True,
                    lr=3e-3, compress_grads=True, log_every=100)
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < out["losses"][0]


def test_train_with_accumulation_matches_tokens():
    out1 = train_run("qwen2-0.5b", steps=6, batch=4, seq=32, reduced=True,
                     accum=1, lr=1e-3, log_every=100)
    out2 = train_run("qwen2-0.5b", steps=6, batch=4, seq=32, reduced=True,
                     accum=2, lr=1e-3, log_every=100)
    # same data, nearly the same optimization trajectory
    assert abs(out1["final_loss"] - out2["final_loss"]) < 0.1


def test_serve_generates():
    out = serve_run("qwen2-0.5b", reduced=True, requests=3, max_new=4,
                    batch=2, max_len=32)
    assert len(out["results"]) == 3
    assert all(len(v) == 4 for v in out["results"].values())


def test_planner_renders_all_archs():
    from repro.configs import ALL_ARCHS, get_config, shapes_for
    from repro.core.planner import plan, render
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            entries = plan(cfg, shape)
            assert entries, f"{arch} x {shape.name}: empty plan"
            text = render(cfg, shape)
            assert arch in text
    # decode attention must be flagged amenable/conditional for GQA archs
    from repro.configs.base import SHAPES
    from repro.core.amenability import Verdict
    entries = plan(get_config("internvl2-26b"), SHAPES["decode_32k"])
    decode_ops = [e for e in entries if "decode-attention" in e.op.name]
    assert decode_ops
    assert decode_ops[0].report.verdict in (Verdict.AMENABLE,
                                            Verdict.CONDITIONAL)
    assert decode_ops[0].est_pim_speedup > 1.5
