"""Decode-loop parity: the fused device-resident scan (slot scheduler,
per-slot lengths, device sampling) must produce token-for-token identical
output to the step-by-step reference loop — greedy, mixed prompt lengths,
EOS mid-batch, and across continuous-batching refills."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig
from repro.serve.reference import reference_decode
from repro.serve.scheduler import Batcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    rng = np.random.default_rng(1)
    requests = [(i, rng.integers(0, cfg.vocab, size=n).tolist())
                for i, n in enumerate([3, 5, 8, 11])]
    return cfg, model, params, requests


def _engine_run(model, params, scfg, requests, max_new, eos_id=None):
    b = Batcher(model, params, scfg, eos_id=eos_id)
    for rid, p in requests:
        b.submit(rid, p)
    return b.run(max_new=max_new)


def test_scan_parity_greedy_mixed_lengths(setup):
    """Fused scan == per-token reference, bit-exact token ids."""
    cfg, model, params, requests = setup
    scfg = ServeConfig(max_len=64, batch=4, dtype=jnp.float32, sync_every=4)
    ref = reference_decode(model, params, scfg, requests, max_new=12)
    got = _engine_run(model, params, scfg, requests, max_new=12)
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
        assert len(got[rid]) == 12


def test_scan_parity_across_refills(setup):
    """More requests than slots: per-request outputs are independent of the
    slot schedule (per-slot lengths isolate the rows)."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(7)
    requests = [(i, rng.integers(0, cfg.vocab,
                                 size=int(rng.integers(3, 12))).tolist())
                for i in range(7)]
    scfg = ServeConfig(max_len=64, batch=3, dtype=jnp.float32, sync_every=4)
    ref = reference_decode(model, params, scfg, requests, max_new=10)
    got = _engine_run(model, params, scfg, requests, max_new=10)
    assert set(got) == {rid for rid, _ in requests}
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])


def test_eos_mid_batch_retires_slot(setup):
    """Pick a token one request emits mid-stream as EOS: that slot retires
    early (EOS kept), the others run to budget — identical to reference."""
    cfg, model, params, requests = setup
    scfg = ServeConfig(max_len=64, batch=4, dtype=jnp.float32, sync_every=4)
    free = reference_decode(model, params, scfg, requests, max_new=12)
    eos = free[requests[0][0]][4]     # token request 0 emits at step 4
    ref = reference_decode(model, params, scfg, requests, max_new=12,
                           eos_id=eos)
    got = _engine_run(model, params, scfg, requests, max_new=12, eos_id=eos)
    assert any(len(v) < 12 for v in ref.values())          # actually mid-batch
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
        if ref[rid][-1] == eos or len(ref[rid]) < 12:
            assert got[rid][-1] == eos                     # EOS is kept


def test_kernel_route_matches_xla(setup):
    """Routing decode attention through the Pallas kernel (interpret on
    CPU) changes nothing about the sampled ids."""
    cfg, model, params, requests = setup
    base = dict(max_len=64, batch=4, dtype=jnp.float32, sync_every=4)
    got_x = _engine_run(model, params,
                        ServeConfig(**base, attn_mode="xla"),
                        requests, max_new=8)
    got_k = _engine_run(model, params,
                        ServeConfig(**base, attn_mode="kernel"),
                        requests, max_new=8)
    for rid, _ in requests:
        assert got_x[rid] == got_k[rid], (rid, got_x[rid], got_k[rid])


def test_temperature_sampling_runs(setup):
    """Non-greedy path: on-device categorical sampling yields in-vocab ids
    for every requested token."""
    cfg, model, params, requests = setup
    scfg = ServeConfig(max_len=64, batch=4, dtype=jnp.float32,
                       sync_every=4, temperature=0.8)
    got = _engine_run(model, params, scfg, requests, max_new=6)
    for rid, _ in requests:
        assert len(got[rid]) == 6
        assert all(0 <= t < cfg.vocab for t in got[rid])


def test_per_slot_lengths_and_grid_pruning():
    """decode_attn with per-slot lengths == oracle, with and without the
    statically pruned KV grid (s_cap)."""
    from repro.kernels.decode_attn import decode_attn
    from repro.kernels.decode_attn.ref import decode_attn_ref
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 4, 512, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    lengths = jnp.asarray([1, 64, 129, 200], jnp.int32)
    ref = decode_attn_ref(q, k, v, lengths)
    out = decode_attn(q, k, v, lengths, bs=64)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)
    # dead blocks past every slot's length pruned from the grid entirely
    capped = decode_attn(q, k, v, lengths, bs=64, s_cap=256)
    np.testing.assert_allclose(capped, ref, rtol=3e-4, atol=3e-4)
