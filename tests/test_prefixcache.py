"""Shared-prefix radix cache: page-aligned matching (with the one-token
suffix cap), insert/dedup semantics, the evictable-cached lifecycle over
the pool, and LRU leaf-first eviction order."""
import pytest

from repro.serve.kvpool import KVPool, PageError
from repro.serve.prefixcache import PrefixCache


def _pool(n_pages=16, page_size=4, slots=4):
    pool = KVPool(n_pages, page_size, slots)
    return pool, PrefixCache(pool)


def test_match_is_page_aligned_and_caps_suffix():
    pool, cache = _pool(page_size=4)
    toks = list(range(100, 110))                 # 10 tokens = 2.5 pages
    pages = pool.reserve(0, len(toks) + 4)
    cache.insert(toks[:8], pages[:2])            # the 2 full prompt pages
    # a longer prompt with the same prefix matches both pages
    got, n = cache.match(toks + [1, 2, 3])
    assert got == pages[:2] and n == 8
    # a 7-token prompt still shares its one full page and prefills 3
    assert cache.match(toks[:7]) == (pages[:1], 4)
    # prompts no longer than one page can never match (the whole prompt
    # would be prefix — nothing left to prefill)
    assert cache.match(toks[:4]) == ([], 0)
    # an exactly-2-page prompt matches only 1 page: at least one token
    # must remain as suffix to produce next-token logits
    got, n = cache.match(toks[:8])
    assert got == pages[:1] and n == 4
    # diverging tokens stop the walk at the split point
    got, n = cache.match(toks[:4] + [0, 0, 0, 0, 9])
    assert got == pages[:1] and n == 4


def test_insert_keeps_existing_entries():
    pool, cache = _pool()
    t = list(range(8))
    a = pool.reserve(0, 8)
    b = pool.reserve(1, 8)
    assert cache.insert(t, a[:2]) == 2
    # a duplicate prompt registers nothing: the first writer wins and the
    # second request's pages stay private (freed normally at retirement)
    assert cache.insert(t, b[:2]) == 0
    assert cache.match(t + [9]) == (a[:2], 8)
    assert cache.n_entries == 2
    cache.check()


def test_insert_rejects_reregistered_page():
    pool, cache = _pool()
    pages = pool.reserve(0, 8)
    cache.insert(list(range(8)), pages[:2])
    with pytest.raises(PageError, match="already registered"):
        cache.insert(list(range(50, 58)), pages[:2])


def test_retire_parks_cached_then_match_revives():
    pool, cache = _pool(n_pages=8, page_size=4)
    t = list(range(12))
    pages = pool.reserve(0, 12)
    cache.insert(t[:8], pages[:2])
    pool.release(0, cacheable=cache.registered_pages(pages))
    assert pool.cached_pages == 2 and pool.free_pages == 6
    cache.check()
    # the cached chain still matches; sharing it revives the pages
    got, n = cache.match(t)
    assert got == pages[:2] and n == 8
    pool.share(1, got)
    assert pool.cached_pages == 0
    assert (pool.refcount[got] == 1).all()
    cache.check()


def test_evict_is_lru_and_leaf_first():
    pool, cache = _pool(n_pages=12, page_size=2, slots=4)
    old = [9, 9, 8, 8]                           # chain A: 2 pages
    new = [7, 7, 6, 6]                           # chain B: 2 pages
    pa = pool.reserve(0, 4)
    cache.insert(old, pa)
    pb = pool.reserve(1, 4)
    cache.insert(new, pb)
    cache.match(old + [1])                       # touch A: now most recent
    pool.release(0, cacheable=frozenset(pa))
    pool.release(1, cacheable=frozenset(pb))
    # evicting one page drops B's leaf (LRU chain), not A's
    assert cache.evict(1) == 1
    assert cache.match(old + [1])[1] == 4        # A fully intact
    assert cache.match(new + [1])[1] == 2        # B peeled from the deep end
    cache.check()
    # those matches were uses: B's root is now the most recent chain, so
    # the next leaf-first cascade peels A (leaf, then its exposed root)
    assert cache.evict(2) == 2
    assert cache.match(old + [1]) == ([], 0)
    assert cache.match(new + [1])[1] == 2
    assert cache.evicted_pages == 3
    cache.check()


def test_evict_skips_mapped_pages():
    pool, cache = _pool(n_pages=8, page_size=2, slots=2)
    t = [5, 5, 4, 4]
    pages = pool.reserve(0, 4)
    cache.insert(t, pages)
    # slot 0 is still live: nothing is evictable
    assert cache.evict(4) == 0
    assert cache.n_entries == 2
    pool.release(0, cacheable=cache.registered_pages(pages))
    assert cache.evict(4) == 2
    assert pool.free_pages == pool.n_pages
    cache.check()


def test_pool_pressure_drives_eviction_through_alloc():
    """reserve/extend under a full pool reclaim cached pages on demand —
    the prefix cache reserves zero capacity."""
    pool, cache = _pool(n_pages=4, page_size=2, slots=2)
    t = list(range(8))
    pages = pool.reserve(0, 8)                   # whole pool
    cache.insert(t, pages)
    pool.release(0, cacheable=cache.registered_pages(pages))
    assert pool.free_pages == 0 and pool.cached_pages == 4
    got = pool.reserve(1, 6)                     # forces 3 evictions
    assert len(got) == 3
    assert cache.evicted_pages == 3
    assert cache.n_entries == 1
    cache.check()
