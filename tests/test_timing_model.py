"""Unit tests for the pim-command IR + DRAM timing engine."""
import pytest

from repro.core.commands import Kind, Loop, Seg, Subset, total_by_kind, total_commands
from repro.core.hwspec import PimSpec
from repro.core.optimizations import Phase, arch_aware_schedule, baseline_schedule
from repro.core.timing import simulate

PIM = PimSpec()


def test_spec_bandwidth_identities():
    assert abs(PIM.regular_bytes_per_ns_per_pch * PIM.pch_per_stack
               - 614.4) < 1e-6
    assert abs(PIM.pim_peak_gbps - 4 * 614.4) < 1e-6
    assert PIM.cols_per_row == 32
    assert PIM.banks_per_subset == 8


def test_command_counting():
    stream = [Loop((Seg(Kind.ACT, Subset.ALL),
                    Seg(Kind.PIM_BCAST, Subset.EVEN, 8),
                    Seg(Kind.PIM_BCAST, Subset.ODD, 8)), 10)]
    assert total_commands(stream) == 170
    by = total_by_kind(stream)
    assert by[Kind.ACT] == 10 and by[Kind.PIM_BCAST] == 160


def test_bcast_issue_rate():
    """Pure compute stream runs at one command per tCCDL."""
    st = simulate([Seg(Kind.PIM_BCAST, Subset.EVEN, 100)], PIM)
    assert st.time_ns == pytest.approx(100 * PIM.t_ccdl_ns, rel=1e-6)


def test_activation_blocks_compute():
    st = simulate([Seg(Kind.ACT, Subset.EVEN),
                   Seg(Kind.PIM_BCAST, Subset.EVEN, 1)], PIM)
    # row ready tRP+tRCD after the ACT's slot, then one command
    assert st.time_ns >= PIM.row_switch_ns
    assert st.act_stall_ns > 0


def test_opposite_subset_not_blocked():
    """Compute on ODD proceeds while EVEN activates (the §5.1.1 overlap)."""
    st = simulate([Seg(Kind.ACT, Subset.EVEN),
                   Seg(Kind.PIM_BCAST, Subset.ODD, 20)], PIM)
    assert st.act_stall_ns == 0.0


def test_arch_aware_beats_baseline():
    phases = [Phase(8), Phase(8), Phase(8)]
    base = simulate(baseline_schedule(phases, 200), PIM)
    opt = simulate(arch_aware_schedule(phases, 200), PIM)
    assert opt.time_ns < base.time_ns
    assert opt.act_stall_frac < base.act_stall_frac


def test_arch_aware_gain_needs_commands_per_phase():
    """Short phases can't hide activation latency (the flux@16regs story)."""
    short = [Phase(2)] * 6
    long_ = [Phase(24)] * 6
    gain_short = (simulate(baseline_schedule(short, 500), PIM).time_ns
                  / simulate(arch_aware_schedule(short, 500), PIM).time_ns)
    gain_long = (simulate(baseline_schedule(long_, 500), PIM).time_ns
                 / simulate(arch_aware_schedule(long_, 500), PIM).time_ns)
    assert gain_long > gain_short


def test_loop_steady_state_matches_unrolled():
    body = (Seg(Kind.ACT, Subset.ALL), Seg(Kind.PIM_BCAST, Subset.EVEN, 8),
            Seg(Kind.PIM_BCAST, Subset.ODD, 8))
    looped = simulate([Loop(body, 50)], PIM)
    unrolled = simulate(list(body) * 50, PIM)
    assert looped.time_ns == pytest.approx(unrolled.time_ns, rel=1e-9)
    assert looped.n_cmds == unrolled.n_cmds


def test_single_bank_command_bus_bound():
    """push-style: 2 cmds/update, one data-less -> command-bus limited."""
    segs = [Seg(Kind.PIM_SB, Subset.ALL, 1000, carries_data=True,
                row_hit_frac=0.9),
            Seg(Kind.PIM_SB, Subset.ALL, 1000, carries_data=False,
                row_hit_frac=1.0)]
    st = simulate(segs, PIM)
    assert st.time_ns == pytest.approx(2000 * PIM.t_ccds_ns, rel=1e-6)
    # 4x command bandwidth -> data bus becomes the limit
    pim4 = PimSpec(command_bw_mult=4.0)
    st4 = simulate(segs, pim4)
    assert st4.time_ns == pytest.approx(1000 * PIM.t_ccds_ns, rel=1e-6)
    assert st4.time_ns < st.time_ns


def test_single_bank_activation_bound():
    """Row-missing scattered updates become activation-throughput bound."""
    seg = [Seg(Kind.PIM_SB, Subset.ALL, 1000, carries_data=True,
               row_hit_frac=0.0)]
    st = simulate(seg, PimSpec(command_bw_mult=4.0))
    expect = 1000 * PIM.row_cycle_ns / PIM.banks_per_pch
    assert st.time_ns == pytest.approx(expect, rel=1e-6)
