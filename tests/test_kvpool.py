"""Block-pool allocator invariants: exact free-page accounting, no double
free, page-table/cache-length consistency — unit tests always, randomized
admit/retire/refill sequences when hypothesis is installed."""
import numpy as np
import pytest

from repro.serve.kvpool import KVPool, PageError


# --------------------------------------------------------------------------
# plain unit tests (no optional deps)
# --------------------------------------------------------------------------

def test_reserve_release_roundtrip():
    pool = KVPool(n_pages=8, page_size=4, slots=2)
    pages = pool.reserve(0, 10)          # ceil(10/4) = 3 pages
    assert len(pages) == 3
    assert pool.free_pages == 5
    assert list(pool.table[0, :3]) == pages
    assert (pool.table[0, 3:] == pool.sentinel).all()
    pool.check()
    assert pool.release(0) == 3
    assert pool.free_pages == 8
    assert (pool.table[0] == pool.sentinel).all()
    pool.check()


def test_release_empty_slot_is_noop():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    assert pool.release(1) == 0
    assert pool.free_pages == 4


def test_exhaustion_and_admission():
    pool = KVPool(n_pages=4, page_size=2, slots=4)
    assert pool.can_admit(8) and not pool.can_admit(9)
    pool.reserve(0, 6)                   # 3 pages
    assert pool.can_admit(2) and not pool.can_admit(3)
    with pytest.raises(PageError):
        pool.reserve(1, 4)               # needs 2, only 1 free
    pool.release(0)
    pool.reserve(1, 8)                   # all 4 pages
    assert pool.free_pages == 0


def test_double_reserve_same_slot_rejected():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 2)
    with pytest.raises(PageError, match="already holds"):
        pool.reserve(0, 2)


def test_max_pages_bounds_one_slot():
    pool = KVPool(n_pages=16, page_size=2, slots=2, max_pages=4)
    with pytest.raises(PageError, match="max_pages"):
        pool.reserve(0, 9)               # 5 pages > max_pages 4
    assert not pool.can_admit(9)
    assert pool.can_admit(8)


def test_refcount_guards_double_free():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 4)
    # simulate corruption: a second slot aliasing the pages without refs
    pool._slot_pages[1] = list(pool._slot_pages[0])
    pool.table[1, :2] = pool.table[0, :2]
    pool.release(0)
    with pytest.raises(PageError, match="double free"):
        pool.release(1)


def test_utilization():
    pool = KVPool(n_pages=8, page_size=4, slots=2)
    assert pool.utilization(0) == 0.0
    pool.reserve(0, 10)                  # 3 pages = 12-token capacity
    assert pool.utilization(10) == pytest.approx(10 / 12)


# --------------------------------------------------------------------------
# property tests (optional dep — only these skip when hypothesis is absent,
# the unit tests above always run)
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

    def _identity_deco(*a, **kw):
        return lambda f: f
    given = settings = _identity_deco

    class st:  # noqa: N801 - stand-in so strategy expressions still parse
        data = integers = booleans = sampled_from = staticmethod(
            lambda *a, **kw: None)


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_random_admit_retire_sequences(data):
    """Random admit/retire/refill traffic never double-frees, always
    accounts pages exactly, and keeps every table row consistent with its
    slot's reservation (the device-side cache_len bound)."""
    n_pages = data.draw(st.integers(2, 24), label="n_pages")
    page_size = data.draw(st.integers(1, 8), label="page_size")
    slots = data.draw(st.integers(1, 6), label="slots")
    pool = KVPool(n_pages, page_size, slots)
    held: dict[int, int] = {}            # slot -> tokens reserved
    for _ in range(data.draw(st.integers(1, 40), label="ops")):
        if held and data.draw(st.booleans(), label="retire?"):
            slot = data.draw(st.sampled_from(sorted(held)), label="slot_r")
            tokens = held.pop(slot)
            assert pool.release(slot) == pool.pages_for(tokens)
        else:
            free_slots = [s for s in range(slots) if s not in held]
            if not free_slots:
                continue
            slot = data.draw(st.sampled_from(free_slots), label="slot_a")
            tokens = data.draw(st.integers(1, n_pages * page_size),
                               label="tokens")
            if pool.can_admit(tokens):
                pages = pool.reserve(slot, tokens)
                assert len(pages) == pool.pages_for(tokens)
                held[slot] = tokens
            else:
                with pytest.raises(PageError):
                    pool.reserve(slot, tokens)
        # exact accounting after every op
        mapped = sum(pool.pages_for(t) for t in held.values())
        assert pool.free_pages == n_pages - mapped
        assert pool.used_pages == mapped
        assert int(pool.refcount.sum()) == mapped
        pool.check()
        # table/cache_len consistency: every position a slot's tokens can
        # reach maps to a real page; everything past it is sentinel
        for slot, tokens in held.items():
            need = pool.pages_for(tokens)
            row = pool.table[slot]
            assert (row[:need] < n_pages).all()
            assert (row[need:] == pool.sentinel).all()
            assert len(set(row[:need])) == need      # no aliased pages
    # drain: everything comes back exactly once
    for slot in list(held):
        pool.release(slot)
    assert pool.free_pages == n_pages
    assert int(pool.refcount.sum()) == 0
    assert (np.asarray(pool.table) == pool.sentinel).all()
