"""Block-pool allocator invariants: exact free-page accounting, no double
free, page-table/cache-length consistency — unit tests always, randomized
admit/retire/refill sequences when hypothesis is installed."""
import numpy as np
import pytest

from repro.serve.kvpool import KVPool, PageError


# --------------------------------------------------------------------------
# plain unit tests (no optional deps)
# --------------------------------------------------------------------------

def test_reserve_release_roundtrip():
    pool = KVPool(n_pages=8, page_size=4, slots=2)
    pages = pool.reserve(0, 10)          # ceil(10/4) = 3 pages
    assert len(pages) == 3
    assert pool.free_pages == 5
    assert list(pool.table[0, :3]) == pages
    assert (pool.table[0, 3:] == pool.sentinel).all()
    pool.check()
    assert pool.release(0) == 3
    assert pool.free_pages == 8
    assert (pool.table[0] == pool.sentinel).all()
    pool.check()


def test_release_empty_slot_is_noop():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    assert pool.release(1) == 0
    assert pool.free_pages == 4


def test_exhaustion_and_admission():
    pool = KVPool(n_pages=4, page_size=2, slots=4)
    assert pool.can_admit(8) and not pool.can_admit(9)
    pool.reserve(0, 6)                   # 3 pages
    assert pool.can_admit(2) and not pool.can_admit(3)
    with pytest.raises(PageError):
        pool.reserve(1, 4)               # needs 2, only 1 free
    pool.release(0)
    pool.reserve(1, 8)                   # all 4 pages
    assert pool.free_pages == 0


def test_double_reserve_same_slot_rejected():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 2)
    with pytest.raises(PageError, match="already holds"):
        pool.reserve(0, 2)


def test_reserve_zero_tokens_rejected():
    """A zero-token reservation used to map zero pages and leave the slot
    indistinguishable from unreserved (a second reserve on it succeeded).
    It is now a hard allocator error."""
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    with pytest.raises(PageError, match="zero-token"):
        pool.reserve(0, 0)
    with pytest.raises(PageError, match="zero-token"):
        pool.reserve(0, -3)
    # the failed reserve left no trace: a real one still works
    assert pool.free_pages == 4
    pool.reserve(0, 2)
    pool.check()


def test_max_pages_bounds_one_slot():
    pool = KVPool(n_pages=16, page_size=2, slots=2, max_pages=4)
    with pytest.raises(PageError, match="max_pages"):
        pool.reserve(0, 9)               # 5 pages > max_pages 4
    assert not pool.can_admit(9)
    assert pool.can_admit(8)


def test_refcount_guards_double_free():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 4)
    # simulate corruption: a second slot aliasing the pages without refs
    pool._slot_pages[1] = list(pool._slot_pages[0])
    pool.table[1, :2] = pool.table[0, :2]
    pool.release(0)
    with pytest.raises(PageError, match="double free"):
        pool.release(1)


def test_utilization():
    pool = KVPool(n_pages=8, page_size=4, slots=2)
    assert pool.utilization(0) == 0.0
    pool.reserve(0, 10)                  # 3 pages = 12-token capacity
    assert pool.utilization(10) == pytest.approx(10 / 12)


# --------------------------------------------------------------------------
# prefix sharing: share / extend / evictable-cached lifecycle
# --------------------------------------------------------------------------

def test_share_takes_refcount_above_one():
    pool = KVPool(n_pages=8, page_size=4, slots=3)
    prefix = pool.reserve(0, 8)          # 2 pages
    pool.share(1, prefix)
    pool.extend(1, 2)
    assert (pool.refcount[prefix] == 2).all()
    assert pool.slot_pages(1)[:2] == prefix
    assert pool.free_pages == 8 - 4      # 2 shared (counted once) + 2 new
    pool.check()
    # releasing one holder keeps the shared pages mapped for the other
    pool.release(0)
    assert (pool.refcount[prefix] == 1).all()
    pool.check()


def test_release_cacheable_parks_pages_evictable():
    pool = KVPool(n_pages=6, page_size=4, slots=2)
    pages = pool.reserve(0, 12)          # 3 pages
    cacheable = frozenset(pages[:2])     # "registered" prefix pages
    freed = pool.release(0, cacheable=cacheable)
    assert freed == 1                    # only the private page is freed
    assert pool.cached_pages == 2
    assert pool.free_pages == 4
    assert pool.used_pages == 0          # cached pages cost no capacity
    assert int(pool.refcount.sum()) == 0
    pool.check()
    # revival: sharing a cached page maps it straight back (refcount 1)
    pool.share(1, pages[:2])
    assert pool.cached_pages == 0
    assert (pool.refcount[pages[:2]] == 1).all()
    pool.check()


def test_reclaim_and_share_of_free_page_rejected():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pages = pool.reserve(0, 4)
    pool.release(0, cacheable=frozenset(pages))
    pool.reclaim(pages[0])
    assert pool.free_pages == 3 and pool.cached_pages == 1
    with pytest.raises(PageError, match="non-cached"):
        pool.reclaim(pages[0])           # already reclaimed
    with pytest.raises(PageError, match="free"):
        pool.share(1, [pages[0]])        # free pages' KV is gone
    pool.check()


def test_can_admit_counts_cached_and_shared_pages():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pages = pool.reserve(0, 8)           # whole pool
    pool.release(0, cacheable=frozenset(pages))
    assert pool.free_pages == 0 and pool.cached_pages == 4
    # cached pages are available capacity (evicted on demand) ...
    assert pool.can_admit(8)
    # ... and shared prefix pages need no fresh allocation at all
    assert pool.can_admit(8, shared_pages=pages)
    # but a matched prefix does not double-count as evictable capacity:
    # 8 tokens need 4 pages, 2 shared -> 2 fresh, only 2 cached left
    assert pool.can_admit(8, shared_pages=pages[:2])
    assert not pool.can_admit(10, shared_pages=pages[:2])


def test_alloc_pressure_calls_evictor():
    class DropOldest:
        def __init__(self, pool):
            self.pool = pool
            self.calls = 0

        def evict(self, n):
            self.calls += 1
            for p in self.pool.cached_page_ids()[:n]:
                self.pool.reclaim(p)

    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.evictor = DropOldest(pool)
    pages = pool.reserve(0, 8)
    pool.release(0, cacheable=frozenset(pages))
    assert pool.free_pages == 0
    got = pool.reserve(1, 6)             # needs 3: all must come via evict
    assert len(got) == 3
    assert pool.evictor.calls == 1
    assert pool.cached_pages == 1
    pool.check()


def test_extend_validates_bounds():
    pool = KVPool(n_pages=8, page_size=2, slots=2, max_pages=3)
    pool.reserve(0, 4)                   # 2 pages
    with pytest.raises(PageError, match="zero-page"):
        pool.extend(0, 0)
    with pytest.raises(PageError, match="max_pages"):
        pool.extend(0, 2)                # 2 + 2 > 3
    pool.extend(0, 1)
    assert len(pool.slot_pages(0)) == 3
    pool.check()


# --------------------------------------------------------------------------
# preemption partition: release(preempt=True), reclaim-first alloc, chaos
# holds, and the refcount-conservation / snapshot debuggability checks
# --------------------------------------------------------------------------

def test_release_preempt_parks_pages_and_alloc_reclaims_them():
    pool = KVPool(n_pages=6, page_size=4, slots=2)
    pool.reserve(0, 12)                  # 3 pages
    assert pool.release(0, preempt=True) == 3
    assert pool.preempted_pages == 3 and pool.free_pages == 3
    assert pool.used_pages == 0          # preempted pages cost no capacity
    pool.check()
    # preempted pages are admission capacity (their KV is dead) ...
    assert pool.can_admit(24)
    # ... and a reservation larger than the free list reclaims them
    # before raising
    assert len(pool.reserve(1, 24)) == 6
    assert pool.preempted_pages == 0
    pool.check()


def test_release_preempt_keeps_registered_pages_cached():
    """Preemption parks only *dead* pages: registered prefix pages still
    go to the evictable cached state, where a resume can match them."""
    pool = KVPool(n_pages=6, page_size=4, slots=2)
    pages = pool.reserve(0, 12)
    assert pool.release(0, cacheable=frozenset(pages[:2]),
                        preempt=True) == 1
    assert pool.cached_pages == 2 and pool.preempted_pages == 1
    pool.check()


def test_release_preempt_respects_shared_refcounts():
    pool = KVPool(n_pages=8, page_size=4, slots=2)
    prefix = pool.reserve(0, 8)
    pool.share(1, prefix)
    assert pool.release(0, preempt=True) == 0
    assert pool.preempted_pages == 0     # still mapped under slot 1
    assert (pool.refcount[prefix] == 1).all()
    pool.check()


def test_hold_and_release_held():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 2)                   # 1 page mapped
    assert len(pool.hold(2)) == 2
    assert pool.held_pages == 2 and pool.free_pages == 1
    pool.check()
    # held pages are NOT admission capacity (unlike preempted ones)
    assert pool.can_admit(2) and not pool.can_admit(4)
    with pytest.raises(PageError):
        pool.reserve(1, 6)               # 3 pages, only 1 reachable
    assert pool.release_held() == 2
    assert pool.held_pages == 0 and pool.free_pages == 3
    pool.check()


def test_hold_raids_free_list_only():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 6)                   # 3 pages mapped
    assert len(pool.hold(10)) == 1       # free list had just one page
    assert pool.used_pages == 3          # live slot untouched
    pool.check()


def test_check_catches_refcount_conservation_drift():
    """A stray refcount on a mapped page must trip the conservation
    check even though the page itself is legitimately mapped."""
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pool.reserve(0, 4)
    pool.refcount[pool.slot_pages(0)[0]] += 1    # phantom reference
    with pytest.raises(PageError):
        pool.check()


def test_check_catches_partition_overlap():
    pool = KVPool(n_pages=4, page_size=2, slots=2)
    pages = pool.reserve(0, 4)
    pool.release(0, preempt=True)
    pool._cached.add(pages[0])           # corrupt: preempted AND cached
    with pytest.raises(PageError, match="both"):
        pool.check()


def test_page_errors_include_slot_snapshot():
    pool = KVPool(n_pages=2, page_size=2, slots=2)
    pool.reserve(0, 4)
    with pytest.raises(PageError, match=r"slot 0 pages=\["):
        pool.reserve(0, 2)               # double reserve: snapshot shows
    with pytest.raises(PageError, match=r"free, 2 mapped"):
        pool.extend(0, 1)                # exhausted: pool totals shown


# --------------------------------------------------------------------------
# property tests (optional dep — only these skip when hypothesis is absent,
# the unit tests above always run)
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

    def _identity_deco(*a, **kw):
        return lambda f: f
    given = settings = _identity_deco

    class st:  # noqa: N801 - stand-in so strategy expressions still parse
        data = integers = booleans = sampled_from = staticmethod(
            lambda *a, **kw: None)


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_random_admit_retire_sequences(data):
    """Random admit/retire/refill traffic never double-frees, always
    accounts pages exactly, and keeps every table row consistent with its
    slot's reservation (the device-side cache_len bound)."""
    n_pages = data.draw(st.integers(2, 24), label="n_pages")
    page_size = data.draw(st.integers(1, 8), label="page_size")
    slots = data.draw(st.integers(1, 6), label="slots")
    pool = KVPool(n_pages, page_size, slots)
    held: dict[int, int] = {}            # slot -> tokens reserved
    for _ in range(data.draw(st.integers(1, 40), label="ops")):
        if held and data.draw(st.booleans(), label="retire?"):
            slot = data.draw(st.sampled_from(sorted(held)), label="slot_r")
            tokens = held.pop(slot)
            assert pool.release(slot) == pool.pages_for(tokens)
        else:
            free_slots = [s for s in range(slots) if s not in held]
            if not free_slots:
                continue
            slot = data.draw(st.sampled_from(free_slots), label="slot_a")
            tokens = data.draw(st.integers(1, n_pages * page_size),
                               label="tokens")
            if pool.can_admit(tokens):
                pages = pool.reserve(slot, tokens)
                assert len(pages) == pool.pages_for(tokens)
                held[slot] = tokens
            else:
                with pytest.raises(PageError):
                    pool.reserve(slot, tokens)
        # exact accounting after every op
        mapped = sum(pool.pages_for(t) for t in held.values())
        assert pool.free_pages == n_pages - mapped
        assert pool.used_pages == mapped
        assert int(pool.refcount.sum()) == mapped
        pool.check()
        # table/cache_len consistency: every position a slot's tokens can
        # reach maps to a real page; everything past it is sentinel
        for slot, tokens in held.items():
            need = pool.pages_for(tokens)
            row = pool.table[slot]
            assert (row[:need] < n_pages).all()
            assert (row[need:] == pool.sentinel).all()
            assert len(set(row[:need])) == need      # no aliased pages
    # drain: everything comes back exactly once
    for slot in list(held):
        pool.release(slot)
    assert pool.free_pages == n_pages
    assert int(pool.refcount.sum()) == 0
    assert (np.asarray(pool.table) == pool.sentinel).all()


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_random_share_cache_evict_sequences(data):
    """Prefix-sharing traffic: random admit (fresh or sharing another
    slot's prefix), cacheable retire, and eviction under pressure keep the
    free/mapped/cached partition exact and ``check()`` green at every
    step.  Models the scheduler's use of share/extend/release(cacheable)/
    reclaim without the radix policy layer."""

    class DropOldest:                    # stand-in evictor (LRU-agnostic)
        def __init__(self, pool):
            self.pool = pool

        def evict(self, n):
            for p in self.pool.cached_page_ids()[:n]:
                self.pool.reclaim(p)

    n_pages = data.draw(st.integers(4, 24), label="n_pages")
    page_size = data.draw(st.integers(1, 8), label="page_size")
    slots = data.draw(st.integers(2, 6), label="slots")
    pool = KVPool(n_pages, page_size, slots)
    pool.evictor = DropOldest(pool)
    held: dict[int, list[int]] = {}      # slot -> pages mapped
    sticky: set[int] = set()             # pages flagged cacheable-on-release
    for _ in range(data.draw(st.integers(1, 40), label="ops")):
        op = data.draw(st.sampled_from(["admit", "share", "retire"]),
                       label="op")
        free_slots = [s for s in range(slots) if s not in held]
        if op == "retire" and held:
            slot = data.draw(st.sampled_from(sorted(held)), label="slot_r")
            pages = held.pop(slot)
            pool.release(slot, cacheable=sticky)
        elif op == "admit" and free_slots:
            slot = data.draw(st.sampled_from(free_slots), label="slot_a")
            tokens = data.draw(st.integers(1, n_pages * page_size),
                               label="tokens")
            if pool.can_admit(tokens):
                pages = pool.reserve(slot, tokens)
                held[slot] = pages
                if data.draw(st.booleans(), label="stick?"):
                    sticky.update(pages[:max(1, len(pages) // 2)])
        elif op == "share" and free_slots and held:
            donor = data.draw(st.sampled_from(sorted(held)), label="donor")
            slot = data.draw(st.sampled_from(free_slots), label="slot_s")
            prefix = held[donor][:data.draw(
                st.integers(1, len(held[donor])), label="depth")]
            extra = data.draw(st.integers(0, 2), label="extra")
            if len(prefix) + extra <= pool.max_pages and (
                    extra == 0 or pool.free_pages + pool.cached_pages
                    >= extra):
                pool.share(slot, prefix)
                if extra:
                    pool.extend(slot, extra)
                held[slot] = pool.slot_pages(slot)
        # exact partition after every op
        mapped = {p for pages in held.values() for p in pages}
        assert pool.used_pages == len(mapped)
        assert (pool.free_pages + pool.cached_pages + len(mapped)
                == n_pages)
        for p in mapped:
            want = sum(p in pages for pages in held.values())
            assert int(pool.refcount[p]) == want
        pool.check()
    # drain: cached pages are reclaimable, everything else frees exactly
    for slot in list(held):
        pool.release(slot, cacheable=sticky)
        held.pop(slot)
    pool.evictor.evict(pool.cached_pages)
    assert pool.free_pages == n_pages
    assert int(pool.refcount.sum()) == 0
    pool.check()
