"""Scheduler policy units: kv_utilization() aggregation over synthetic
segment samples, and the opt-in skip-ahead admission policy (bounded
lookahead past a head-of-line request whose pages don't fit)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig
from repro.serve.scheduler import Batcher, ContinuousBatcher


# --------------------------------------------------------------------------
# kv_utilization aggregation (pure host math — no model needed)
# --------------------------------------------------------------------------

def _batcher_with_samples(samples):
    b = ContinuousBatcher.__new__(ContinuousBatcher)
    b.kv_samples = samples
    return b


def test_kv_utilization_empty():
    u = _batcher_with_samples([]).kv_utilization()
    assert u == {"mean_util": 0.0, "peak_util": 0.0,
                 "peak_live_slots": 0, "samples": 0}


def test_kv_utilization_mean_peak_and_live_slots():
    # (live tokens, allocated token capacity, live slots) per segment
    u = _batcher_with_samples([(10, 100, 2), (50, 100, 3),
                               (30, 60, 1)]).kv_utilization()
    assert u["mean_util"] == pytest.approx((0.1 + 0.5 + 0.5) / 3)
    assert u["peak_util"] == pytest.approx(0.5)
    assert u["peak_live_slots"] == 3
    assert u["samples"] == 3


def test_kv_utilization_skips_zero_capacity_samples():
    """A segment sampled with nothing allocated (cap 0) must not divide by
    zero or drag the mean; live-slot peaks still count every sample."""
    u = _batcher_with_samples([(0, 0, 0), (40, 80, 4),
                               (0, 0, 0)]).kv_utilization()
    assert u["mean_util"] == pytest.approx(0.5)
    assert u["peak_util"] == pytest.approx(0.5)
    assert u["peak_live_slots"] == 4
    assert u["samples"] == 3


def test_unknown_admission_policy_rejected():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    with pytest.raises(ValueError, match="admission"):
        Batcher(model, {}, ServeConfig(max_len=32, batch=2,
                                       admission="lifo"))


# --------------------------------------------------------------------------
# skip-ahead admission
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


def test_skip_ahead_improves_occupancy_mixed_sizes(setup):
    """Mixed prompt sizes against a small pool: FIFO head-of-line blocks
    on the big request and serves alone; skip-ahead admits the small
    requests queued behind it into the idle slots.  Outputs are identical
    either way (per-slot lengths make tokens schedule-independent)."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    big = rng.integers(0, cfg.vocab, size=30).tolist()
    smalls = [rng.integers(0, cfg.vocab, size=4).tolist() for _ in range(3)]
    # small first so the pool is part-full when the big head blocks
    requests = [(0, smalls[0]), (1, big), (2, smalls[1]), (3, smalls[2])]
    base = dict(max_len=64, batch=3, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8, total_pages=6)

    def run(admission):
        b = Batcher(model, params,
                    ServeConfig(**base, admission=admission))
        for rid, p in requests:
            b.submit(rid, p)
        res = b.run(max_new=8)
        occ = [s for _, _, s in b.kv_samples]
        return res, b.kv_utilization()["peak_live_slots"], occ

    fifo_res, fifo_peak, fifo_occ = run("fifo")
    skip_res, skip_peak, skip_occ = run("skip-ahead")
    for rid, _ in requests:
        assert skip_res[rid] == fifo_res[rid], rid
    # the big request needs 5 of 6 pages: FIFO can never run two slots
    # while it is at the head, skip-ahead packs the smalls in
    assert fifo_peak < skip_peak
    assert skip_peak == 3
    assert (sum(skip_occ) / len(skip_occ)
            > sum(fifo_occ) / len(fifo_occ))


def test_skip_ahead_lookahead_is_bounded(setup):
    """With lookahead 1 the policy degenerates to FIFO: the admissible
    small request sits outside the scan window while the big head
    blocks."""
    cfg, model, params = setup
    rng = np.random.default_rng(6)
    big = rng.integers(0, cfg.vocab, size=30).tolist()
    small = rng.integers(0, cfg.vocab, size=4).tolist()
    base = dict(max_len=64, batch=2, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8, total_pages=6)

    def peak(lookahead):
        b = Batcher(model, params,
                    ServeConfig(**base, admission="skip-ahead",
                                admission_lookahead=lookahead))
        # the first small part-fills the pool, so the big head blocks and
        # the last small is only reachable through the lookahead window
        b.submit(0, small)
        b.submit(1, big)
        b.submit(2, small[:3])
        b.run(max_new=8)
        return b.kv_utilization()["peak_live_slots"]

    assert peak(1) == 1      # window stops at the blocked head
    assert peak(3) == 2      # window reaches past it


# --------------------------------------------------------------------------
# skip-ahead aging (starvation bound)
# --------------------------------------------------------------------------

def _aging_batcher(model, params, max_skips):
    """Pool staged so a big head blocks while smalls fit: 14 pages, an
    occupier slot pinning 8, the big request needing 7 > 6 free."""
    scfg = ServeConfig(max_len=64, batch=5, dtype=jnp.float32,
                      paged=True, page_size=8, total_pages=14,
                      admission="skip-ahead", admission_max_skips=max_skips)
    b = Batcher(model, params, scfg)
    b.pool.reserve(4, 64)          # occupier: 8 pages off the free list
    rng = np.random.default_rng(4)
    big = rng.integers(0, 100, size=48).tolist()      # 7 pages w/ budget 8
    smalls = [rng.integers(0, 100, size=4).tolist() for _ in range(3)]
    b.submit(100, big)
    for i, s in enumerate(smalls):
        b.submit(200 + i, s)
    return b


def test_skip_ahead_aging_becomes_barrier(setup):
    """Each bypass charges the blocked head one skip; at max_skips it
    turns into a barrier — later smalls stop being admitted past it even
    though their pages fit."""
    cfg, model, params = setup
    b = _aging_batcher(model, params, max_skips=2)
    assert b._admit_next(0, 8)[0] == 200          # skip 1 charged to big
    assert b._admit_next(1, 8)[0] == 201          # skip 2 charged to big
    assert b._skips[100] == 2
    # a third small fits (2 of 2 free pages) but the aged head blocks it
    assert b._admit_next(2, 8) is None
    assert b.queue[0][0] == 100 and len(b.queue) == 2
    # pages freeing unblocks the head itself; its skip record clears
    b.pool.release(4)
    assert b._admit_next(2, 8)[0] == 100
    assert 100 not in b._skips
    assert b._admit_next(3, 8)[0] == 202          # queue drains in order
    assert b.admit_order == [200, 201, 100, 202]


def test_skip_ahead_max_skips_zero_is_fifo(setup):
    """max_skips=0 ages the head instantly: skip-ahead degenerates to
    strict FIFO (nothing is ever admitted past a blocked head)."""
    cfg, model, params = setup
    b = _aging_batcher(model, params, max_skips=0)
    assert b._admit_next(0, 8) is None
    assert len(b.queue) == 4 and not b._skips


def test_skip_ahead_aging_full_drain_parity(setup):
    """End to end: aging changes only the admission schedule, never the
    tokens (per-slot lengths keep requests schedule-independent)."""
    cfg, model, params = setup
    rng = np.random.default_rng(8)
    big = rng.integers(0, cfg.vocab, size=30).tolist()
    smalls = [rng.integers(0, cfg.vocab, size=4).tolist() for _ in range(3)]
    requests = [(0, smalls[0]), (1, big), (2, smalls[1]), (3, smalls[2])]
    base = dict(max_len=64, batch=3, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8, total_pages=6,
                admission="skip-ahead")

    def run(max_skips):
        b = Batcher(model, params,
                    ServeConfig(**base, admission_max_skips=max_skips))
        for rid, p in requests:
            b.submit(rid, p)
        return b.run(max_new=8), b

    loose, _ = run(max_skips=8)
    tight, bt = run(max_skips=1)
    for rid, _ in requests:
        assert loose[rid] == tight[rid], rid
    # once the big head ages out, the tight run stops packing smalls in
    assert max(bt._skips.values(), default=0) == 0   # drained clean
