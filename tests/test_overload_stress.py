"""Hypothesis stress: overload protection composed with everything else.

Random overloaded traffic driving cancellation (expired deadlines),
SLO-burn/pressure degradation and shedding, page-level preemption,
prefix cache, chunked prefill and speculation — all at once, against
the allocator/radix invariant sweeps.  The schedule-independence
contract under test: **every request that completes emits tokens
bit-identical to the no-overload, no-pressure reference run**, every
request is accounted for exactly once (retired xor cancelled), and the
pool drains with nothing orphaned no matter which requests were
cancelled mid-flight.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.chaos import ChaosInjector
from repro.serve.engine import ServeConfig
from repro.serve.scheduler import Batcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=6, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, admission_mode="optimistic")


def test_stress_overload_traffic_invariants(setup):
    """Random traffic with deadlines, the degradation controller, chaos
    exhaustion and every serving feature armed: parity for completers,
    full accounting for everyone else, invariants green every round.
    (importorskip inside the test, like the other serve suites, so the
    rest of the module still runs without hypothesis; ci.sh fails
    loudly when the install is missing.)"""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    cfg, model, params = setup

    @settings(max_examples=5, deadline=None)
    @given(st.data())
    def inner(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16),
                                              label="seed"))
        n_req = data.draw(st.integers(5, 9), label="n_req")
        system = rng.integers(
            0, cfg.vocab,
            size=data.draw(st.integers(0, 16), label="system")).tolist()
        requests = [(i, system + rng.integers(
            0, cfg.vocab, size=int(rng.integers(4, 14))).tolist())
            for i in range(n_req)]
        max_new = data.draw(st.integers(4, 12), label="max_new")
        pages = data.draw(st.integers(8, 14), label="pages")
        kw: dict = {"total_pages": pages}
        if data.draw(st.booleans(), label="chunked?"):
            kw["prefill_chunk"] = 8
        if data.draw(st.booleans(), label="prefix?"):
            kw["prefix_cache"] = True
        if data.draw(st.booleans(), label="spec?"):
            kw["speculate_k"] = 2
        priorities = {i: data.draw(st.integers(0, 1), label=f"prio{i}")
                      for i in range(n_req)}
        # a random subset carries an already-expired deadline (swept at
        # round one — a deterministic cancellation source) and another
        # subset a generous one that must always be met
        doomed = {i for i in range(n_req)
                  if data.draw(st.booleans(), label=f"doomed{i}")}
        chaos = ChaosInjector(
            exhaust_at={data.draw(st.integers(2, 5), label="xr"): 0},
            release_at=(data.draw(st.integers(7, 10), label="rr"),),
            check_invariants=True)

        def submit_all(b, with_deadlines):
            for rid, p in requests:
                dl = None
                if with_deadlines and rid in doomed:
                    dl = 0.0
                elif with_deadlines:
                    dl = 600.0
                b.submit(rid, p, priority=priorities[rid],
                         deadline_s=dl)

        # no-overload, no-pressure oracle: ample pool, reservation
        # admission, no controller, no deadlines
        ref_b = Batcher(model, params, ServeConfig(
            **{**BASE, **kw, "total_pages": 64,
               "admission_mode": "reserve"}))
        submit_all(ref_b, with_deadlines=False)
        ref = ref_b.run(max_new=max_new)

        b = Batcher(model, params, ServeConfig(
            **{**BASE, **kw, "overload": True,
               "overload_degrade_pressure": 0.5,
               "overload_shed_pressure": 0.9,
               "overload_up_rounds": 1, "overload_down_rounds": 2,
               "overload_queue_keep": data.draw(
                   st.integers(2, 6), label="keep")}), chaos=chaos)
        submit_all(b, with_deadlines=True)
        got = b.run(max_new=max_new)

        all_rids = {rid for rid, _ in requests}
        # exactly-once accounting: retired xor cancelled, nobody lost
        assert set(got) | set(b.cancelled) == all_rids
        assert set(got).isdisjoint(b.cancelled)
        # completers are bit-identical to the unloaded reference
        for rid in got:
            assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
        # an expired deadline can never be served
        for rid in doomed:
            assert rid not in got
            assert b.cancelled[rid] in ("deadline", "timeout")
        # deadline ledger: every completer carried a generous stamp and
        # met it; deadline/timeout cancels are scored misses; sheds are
        # excluded (RETRY_AFTER is an answer, not a late completion)
        st_ov = b.overload_stats()
        met, tot = st_ov["deadline_met"], st_ov["deadline_total"]
        dl_cancels = sum(1 for v in b.cancelled.values()
                         if v in ("deadline", "timeout"))
        assert met == len(got)
        assert tot == len(got) + dl_cancels
        # every preempted request was resolved (retired or cancelled)
        assert b.preempt_stats()["recomputed_ok"]
        assert not b._resumed
        # nothing orphaned: pool fully drained, invariants green
        assert b.pool.held_pages == 0
        assert b.pool.used_pages == 0
        b.pool.check()
        if b.prefix is not None:
            b.prefix.check()

    inner()
