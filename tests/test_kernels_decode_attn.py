import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import decode_attn
from repro.kernels.decode_attn.ref import decode_attn_ref


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 256, 4, 4, 64),      # MHA
    (2, 1024, 8, 2, 64),     # GQA 4:1
    (1, 512, 16, 1, 128),    # MQA
    (4, 384, 8, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn_sweep(b, s, hq, hkv, d, dtype):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    ln = s // 2 + 1
    out = decode_attn(q, k, v, ln, bs=128)
    ref = decode_attn_ref(q, k, v, ln)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ln", [1, 127, 128, 129, 512])
def test_decode_attn_lengths(ln):
    """Length masking at block boundaries."""
    rng = np.random.default_rng(ln)
    q = jnp.asarray(rng.standard_normal((1, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    out = decode_attn(q, k, v, ln, bs=128)
    ref = decode_attn_ref(q, k, v, ln)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_decode_attn_matches_model_core():
    """Cross-check against the model's attention_core decode path."""
    from repro.models.attention import attention_core
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 256, 8, 2, 32
    ln = 200
    q = jnp.asarray(rng.standard_normal((b, 1, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    ref = attention_core(q, k, v, causal=True, q_offset=ln - 1, kv_len=ln)
    out = decode_attn(q[:, 0], k, v, ln, bs=64)
    np.testing.assert_allclose(out, ref[:, 0], rtol=3e-4, atol=3e-4)
