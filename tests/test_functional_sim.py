"""Functional PIM machine: the orchestration computes the right answers."""
import numpy as np
import pytest

from repro.core.functional_sim import (Cmd, PimMachine, elementwise_program,
                                       gather_coaligned, place_coaligned)
from repro.core.hwspec import PimSpec


def test_vector_sum_program_executes_correctly():
    """The §4.2.2 vector-sum schedule, executed command-by-command on the
    machine model, equals a + b."""
    spec = PimSpec()
    m = PimMachine(spec)
    rng = np.random.default_rng(0)
    n = 5000
    a = rng.standard_normal(n).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    place_coaligned(m, {0: a, 1: b, 2: np.zeros(n, np.float32)})
    prog = elementwise_program(spec, in_rows=[0, 1], out_row=2,
                               fn=lambda r, x: r + x)
    m.execute(prog)
    out = gather_coaligned(m, 2, n)
    np.testing.assert_allclose(out, a + b, rtol=1e-6)


def test_three_operand_fma_program():
    """c = (a + b) * d via chained op phases (register staging)."""
    spec = PimSpec()
    m = PimMachine(spec)
    rng = np.random.default_rng(1)
    n = 2048
    a, b, d = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    place_coaligned(m, {0: a, 1: b, 2: d, 3: np.zeros(n, np.float32)})
    prog = []
    prog += elementwise_program(spec, in_rows=[0, 1], out_row=3,
                                fn=lambda r, x: r + x)
    prog += elementwise_program(spec, in_rows=[3, 2], out_row=3,
                                fn=lambda r, x: r * x)
    m.execute(prog)
    np.testing.assert_allclose(gather_coaligned(m, 3, n), (a + b) * d,
                               rtol=1e-5)


def test_machine_enforces_register_bounds():
    m = PimMachine()
    m.write_row(0, 0, np.zeros((32, 16), np.float32))
    with pytest.raises(ValueError):
        m.execute([Cmd("act", "all", row=0),
                   Cmd("ld", "even", col=0, reg=99)])


def test_machine_requires_open_row():
    m = PimMachine()
    with pytest.raises(RuntimeError):
        m.execute([Cmd("ld", "even", col=0, reg=0)])


def test_program_command_mix_matches_timing_model():
    """The functional program's command counts equal what the timing model
    charges for the same problem slice — the two models describe one
    machine."""
    from repro.core.commands import Kind, total_by_kind
    from repro.core.optimizations import Phase, baseline_schedule, chunk_cols
    spec = PimSpec()
    prog = elementwise_program(spec, in_rows=[0, 1], out_row=2,
                               fn=lambda r, x: r + x)
    n_act = sum(1 for c in prog if c.kind == "act")
    n_compute = sum(1 for c in prog if c.kind != "act")
    cols = chunk_cols(spec.pim_regs_per_alu)
    trips = spec.cols_per_row // cols
    stream = baseline_schedule([Phase(cols)] * 3, trips)
    by = total_by_kind(stream)
    assert by[Kind.ACT] == n_act
    assert by[Kind.PIM_BCAST] == n_compute
