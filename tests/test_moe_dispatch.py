"""MoE dispatch semantics: rank computation, capacity drops, combine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.moe import aux_load_balance_loss, init_moe, moe_apply, route


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    params = pm.unwrap(init_moe(jax.random.key(0), cfg))
    return cfg, params


def test_moe_dense_equivalence(setup):
    """With capacity >= all assignments, MoE == explicit dense mixture."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)) * 0.5,
                    jnp.float32)
    y, _ = moe_apply(params, x, cfg, "silu")
    # explicit: for each token, run its top-k experts densely
    x2d = x.reshape(-1, cfg.d_model)
    w, ids, _ = route(params, x2d, cfg)
    act = jax.nn.silu
    wi, wg, wo = params["wi"], params["wg"], params["wo"]
    ref = np.zeros_like(np.asarray(x2d))
    for t in range(x2d.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(ids[t, j])
            h = act(x2d[t] @ wg[e]) * (x2d[t] @ wi[e])
            ref[t] += float(w[t, j]) * np.asarray(h @ wo[e])
    if "shared" in params:
        from repro.models.layers import mlp
        ref += np.asarray(mlp(params["shared"], x, "silu")).reshape(
            ref.shape)
    np.testing.assert_allclose(np.asarray(y).reshape(ref.shape), ref,
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drop_monotone(setup):
    """Tiny capacity drops tokens -> output moves toward shared-only."""
    import dataclasses
    cfg, params = setup
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), jnp.float32)
    y_full, _ = moe_apply(params, x, cfg, "silu")
    cfg_tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    y_tight, _ = moe_apply(params, x, cfg_tight, "silu")
    # outputs differ (drops happened) but remain finite
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tight))
    assert np.isfinite(np.asarray(y_tight)).all()


def test_aux_loss_uniform_routing_is_one():
    """Perfectly uniform routing gives aux loss ~= 1 (its minimum)."""
    e = 8
    probs = jnp.full((64, e), 1.0 / e)
    ids = jnp.tile(jnp.arange(e)[None, :2], (64, 1))
    ids = jnp.asarray(np.random.default_rng(0).integers(0, e, (64, 2)))
    loss = aux_load_balance_loss(probs, ids, e)
    assert 0.8 < float(loss) < 1.3


def test_group_gemm_agrees_with_moe_expert_compute(setup):
    """The Pallas grouped GEMM computes the same expert outputs as the
    einsum inside moe_apply (single-matrix case)."""
    cfg, params = setup
    from repro.kernels.moe_group_gemm import group_gemm
    rng = np.random.default_rng(2)
    e = cfg.moe.n_experts
    c, d = 16, cfg.d_model
    xe = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    counts = jnp.asarray(rng.integers(0, c + 1, e), jnp.int32)
    live = jnp.arange(c)[None, :, None] < counts[:, None, None]
    ref = jnp.where(live, jnp.einsum("ecd,edf->ecf", xe, params["wi"]), 0.0)
    out = group_gemm(xe, params["wi"], counts, bc=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_shard_map_path_matches_jit_path(setup):
    """§Perf iter 6: the shard_map MoE (local dispatch + psum) computes the
    same outputs as the plain-jit path on a 1x1 host mesh."""
    import jax
    from repro.distributed.act_sharding import activation_policy
    cfg, params = setup
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.5,
                    jnp.float32)
    y_jit, aux_jit = moe_apply(params, x, cfg, "silu")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    with activation_policy(mesh):
        y_sm, aux_sm = moe_apply(params, x, cfg, "silu")
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_jit),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_sm), float(aux_jit), rtol=1e-5)
