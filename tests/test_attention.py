"""Attention-core equivalences: blockwise (flash) vs dense, GQA grouping,
RoPE decode consistency, MLA absorbed-path internals."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models.layers import apply_rope


def _qkv(rng, b, lq, lk, hq, hkv, d):
    q = jnp.asarray(rng.standard_normal((b, lq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, lk, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, lk, hkv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
def test_blockwise_matches_dense(causal, hq, hkv):
    rng = np.random.default_rng(hq * 10 + hkv)
    b, l, d = 2, 256, 32
    q, k, v = _qkv(rng, b, l, l, hq, hkv, d)
    qg = q.reshape(b, l, hkv, hq // hkv, d)
    dense = attn._dense_attn(qg, k, v, causal=causal, q_offset=0)
    old_bq, old_bk = attn.BLOCK_Q, attn.BLOCK_K
    attn.BLOCK_Q, attn.BLOCK_K = 64, 96   # force multi-block + ragged tail
    try:
        block = attn._blockwise_attn(qg, k, v, causal=causal, q_offset=0)
    finally:
        attn.BLOCK_Q, attn.BLOCK_K = old_bq, old_bk
    np.testing.assert_allclose(np.asarray(block), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_gqa_equals_repeated_kv():
    """Grouped attention == full MHA with explicitly repeated KV heads."""
    rng = np.random.default_rng(0)
    b, l, hq, hkv, d = 1, 64, 8, 2, 16
    q, k, v = _qkv(rng, b, l, l, hq, hkv, d)
    out = attn.attention_core(q, k, v, causal=True)
    k_rep = jnp.repeat(k, hq // hkv, axis=2)
    v_rep = jnp.repeat(v, hq // hkv, axis=2)
    ref = attn.attention_core(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 32)), jnp.float32)
    p0 = jnp.arange(8)
    s0 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, p0),
                    apply_rope(k, p0))
    s1 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, p0 + 1000),
                    apply_rope(k, p0 + 1000))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-3,
                               atol=1e-3)


def test_mla_cache_is_compressed():
    """MLA decode cache stores kv_lora + rope dims only (arXiv:2412.19437)."""
    from repro.configs import get_config
    from repro.models.model_zoo import Model
    cfg = get_config("deepseek-v3-671b").reduced()
    model = Model(cfg)
    caches = model.init_caches(batch=2, max_len=16, dtype=jnp.float32)
    for c in caches:
        assert c["k"].shape[-1] == cfg.mla.kv_lora_rank
        assert c["v"].shape[-1] == cfg.mla.qk_rope_head_dim


def test_decode_attn_kernel_vs_blockwise_long():
    """Kernel / dense / blockwise triple agreement at a longer context."""
    from repro.kernels.decode_attn import decode_attn
    rng = np.random.default_rng(3)
    b, s, hq, hkv, d = 1, 2048, 4, 2, 32
    q, k, v = _qkv(rng, b, 1, s, hq, hkv, d)
    ln = 1500
    out_k = decode_attn(q[:, 0], k, v, ln, bs=256)
    out_d = attn.attention_core(q, k, v, causal=True, q_offset=ln - 1,
                                kv_len=ln)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d[:, 0]),
                               rtol=3e-4, atol=3e-4)
