"""Paged decode-attention kernel vs oracle: permuted page tables, partial
last pages, sentinel (unallocated) tail entries, GQA/MQA head layouts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import (gather_pages, paged_attn,
                                      paged_attn_ref, paged_attn_xla)


def _mk(rng, b, hq, hkv, d, n, ps, p_max, lengths, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((n, ps, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((n, ps, hkv, d)), dtype)
    # each slot maps ceil(len/ps) random distinct pages; the tail of each
    # row is the pool's sentinel id (== n)
    tbl = np.full((b, p_max), n, np.int32)
    perm = list(rng.permutation(n))
    for i, ln in enumerate(lengths):
        need = -(-ln // ps)
        assert need <= p_max and len(perm) >= need, "test sizing bug"
        for j in range(need):
            tbl[i, j] = perm.pop()
    return q, k, v, jnp.asarray(tbl), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 32),    # GQA 4:1
    (1, 4, 4, 64),    # MHA
    (2, 8, 1, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attn_sweep(b, hq, hkv, d, dtype):
    rng = np.random.default_rng(hq * d)
    n, ps, p_max = 24, 8, 8
    lengths = [int(rng.integers(1, p_max * ps)) for _ in range(b)]
    q, k, v, tbl, ln = _mk(rng, b, hq, hkv, d, n, ps, p_max, lengths, dtype)
    out = paged_attn(q, k, v, tbl, ln)
    ref = paged_attn_ref(q, k, v, tbl, ln)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ln", [1, 7, 8, 9, 63, 64])
def test_paged_attn_page_boundaries(ln):
    """Length masking at page boundaries (partial last page, exact fill,
    one-token slot)."""
    rng = np.random.default_rng(ln)
    q, k, v, tbl, lns = _mk(rng, 1, 4, 2, 32, 16, 8, 8, [ln])
    out = paged_attn(q, k, v, tbl, lns)
    ref = paged_attn_ref(q, k, v, tbl, lns)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_paged_attn_matches_dense_decode_attn():
    """A paged cache whose table is the identity permutation is exactly a
    dense cache: paged_attn == decode_attn == dense oracle."""
    from repro.kernels.decode_attn import decode_attn
    rng = np.random.default_rng(0)
    b, hq, hkv, d, ps, p_max = 3, 8, 2, 32, 8, 6
    n = b * p_max
    lengths = [5, 33, 48]
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((b, p_max * ps, hkv, d)),
                     jnp.float32)
    vd = jnp.asarray(rng.standard_normal((b, p_max * ps, hkv, d)),
                     jnp.float32)
    # identity layout: slot i's pages are i*p_max .. i*p_max+p_max-1
    kp = kd.reshape(n, ps, hkv, d)
    vp = vd.reshape(n, ps, hkv, d)
    tbl = jnp.arange(n, dtype=jnp.int32).reshape(b, p_max)
    ln = jnp.asarray(lengths, jnp.int32)
    paged = paged_attn(q, kp, vp, tbl, ln)
    dense = decode_attn(q, kd, vd, ln, bs=ps)
    np.testing.assert_allclose(paged, dense, rtol=3e-4, atol=3e-4)


def test_gather_pages_layout():
    """gather_pages reassembles table order and clamps sentinels."""
    pool = jnp.arange(4 * 2 * 1 * 1, dtype=jnp.float32).reshape(4, 2, 1, 1)
    tbl = jnp.asarray([[2, 0, 4]], jnp.int32)      # 4 == sentinel, clamps
    out = gather_pages(pool, tbl)
    assert out.shape == (1, 6, 1, 1)
    got = np.asarray(out)[0, :, 0, 0]
    np.testing.assert_array_equal(got[:4], [4.0, 5.0, 0.0, 1.0])


def test_paged_attn_xla_matches_kernel():
    rng = np.random.default_rng(9)
    q, k, v, tbl, ln = _mk(rng, 2, 4, 2, 32, 12, 8, 4, [9, 25])
    out_k = paged_attn(q, k, v, tbl, ln)
    out_x = paged_attn_xla(q, k, v, tbl, ln)
    np.testing.assert_allclose(out_k, out_x, rtol=3e-4, atol=3e-4)
