"""Paged attention kernels vs oracle: permuted page tables, partial last
pages, sentinel (unallocated) tail entries, GQA/MQA head layouts — for the
one-token decode kernel and the multi-token flash-prefill kernel (mixed
per-slot prefix depths, suffixes crossing page boundaries)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import (gather_pages, paged_attn,
                                      paged_attn_ref, paged_attn_xla,
                                      paged_prefill_attn,
                                      paged_prefill_attn_pallas,
                                      paged_prefill_attn_ref)


def _mk(rng, b, hq, hkv, d, n, ps, p_max, lengths, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((n, ps, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((n, ps, hkv, d)), dtype)
    # each slot maps ceil(len/ps) random distinct pages; the tail of each
    # row is the pool's sentinel id (== n)
    tbl = np.full((b, p_max), n, np.int32)
    perm = list(rng.permutation(n))
    for i, ln in enumerate(lengths):
        need = -(-ln // ps)
        assert need <= p_max and len(perm) >= need, "test sizing bug"
        for j in range(need):
            tbl[i, j] = perm.pop()
    return q, k, v, jnp.asarray(tbl), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 32),    # GQA 4:1
    (1, 4, 4, 64),    # MHA
    (2, 8, 1, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attn_sweep(b, hq, hkv, d, dtype):
    rng = np.random.default_rng(hq * d)
    n, ps, p_max = 24, 8, 8
    lengths = [int(rng.integers(1, p_max * ps)) for _ in range(b)]
    q, k, v, tbl, ln = _mk(rng, b, hq, hkv, d, n, ps, p_max, lengths, dtype)
    out = paged_attn(q, k, v, tbl, ln)
    ref = paged_attn_ref(q, k, v, tbl, ln)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ln", [1, 7, 8, 9, 63, 64])
def test_paged_attn_page_boundaries(ln):
    """Length masking at page boundaries (partial last page, exact fill,
    one-token slot)."""
    rng = np.random.default_rng(ln)
    q, k, v, tbl, lns = _mk(rng, 1, 4, 2, 32, 16, 8, 8, [ln])
    out = paged_attn(q, k, v, tbl, lns)
    ref = paged_attn_ref(q, k, v, tbl, lns)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_paged_attn_matches_dense_decode_attn():
    """A paged cache whose table is the identity permutation is exactly a
    dense cache: paged_attn == decode_attn == dense oracle."""
    from repro.kernels.decode_attn import decode_attn
    rng = np.random.default_rng(0)
    b, hq, hkv, d, ps, p_max = 3, 8, 2, 32, 8, 6
    n = b * p_max
    lengths = [5, 33, 48]
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((b, p_max * ps, hkv, d)),
                     jnp.float32)
    vd = jnp.asarray(rng.standard_normal((b, p_max * ps, hkv, d)),
                     jnp.float32)
    # identity layout: slot i's pages are i*p_max .. i*p_max+p_max-1
    kp = kd.reshape(n, ps, hkv, d)
    vp = vd.reshape(n, ps, hkv, d)
    tbl = jnp.arange(n, dtype=jnp.int32).reshape(b, p_max)
    ln = jnp.asarray(lengths, jnp.int32)
    paged = paged_attn(q, kp, vp, tbl, ln)
    dense = decode_attn(q, kd, vd, ln, bs=ps)
    np.testing.assert_allclose(paged, dense, rtol=3e-4, atol=3e-4)


def test_gather_pages_layout():
    """gather_pages reassembles table order and clamps sentinels."""
    pool = jnp.arange(4 * 2 * 1 * 1, dtype=jnp.float32).reshape(4, 2, 1, 1)
    tbl = jnp.asarray([[2, 0, 4]], jnp.int32)      # 4 == sentinel, clamps
    out = gather_pages(pool, tbl)
    assert out.shape == (1, 6, 1, 1)
    got = np.asarray(out)[0, :, 0, 0]
    np.testing.assert_array_equal(got[:4], [4.0, 5.0, 0.0, 1.0])


def test_paged_attn_xla_matches_kernel():
    rng = np.random.default_rng(9)
    q, k, v, tbl, ln = _mk(rng, 2, 4, 2, 32, 12, 8, 4, [9, 25])
    out_k = paged_attn(q, k, v, tbl, ln)
    out_x = paged_attn_xla(q, k, v, tbl, ln)
    np.testing.assert_allclose(out_k, out_x, rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# flash-prefill kernel (multi-token suffix queries at per-slot depths)
# --------------------------------------------------------------------------

def _mk_prefill(rng, b, hq, hkv, d, n, ps, p_max, offsets, lq,
                dtype=jnp.float32):
    """Random pooled pages + per-slot tables sized for offset + lq tokens;
    table tails hold the sentinel id (== n)."""
    q = jnp.asarray(rng.standard_normal((b, lq, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((n, ps, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((n, ps, hkv, d)), dtype)
    tbl = np.full((b, p_max), n, np.int32)
    perm = list(rng.permutation(n))
    for i, off in enumerate(offsets):
        need = -(-(off + lq) // ps)
        assert need <= p_max and len(perm) >= need, "test sizing bug"
        for j in range(need):
            tbl[i, j] = perm.pop()
    off = jnp.asarray(offsets, jnp.int32)
    return q, k, v, jnp.asarray(tbl), off, off + lq


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 32),    # GQA 4:1
    (1, 4, 4, 64),    # MHA
    (2, 8, 1, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_sweep(b, hq, hkv, d, dtype):
    """Kernel vs oracle across head ratios and dtypes at mixed per-slot
    prefix depths (one row deep, one shallow)."""
    rng = np.random.default_rng(hq * d + 1)
    n, ps, p_max, lq = 32, 8, 8, 5
    offsets = [int(rng.integers(0, 3 * ps)) for _ in range(b)]
    q, k, v, tbl, off, ln = _mk_prefill(rng, b, hq, hkv, d, n, ps, p_max,
                                        offsets, lq, dtype)
    out = paged_prefill_attn_pallas(q, k, v, tbl, off, ln)
    ref = paged_prefill_attn_ref(q, k, v, tbl, off, ln)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("off,lq", [
    (0, 1),      # fresh one-token prompt
    (0, 8),      # exactly one page, no prefix
    (7, 2),      # suffix straddles the first page boundary
    (8, 8),      # page-aligned prefix, page-aligned suffix
    (8, 9),      # page-aligned prefix, suffix crosses into a third page
    (13, 11),    # nothing aligned anywhere
])
def test_paged_prefill_page_boundaries(off, lq):
    """Causal masking at absolute depth across page boundaries: partial
    prefix pages, suffixes crossing pages, exact fills."""
    rng = np.random.default_rng(off * 16 + lq)
    q, k, v, tbl, offs, ln = _mk_prefill(rng, 1, 4, 2, 32, 16, 8, 8,
                                         [off], lq)
    out = paged_prefill_attn_pallas(q, k, v, tbl, offs, ln)
    ref = paged_prefill_attn_ref(q, k, v, tbl, offs, ln)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_paged_prefill_matches_decode_rowwise():
    """An Lq=1 prefill at depth ``off`` is exactly a decode step whose
    cache already holds off+1 tokens: both kernels agree."""
    rng = np.random.default_rng(3)
    b, hq, hkv, d, ps = 2, 8, 2, 32, 8
    offsets = [5, 19]
    q, k, v, tbl, off, ln = _mk_prefill(rng, b, hq, hkv, d, 24, ps, 8,
                                        offsets, 1)
    pre = paged_prefill_attn_pallas(q, k, v, tbl, off, ln)
    dec = paged_attn(q[:, 0], k, v, tbl, ln)
    np.testing.assert_allclose(pre[:, 0], dec, rtol=3e-4, atol=3e-4)


def test_paged_prefill_policy_routing():
    """``paged_prefill_attn`` follows the decode-attention policy: the
    kernel path (interpreted here) and the XLA ref agree; ``mode="xla"``
    is the ref bit-for-bit."""
    from repro.kernels.decode_attn import decode_attn_policy
    rng = np.random.default_rng(7)
    q, k, v, tbl, off, ln = _mk_prefill(rng, 2, 8, 2, 32, 24, 8, 8,
                                        [6, 16], 4)
    ref = paged_prefill_attn_ref(q, k, v, tbl, off, ln)
    with decode_attn_policy(mode="kernel", interpret=True):
        out_k = paged_prefill_attn(q, k, v, tbl, off, ln)
    with decode_attn_policy(mode="xla"):
        out_x = paged_prefill_attn(q, k, v, tbl, off, ln)
    np.testing.assert_allclose(out_k, ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_array_equal(np.asarray(out_x), np.asarray(ref))


def test_paged_prefill_dead_pages_skipped():
    """Pages above the causal window never affect the output: corrupting
    every page past ceil((off+lq)/ps) leaves the result bit-identical
    (the §5.1.2 skip really skips)."""
    rng = np.random.default_rng(11)
    n, ps, off, lq = 16, 8, 9, 3
    q, k, v, tbl, offs, ln = _mk_prefill(rng, 1, 4, 2, 32, n, ps, 8,
                                         [off], lq)
    out = paged_prefill_attn_pallas(q, k, v, tbl, offs, ln)
    live = {int(p) for p in np.asarray(tbl)[0, :-(-(off + lq) // ps)]}
    dead = [p for p in range(n) if p not in live]
    k2 = k.at[jnp.asarray(dead)].set(jnp.nan)
    v2 = v.at[jnp.asarray(dead)].set(jnp.nan)
    out2 = paged_prefill_attn_pallas(q, k2, v2, tbl, offs, ln)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
