"""Functional correctness of the studied primitives (JAX implementations)
and reproduction-band checks of the analytical results."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import push, ss_gemm, vector_sum, wavesim
from repro.core.primitives.graphs import paper_inputs, powerlaw, roadnet


def test_wavesim_step_conserves_shape_and_energy_scale():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.standard_normal((4, 4, 4, 3, 3, 3, 3)), jnp.float32)
    u2 = wavesim.step(u, dt=1e-3)
    assert u2.shape == u.shape
    # explicit Euler with small dt: bounded change
    rel = float(jnp.linalg.norm(u2 - u) / jnp.linalg.norm(u))
    assert 0 < rel < 0.1


def test_wavesim_flux_zero_for_constant_field():
    """Constant fields have no jumps -> zero flux."""
    u = jnp.ones((4, 4, 4, 2, 3, 3, 3), jnp.float32)
    f = wavesim.flux(u)
    assert float(jnp.abs(f).max()) == 0.0


def test_wavesim_volume_zero_for_constant_field():
    u = jnp.ones((8, 2, 3, 3, 3), jnp.float32)
    v = wavesim.volume(u)
    np.testing.assert_allclose(np.asarray(v), 0.0, atol=1e-5)


def test_push_reference_matches_numpy():
    rng = np.random.default_rng(1)
    n, e = 500, 2000
    vals = rng.standard_normal(n).astype(np.float32)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    out = push.reference(jnp.asarray(vals), jnp.asarray(src),
                         jnp.asarray(dst), n)
    expect = vals.copy()
    np.add.at(expect, dst, 0.85 * vals[src])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4,
                               atol=1e-4)


def test_ssgemm_generator_stats():
    p = ss_gemm.Problem(n=4)
    b = ss_gemm.make_skinny(p, seed=0)
    density, row_zero = ss_gemm.measured_sparsity(b)
    assert abs(density - p.density) < 0.05
    assert 0.0 <= row_zero < 0.3


# ---------------- reproduction bands (paper anchors) ----------------------

def test_vector_sum_band():
    s = vector_sum.speedup(vector_sum.Problem(64 << 20), PIM, GPU)
    assert s > 2.6                         # paper: "over 2.6x"
    assert s < PIM.pim_peak_gbps / GPU.effective_gbps   # below upper bound


def test_wavesim_volume_band():
    wp = wavesim.Problem()
    base = wavesim.speedup_volume(wp, PIM, GPU)
    opt = wavesim.speedup_volume(wp, PIM, GPU, arch_aware=True)
    act = wavesim.pim_time_volume(wp, PIM).act_stall_frac
    assert base == pytest.approx(1.5, rel=0.1)          # paper 1.5x
    assert opt == pytest.approx(2.04, rel=0.1)          # paper 2.04x
    assert act == pytest.approx(0.27, abs=0.05)         # paper 27%


def test_wavesim_flux_band():
    wp = wavesim.Problem()
    act = wavesim.pim_time_flux(wp, PIM).act_stall_frac
    assert act == pytest.approx(0.50, abs=0.06)         # paper 50%
    opt64 = wavesim.speedup_flux(wp, PIM, GPU, arch_aware=True, regs=64)
    assert opt64 == pytest.approx(2.63, rel=0.1)        # paper up to 2.63x
    # arch-aware gains little at 16 regs, a lot at 64 (Fig 8 shape)
    gain16 = (wavesim.speedup_flux(wp, PIM, GPU, arch_aware=True, regs=16)
              / wavesim.speedup_flux(wp, PIM, GPU, regs=16))
    gain64 = opt64 / wavesim.speedup_flux(wp, PIM, GPU, regs=64)
    assert gain16 < gain64 + 0.05


def test_ssgemm_bands():
    r2 = ss_gemm.speedups(ss_gemm.Problem(n=2), PIM, GPU)
    r8 = ss_gemm.speedups(ss_gemm.Problem(n=8), PIM, GPU)
    assert r2["baseline"] == pytest.approx(1.66, rel=0.1)   # paper 1.66x
    assert r2["sparsity_aware"] > 2.5                       # paper: >3x-ish
    assert r8["baseline"] < 1.0                             # slowdown
    assert r8["sparsity_aware"] == pytest.approx(1.07, rel=0.15)


@pytest.mark.slow
def test_push_bands():
    results = [push.evaluate(g, PIM, GPU, predictor_sample=150_000)
               for g in paper_inputs()]
    ca = [r.speedup_cache_aware for r in results]
    base = [r.speedup_baseline for r in results]
    assert all(b < 1.1 for b in base)            # baseline PIM degrades
    assert all(c > 1.0 for c in ca)              # cache-aware recovers
    assert max(ca) == pytest.approx(1.39, rel=0.15)
    assert sum(ca) / len(ca) == pytest.approx(1.20, rel=0.15)
