"""Sharding-rule tests on a small host mesh (4 virtual devices via the
conftest-free path: skipped unless enough devices — the dry-run covers the
production mesh; here we verify rule semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec

from repro.distributed.sharding import spec_for
from repro.models import param as pm


@pytest.fixture(scope="module")
def mesh():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device mesh still exercises the rule logic (axis size 1)
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=devs[:1])


def _mesh2(shape, names):
    class FakeMesh:
        axis_names = names
        import numpy as _np
        devices = np.empty(shape, dtype=object)
    return FakeMesh()


def test_divisibility_fallback():
    mesh = _mesh2((16, 16), ("data", "model"))
    # kv_heads=2 cannot shard over model=16 -> replicated
    spec = spec_for(("embed", "kv_heads", "head_dim"), (4096, 2, 128), mesh)
    assert spec == PartitionSpec("data", None, None)
    # kv_heads=32 shards
    spec = spec_for(("embed", "kv_heads", "head_dim"), (4096, 32, 128), mesh)
    assert spec == PartitionSpec("data", "model", None)


def test_axis_used_once():
    mesh = _mesh2((16, 16), ("data", "model"))
    spec = spec_for(("experts", "embed", "mlp"), (256, 7168, 2048), mesh)
    # experts take model; embed takes data; mlp finds model taken -> None
    assert spec == PartitionSpec("model", "data", None)


def test_batch_multi_axis_and_fallback():
    mesh = _mesh2((2, 16, 16), ("pod", "data", "model"))
    spec = spec_for(("batch", None), (256, 4096), mesh)
    assert spec == PartitionSpec(("pod", "data"), None)
    # batch=1 (long_500k): fully replicated
    spec = spec_for(("batch", None), (1, 4096), mesh)
    assert spec == PartitionSpec(None, None)
    # batch=2: only the pod axis fits
    spec = spec_for(("batch", None), (2, 4096), mesh)
    assert spec == PartitionSpec(("pod",), None) or \
        spec == PartitionSpec("pod", None)


def test_param_shardings_stacked_segments(mesh):
    from repro.configs import get_config
    from repro.distributed.sharding import param_shardings
    from repro.models.model_zoo import Model
    model = Model(get_config("qwen2-0.5b").reduced())
    shardings = param_shardings(model.abstract_ptree(), mesh)
    values = model.abstract_params()
    # structures must match exactly (jit in_shardings contract)
    assert jax.tree_util.tree_structure(shardings) == \
        jax.tree_util.tree_structure(values)
