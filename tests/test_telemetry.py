"""Unified serve telemetry: the Tracer's lifecycle stream must be
complete (every submitted rid runs SUBMIT -> ... -> RETIRE with
monotone rounds, preemptions show PREEMPT -> ADMIT -> RESUME), the
Perfetto export must be schema-valid trace_event JSON with
non-overlapping slot spans, and the MetricsRegistry must reproduce the
legacy ``*_stats()`` numbers bit-for-bit while ``reset_stats()`` now
clears *everything* it accumulates.  Also covers the chaos-fault trace,
the pool-partition gauge, the kernel timing hooks, and the
zero-overhead-off contract (no tracer calls reachable when telemetry is
off).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.chaos import ChaosInjector
from repro.serve.engine import ServeConfig
from repro.serve.scheduler import Batcher
from repro.serve.telemetry import (CHAOS_KINDS, LIFECYCLE_KINDS,
                                   MetricsRegistry, Tracer, _pct)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=6, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, total_pages=10,
            admission_mode="optimistic")


def _requests(cfg, n=5, lo=8, hi=14, seed=1):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, cfg.vocab,
                             size=int(rng.integers(lo, hi))).tolist())
            for i in range(n)]


def _chaos_run(setup, max_new=10, **kw):
    """The canonical traced chaos run: forced exhaustion at round 2,
    release at round 5 — guarantees preemption at these sizes."""
    cfg, model, params = setup
    chaos = ChaosInjector(exhaust_at={2: 0}, release_at=(5,),
                          check_invariants=True)
    b = Batcher(model, params,
                ServeConfig(**{**BASE, **kw}, telemetry=True), chaos=chaos)
    for rid, p in _requests(cfg):
        b.submit(rid, p)
    results = b.run(max_new=max_new)
    return results, b


@pytest.fixture(scope="module")
def chaos_run(setup):
    return _chaos_run(setup)


# ---------------------------------------------------------------------------
# MetricsRegistry units
# ---------------------------------------------------------------------------

def test_registry_counters_gauges():
    m = MetricsRegistry()
    m.inc("a.b")
    m.inc("a.b", 4)
    assert m.value("a.b") == 5
    assert m.value("missing") == 0
    m.set_gauge("pool.free_pages", 7)
    assert m.gauge("pool.free_pages") == 7
    assert m.gauge("missing", -1) == -1


def test_registry_histogram_keeps_raw_samples():
    m = MetricsRegistry()
    for v in (0.3, 1.0, 0.01):
        m.observe("lat.x_s", v)
    assert m.count("lat.x_s") == 3
    assert m.sum("lat.x_s") == pytest.approx(1.31)
    # percentile must be the legacy _pct over the raw list, not a
    # bucket-interpolated estimate
    assert m.percentile("lat.x_s", 50) == _pct([0.3, 1.0, 0.01], 50)
    assert m.percentile("empty", 95) == 0.0
    # bucket counts track the same observations
    assert sum(m.hist("lat.x_s").counts) == 3


def test_registry_histogram_caps_reservoir():
    # the raw-sample reservoir is bounded: running count/sum stay exact
    # while the kept samples decimate deterministically past the cap
    from repro.serve.telemetry import _Histogram
    h = _Histogram(cap=64)
    n = 10_000
    for i in range(n):
        h.observe(float(i))
    assert h.count == n
    assert h.sum == pytest.approx(sum(range(n)))
    assert len(h.samples) <= 64
    # decimation is stride-based, so the survivors still span the range
    assert min(h.samples) < n * 0.1 and max(h.samples) > n * 0.8
    h.reset()
    assert h.count == 0 and h.sum == 0.0 and h.samples == []
    # percentiles over a capped registry hist remain order-of-magnitude
    # right (survivors are an evenly-strided subsample)
    m = MetricsRegistry()
    for i in range(n):
        m.observe("lat.x_s", float(i))
    assert m.count("lat.x_s") == n
    assert m.percentile("lat.x_s", 50) == pytest.approx(n / 2, rel=0.2)


def test_registry_reset_clears_counters_and_hists_keeps_gauges():
    m = MetricsRegistry()
    m.inc("c", 3)
    m.observe("h", 1.0)
    m.set_gauge("g", 2)
    m.reset()
    assert m.value("c") == 0
    assert m.count("h") == 0 and m.samples("h") == []
    assert m.gauge("g") == 2          # gauges describe current state


def test_registry_reset_gauges_opt_in():
    m = MetricsRegistry()
    m.set_gauge("pool.free_pages", 7)
    m.set_gauge("other.g", 1)
    m.clear_gauges("pool.")
    assert m.gauge("pool.free_pages", -1) == -1
    assert m.gauge("other.g") == 1
    m.reset(gauges=True)
    assert m.gauge("other.g", -1) == -1


def test_registry_snapshot_flat():
    m = MetricsRegistry()
    m.inc("spec.steps", 2)
    m.observe("lat.ttft_s", 0.5)
    m.set_gauge("pool.free_pages", 3)
    s = m.snapshot()
    assert s["spec.steps"] == 2
    assert s["pool.free_pages"] == 3
    assert s["lat.ttft_s.count"] == 1
    assert s["lat.ttft_s.p50"] == 0.5


# ---------------------------------------------------------------------------
# Tracer units
# ---------------------------------------------------------------------------

def test_tracer_timeline_sorted_and_copied():
    clock = iter(float(i) for i in range(100))
    tr = Tracer(clock=lambda: next(clock))
    tr.event("SUBMIT", 1, round=0)
    tr.event("ADMIT", 1, round=1, slot=0)
    tr.event("SUBMIT", 2, round=1)
    tl = tr.timeline(1)
    assert [e["kind"] for e in tl] == ["SUBMIT", "ADMIT"]
    tl[0]["kind"] = "corrupted"
    assert tr.events[0]["kind"] == "SUBMIT"      # copies, not aliases
    assert tr.rids() == [1, 2]


def test_tracer_span_contextmanager():
    ts = iter([0.0, 1.0, 3.0])
    tr = Tracer(clock=lambda: next(ts))
    with tr.span("join", round=4):
        pass
    (sp,) = tr.spans
    assert sp == {"name": "join", "round": 4, "t0": 1.0, "t1": 3.0}


# ---------------------------------------------------------------------------
# trace completeness on the real scheduler
# ---------------------------------------------------------------------------

def test_trace_complete_lifecycles(chaos_run):
    results, b = chaos_run
    tr = b.telemetry
    assert tr is not None
    rids = set(tr.rids()) - {None}
    assert rids == set(results)          # every request left a trace
    for rid in rids:
        tl = tr.timeline(rid)
        kinds = [e["kind"] for e in tl]
        assert kinds[0] == "SUBMIT"
        assert kinds[-1] == "RETIRE"
        assert kinds.count("RETIRE") == 1
        assert "FIRST_TOKEN" in kinds
        rounds = [e["round"] for e in tl]
        assert rounds == sorted(rounds), (rid, kinds, rounds)
        for e in tl:
            assert e["kind"] in LIFECYCLE_KINDS
            assert e["pool_free"] >= 0 and e["pages_held"] >= 0


def test_trace_preempt_resume_pairs(chaos_run):
    _, b = chaos_run
    tr = b.telemetry
    assert b.preemptions > 0             # the chaos run actually preempted
    preempted = [rid for rid in tr.rids()
                 if any(e["kind"] == "PREEMPT" for e in tr.timeline(rid))]
    assert preempted
    total_preempts = 0
    for rid in preempted:
        tl = tr.timeline(rid)
        kinds = [e["kind"] for e in tl]
        total_preempts += kinds.count("PREEMPT")
        # every PREEMPT is followed by a re-ADMIT then RESUME (or the
        # request retired… which cannot happen: recompute always resumes)
        for i, k in enumerate(kinds):
            if k == "PREEMPT":
                rest = kinds[i + 1:]
                assert "ADMIT" in rest and "RESUME" in rest, (rid, kinds)
                assert rest.index("ADMIT") < rest.index("RESUME")
        # a preempted rid's RESUME carries its prior decode progress
        resumes = [e for e in tl if e["kind"] == "RESUME"]
        assert all(e["prior_tokens"] >= 0 for e in resumes)
    assert total_preempts == b.preemptions


def test_trace_preempt_rid_moves_or_reuses_slot(chaos_run):
    _, b = chaos_run
    tr = b.telemetry
    for rid in tr.rids():
        tl = tr.timeline(rid)
        admits = [e for e in tl if e["kind"] == "ADMIT"]
        preempts = [e for e in tl if e["kind"] == "PREEMPT"]
        # one ADMIT per admission: initial + one per preemption
        assert len(admits) == 1 + len(preempts)
        for e in admits + preempts:
            assert e["slot"] is not None


def test_chaos_faults_land_in_trace(chaos_run):
    _, b = chaos_run
    tr = b.telemetry
    kinds = {e["kind"] for e in tr.events if e["rid"] is None}
    assert "CHAOS_HOLD" in kinds
    assert "CHAOS_RELEASE_HELD" in kinds
    assert kinds <= set(CHAOS_KINDS)
    hold = next(e for e in tr.events if e["kind"] == "CHAOS_HOLD")
    # pages may be 0 when the free list was already drained at round 2 —
    # the event recording the (attempted) raid is what matters
    assert hold["round"] == 2 and hold["pages"] >= 0
    assert hold["keep_free"] == 0


def test_pool_gauge_sampled(chaos_run):
    _, b = chaos_run
    tr = b.telemetry
    assert tr.pool_samples
    for _, counts in tr.pool_samples:
        assert set(counts) == {"free", "mapped", "cached", "preempted",
                               "held"}
        assert sum(counts.values()) == b.pool.n_pages
    # registry mirrors the last sample
    assert b.metrics.gauge("pool.free_pages") == tr.pool_samples[-1][1]["free"]


def test_scheduler_spans_per_round(chaos_run):
    _, b = chaos_run
    tr = b.telemetry
    names = {sp["name"] for sp in tr.spans}
    assert {"join", "decode-segment", "collect", "chaos"} <= names
    for sp in tr.spans:
        assert sp["t1"] >= sp["t0"]


# ---------------------------------------------------------------------------
# Perfetto export schema
# ---------------------------------------------------------------------------

def test_perfetto_schema_valid(chaos_run, tmp_path):
    _, b = chaos_run
    path = tmp_path / "trace.json"
    data = b.telemetry.to_perfetto(str(path))
    loaded = json.loads(path.read_text())
    assert loaded == data
    evs = loaded["traceEvents"]
    assert evs and loaded["displayTimeUnit"] == "ms"
    valid_ph = {"M", "X", "i", "C", "b", "e"}
    for e in evs:
        assert e["ph"] in valid_ph, e
        assert e["pid"] == 1
        if e["ph"] != "M":
            assert isinstance(e["ts"], float) and e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        if e["ph"] == "i":
            assert e["s"] in ("t", "p", "g")
        if e["ph"] in ("b", "e"):
            assert "id" in e
    # process/thread metadata present for every tid used
    tids_used = {e["tid"] for e in evs if "tid" in e and e["ph"] != "M"}
    tids_named = {e["tid"] for e in evs
                  if e["ph"] == "M" and e["name"] == "thread_name"}
    assert tids_used <= tids_named


def test_perfetto_slot_spans_never_overlap(chaos_run):
    _, b = chaos_run
    evs = b.telemetry.to_perfetto()["traceEvents"]
    by_tid: dict = {}
    for e in evs:
        if e["ph"] == "X" and e.get("cat") == "slot":
            by_tid.setdefault(e["tid"], []).append(e)
    assert by_tid                       # at least one slot track
    for tid, spans in by_tid.items():
        spans.sort(key=lambda e: e["ts"])
        for a, bsp in zip(spans, spans[1:]):
            assert a["ts"] + a["dur"] <= bsp["ts"] + 1e-6, (tid, a, bsp)


def test_perfetto_preempted_span_ends_with_preempt(chaos_run):
    _, b = chaos_run
    evs = b.telemetry.to_perfetto()["traceEvents"]
    slot_spans = [e for e in evs
                  if e["ph"] == "X" and e.get("cat") == "slot"]
    ended = {e["args"]["end"] for e in slot_spans}
    assert "PREEMPT" in ended and "RETIRE" in ended
    # the preempted rid re-appears in a later span (same or other slot)
    pre = next(e for e in slot_spans if e["args"]["end"] == "PREEMPT")
    rid = pre["args"]["rid"]
    later = [e for e in slot_spans
             if e["args"]["rid"] == rid and e["ts"] >= pre["ts"] + pre["dur"]]
    assert later and any(e["args"]["end"] == "RETIRE" for e in later)


def test_perfetto_queue_spans_balanced(chaos_run):
    _, b = chaos_run
    evs = b.telemetry.to_perfetto()["traceEvents"]
    opens = [e["id"] for e in evs if e["ph"] == "b"]
    closes = [e["id"] for e in evs if e["ph"] == "e"]
    assert sorted(opens) == sorted(closes)   # every queue span closed
    assert opens                             # and some existed


def test_perfetto_spec_commits_on_slot_tracks(setup):
    # speculation under trace: SPEC_COMMIT instants land on the slot
    # track of the committing slot with their accepted counts, and each
    # request's FIRST_TOKEN precedes its first SPEC_COMMIT (a draft can
    # only verify against an already-started decode)
    from repro.serve.telemetry import _TID_SLOT0
    cfg, model, params = setup
    b = Batcher(model, params,
                ServeConfig(max_len=96, batch=4, dtype=jnp.float32,
                            sync_every=4, paged=True, page_size=8,
                            speculate_k=3, telemetry=True))
    tok = int(np.random.default_rng(0).integers(0, cfg.vocab))
    for rid in range(3):
        b.submit(rid, [tok] * 12)
    b.run(max_new=12)
    commits = [e for e in b.telemetry.events if e["kind"] == "SPEC_COMMIT"]
    assert commits
    evs = b.telemetry.to_perfetto()["traceEvents"]
    marks = [e for e in evs if e["ph"] == "i" and e["name"] == "SPEC_COMMIT"]
    assert len(marks) == len(commits)
    for e in marks:
        slot = e["args"]["slot"]
        assert e["tid"] == _TID_SLOT0 + slot     # rides its slot's track
        assert e["args"]["accepted_drafts"] >= 0
        assert e["args"]["committed"] >= 1       # every step commits >= 1
    for rid in range(3):
        tl = b.telemetry.timeline(rid)
        kinds = [e["kind"] for e in tl]
        assert "FIRST_TOKEN" in kinds and "SPEC_COMMIT" in kinds
        assert kinds.index("FIRST_TOKEN") < kinds.index("SPEC_COMMIT")
        first = next(e for e in tl if e["kind"] == "FIRST_TOKEN")
        commit = next(e for e in tl if e["kind"] == "SPEC_COMMIT")
        assert first["t"] <= commit["t"]


# ---------------------------------------------------------------------------
# metrics vs legacy stats equivalence + reset
# ---------------------------------------------------------------------------

def test_metrics_match_legacy_stats(chaos_run):
    _, b = chaos_run
    m = b.metrics
    lat = b.latency_stats()
    assert lat["ttft_p50_s"] == _pct(b.ttfts, 50)
    assert lat["ttft_p95_s"] == m.percentile("lat.ttft_s", 95)
    assert lat["tpot_p50_s"] == m.percentile("lat.tpot_s", 50)
    assert lat["queue_wait_p95_s"] == m.percentile("lat.queue_wait_s", 95)
    assert lat["preemptions"] == m.value("preempt.count") == b.preemptions
    assert lat["requests"] == m.count("lat.ttft_s")
    k = b.preempt_stats()
    assert k["preemptions"] == m.value("preempt.count")
    assert k["recompute_tokens"] == m.value("preempt.recompute_tokens")
    j = b.join_stats()
    assert j["joins"] == m.count("join.seconds")
    assert j["max_join_s"] == (max(m.samples("join.seconds"))
                               if m.count("join.seconds") else 0.0)
    p = b.prefix_stats()
    assert p["prefill_computed"] == m.value("prefill.computed_tokens")
    assert p["prefill_skipped"] == m.value("prefill.skipped_tokens")


def test_spec_metrics_match_legacy(setup):
    cfg, model, params = setup
    b = Batcher(model, params,
                ServeConfig(max_len=96, batch=4, dtype=jnp.float32,
                            sync_every=4, paged=True, page_size=8,
                            speculate_k=3, telemetry=True))
    tok = int(np.random.default_rng(0).integers(0, cfg.vocab))
    for rid in range(3):
        b.submit(rid, [tok] * 12)
    b.run(max_new=12)
    m = b.metrics
    s = b.spec_stats()
    assert b.spec_steps == m.value("spec.steps") > 0
    assert b.spec_accepted == m.value("spec.accepted")
    assert s["acceptance_rate"] == pytest.approx(
        m.value("spec.accepted") / max(1, m.value("spec.proposed")))
    # SPEC_COMMIT events carry the same totals as the counters
    commits = [e for e in b.telemetry.events if e["kind"] == "SPEC_COMMIT"]
    assert sum(e["committed"] for e in commits) == b.spec_emitted
    assert sum(e["accepted_drafts"] for e in commits) == b.spec_accepted


def test_reset_stats_clears_everything(setup):
    results, b = _chaos_run(setup)
    assert b.preemptions > 0 and b.ttfts and b.queue_waits
    b.kv_samples = [0.5]
    b.reset_stats()
    assert b.ttfts == [] and b.tpots == [] and b.queue_waits == []
    assert b.join_times == [] and b.kv_samples == []
    assert b.preemptions == 0 and b.preempted_token_recompute == 0
    assert b.prefill_computed == 0 and b.prefill_skipped == 0
    assert b.spec_steps == 0 and b.chunk_joins == 0
    assert b.budget_deferrals == 0
    assert not b._first_tok_t
    assert b.preempt_events == [] and b.preempted_rids == set()
    assert b.latency_stats()["ttft_p50_s"] == 0.0
    assert b.join_stats()["joins"] == 0


# ---------------------------------------------------------------------------
# zero-overhead-off contract
# ---------------------------------------------------------------------------

def test_telemetry_off_by_default(setup):
    cfg, model, params = setup
    b = Batcher(model, params, ServeConfig(**BASE))
    assert b.telemetry is None
    assert b.pool.gauge_cb is None       # no per-mutation callback wired
    for rid, p in _requests(cfg, n=2):
        b.submit(rid, p)
    results = b.run(max_new=4)
    assert all(len(v) == 4 for v in results.values())
    # metrics still accumulate (they are the *_stats substrate)
    assert b.metrics.count("lat.ttft_s") == 2


def test_traced_off_equals_untraced_tokens(setup):
    # tracing must observe, not perturb: same greedy tokens either way
    res_on, _ = _chaos_run(setup)
    cfg, model, params = setup
    chaos = ChaosInjector(exhaust_at={2: 0}, release_at=(5,),
                          check_invariants=True)
    b = Batcher(model, params, ServeConfig(**BASE), chaos=chaos)
    for rid, p in _requests(cfg):
        b.submit(rid, p)
    res_off = b.run(max_new=10)
    assert res_on == res_off


# ---------------------------------------------------------------------------
# kernel timing hooks
# ---------------------------------------------------------------------------

def test_kernel_hooks_off_record_nothing():
    from repro.kernels.paged_attn import attn_telemetry, paged_attn
    tel = attn_telemetry()
    tel.disable()
    tel.reset()
    rng = np.random.default_rng(0)
    kp = jnp.asarray(rng.normal(size=(4, 4, 2, 8)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)
    tbl = jnp.zeros((2, 2), jnp.int32)
    ln = jnp.asarray([3, 5], jnp.int32)
    paged_attn(q, kp, kp, tbl, ln)
    assert tel.stats == {}


def test_kernel_hooks_record_ops_routes():
    from repro.kernels.paged_attn import (attn_telemetry, paged_attn,
                                          paged_attn_xla,
                                          paged_prefill_attn,
                                          paged_verify_attn)
    tel = attn_telemetry()
    tel.reset()
    tel.enable()
    try:
        rng = np.random.default_rng(0)
        kp = jnp.asarray(rng.normal(size=(6, 4, 2, 8)), jnp.float32)
        tbl = jnp.asarray(rng.integers(0, 6, size=(2, 3)), jnp.int32)
        ln = jnp.asarray([5, 9], jnp.int32)
        q1 = jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)
        q3 = jnp.asarray(rng.normal(size=(2, 3, 4, 8)), jnp.float32)
        paged_attn(q1, kp, kp, tbl, ln)
        paged_attn_xla(q1, kp, kp, tbl, ln)
        paged_prefill_attn(q3, kp, kp, tbl, ln - 3, ln)
        paged_verify_attn(q3, kp, kp, tbl, ln, ln)
        snap = tel.snapshot()
        assert snap["decode.kernel"]["calls"] == 1
        assert snap["decode.kernel"]["tokens"] == 2       # B=2, Lq=1
        assert snap["decode.xla"]["calls"] == 1
        ops = {k.split(".")[0] for k in snap}
        assert {"decode", "prefill", "verify"} <= ops
        # eager calls are timed; none were traced
        for v in snap.values():
            assert v["traced_calls"] == 0 and v["wall_s"] > 0.0
    finally:
        tel.disable()
        tel.reset()


def test_kernel_hooks_traced_counted_not_timed():
    from repro.kernels.paged_attn import attn_telemetry, paged_prefill_attn
    tel = attn_telemetry()
    tel.reset()
    tel.enable()
    try:
        rng = np.random.default_rng(0)
        kp = jnp.asarray(rng.normal(size=(6, 4, 2, 8)), jnp.float32)
        tbl = jnp.asarray(rng.integers(0, 6, size=(2, 3)), jnp.int32)
        ln = jnp.asarray([5, 9], jnp.int32)
        q3 = jnp.asarray(rng.normal(size=(2, 3, 4, 8)), jnp.float32)
        f = jax.jit(lambda q: paged_prefill_attn(q, kp, kp, tbl,
                                                 ln - 3, ln))
        f(q3).block_until_ready()
        f(q3).block_until_ready()        # compile cache: no re-trace
        snap = tel.snapshot()
        (row,) = snap.values()
        assert row["calls"] == row["traced_calls"] == 1
        assert row["wall_s"] == 0.0      # never timed under trace
        # traced calls still contribute analytic traffic (full sliced
        # table assumed live) but no timed bytes -> no achieved GB/s
        assert row["bytes"] > 0.0 and row["flops"] > 0.0
        assert row["timed_bytes"] == 0.0
        assert row["achieved_gbps"] == 0.0
    finally:
        tel.disable()
        tel.reset()


def test_kernel_roofline_all_ops_on_kernel_route():
    # acceptance: nonzero achieved GB/s and op/byte for decode, prefill
    # and verify on the *kernel* route (policy-forced, interpret mode)
    from repro.kernels.decode_attn import decode_attn_policy
    from repro.kernels.paged_attn import (amenability_reports,
                                          attn_telemetry, paged_attn,
                                          paged_prefill_attn,
                                          paged_verify_attn)
    tel = attn_telemetry()
    tel.reset()
    tel.enable()
    try:
        rng = np.random.default_rng(0)
        kp = jnp.asarray(rng.normal(size=(6, 4, 2, 8)), jnp.float32)
        tbl = jnp.asarray(rng.integers(0, 6, size=(2, 3)), jnp.int32)
        ln = jnp.asarray([5, 9], jnp.int32)
        q1 = jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)
        q3 = jnp.asarray(rng.normal(size=(2, 3, 4, 8)), jnp.float32)
        with decode_attn_policy(mode="kernel", interpret=True):
            paged_attn(q1, kp, kp, tbl, ln, interpret=True)
            paged_prefill_attn(q3, kp, kp, tbl, ln - 3, ln)
            paged_verify_attn(q3, kp, kp, tbl, ln, ln)
        snap = tel.snapshot()
        for op in ("decode", "prefill", "verify"):
            row = snap[f"{op}.kernel"]
            assert row["achieved_gbps"] > 0.0, (op, row)
            assert row["op_byte"] > 0.0, (op, row)
            assert row["timed_bytes"] == row["bytes"] > 0.0
        # dead-page subtraction: slot 0 (5 live tokens, page_size 4)
        # touches 2 of its 3 table pages in decode, slot 1 all 3 — the
        # K+V page traffic must reflect 5 live pages, not 6
        page_bytes = 4 * 2 * 8 * 4 * 2            # ps*Hkv*D*itemsize*(K+V)
        q_bytes = 2 * 2 * 4 * 8 * 4               # Q read + O write
        tbl_bytes = 2 * 3 * 4
        assert snap["decode.kernel"]["bytes"] == pytest.approx(
            5 * page_bytes + q_bytes + tbl_bytes)
        # attention is memory-bound at these shapes: the paper's test
        # must judge every measured op bandwidth-limited (char A holds)
        reports = amenability_reports()
        assert set(reports) == {"decode", "prefill", "verify"}
        for rep in reports.values():
            assert rep.characteristics[0].passed    # low op/byte
            assert rep.verdict.value in ("amenable", "conditional")
    finally:
        tel.disable()
        tel.reset()


# ---------------------------------------------------------------------------
# check_bench trace gate
# ---------------------------------------------------------------------------

def _load_check_bench():
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(os.path.dirname(__file__), os.pardir,
                                    "scripts", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_gate_pass_and_fail(chaos_run, tmp_path):
    cb = _load_check_bench()
    _, b = chaos_run
    good = tmp_path / "good.json"
    b.telemetry.to_perfetto(str(good))
    assert cb.check_trace(str(good)) == 0
    # empty trace fails
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": []}')
    assert cb.check_trace(str(bad)) > 0
    # a submitted-but-never-retired rid fails
    data = json.loads(good.read_text())
    data["traceEvents"] = [e for e in data["traceEvents"]
                           if e.get("name") != "RETIRE"]
    lost = tmp_path / "lost.json"
    lost.write_text(json.dumps(data))
    assert cb.check_trace(str(lost)) > 0
    # unparseable fails without raising
    garbled = tmp_path / "garbled.json"
    garbled.write_text("{not json")
    assert cb.check_trace(str(garbled)) == 1
