import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.vector_sum import vector_sum
from repro.kernels.vector_sum.ref import vector_sum_ref


@pytest.mark.parametrize("n", [1, 7, 512, 4096, 10_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vector_sum(n, dtype):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.standard_normal(n), dtype)
    b = jnp.asarray(rng.standard_normal(n), dtype)
    out = vector_sum(a, b)
    ref = vector_sum_ref(a, b)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_vector_sum_nd():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((3, 5, 7)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 5, 7)), jnp.float32)
    np.testing.assert_allclose(vector_sum(a, b), vector_sum_ref(a, b),
                               rtol=1e-6)
