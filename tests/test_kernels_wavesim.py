import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wavesim_flux import flux1d
from repro.kernels.wavesim_flux.ref import flux1d_ref
from repro.kernels.wavesim_volume import volume
from repro.kernels.wavesim_volume.ref import volume_ref


@pytest.mark.parametrize("e,f", [(1, 1), (8, 9), (65, 27), (256, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_volume_sweep(e, f, dtype):
    rng = np.random.default_rng(e * 31 + f)
    u = jnp.asarray(rng.standard_normal((e, f, 3, 3, 3)), dtype)
    out = volume(u, 0.7)
    ref = volume_ref(u, 0.7)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("e,t", [(4, 9), (256, 36), (600, 27), (1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flux_sweep(e, t, dtype):
    rng = np.random.default_rng(e + t)
    hi = jnp.asarray(rng.standard_normal((e, t)), dtype)
    lo = jnp.asarray(rng.standard_normal((e, t)), dtype)
    fh, fl = flux1d(hi, lo, 0.5)
    rh, rl = flux1d_ref(hi, lo, 0.5)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(fh, np.float32),
                               np.asarray(rh, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(fl, np.float32),
                               np.asarray(rl, np.float32), rtol=tol,
                               atol=tol)


def test_volume_is_linear_operator():
    """Property: volume(au + bv) == a*volume(u) + b*volume(v)."""
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.standard_normal((4, 9, 3, 3, 3)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4, 9, 3, 3, 3)), jnp.float32)
    lhs = volume(2.0 * u + 3.0 * v)
    rhs = 2.0 * volume(u) + 3.0 * volume(v)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)
