"""Shared-prefix radix cache over the paged pool: suffix-only prefill must
be invisible in the tokens.  Cache-on output matches the cache-off paged
engine (and the dense step-by-step reference) token-for-token across
mixed suffix lengths, EOS mid-batch, refills re-hitting the cache, and
eviction under a constrained pool — while the stats prove prefill work
was actually skipped and the allocator/radix invariants hold throughout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig
from repro.serve.reference import reference_decode
from repro.serve.scheduler import Batcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    rng = np.random.default_rng(11)
    system = rng.integers(0, cfg.vocab, size=17).tolist()  # 2 full pages @ 8
    # mixed suffix lengths, including one page-aligned total prompt (24)
    requests = [(i, system + rng.integers(0, cfg.vocab, size=n).tolist())
                for i, n in enumerate([1, 4, 7, 2])]
    return cfg, model, params, requests


def _run(model, params, scfg, requests, max_new, eos_id=None):
    b = Batcher(model, params, scfg, eos_id=eos_id)
    for rid, p in requests:
        b.submit(rid, p)
    return b.run(max_new=max_new), b


def _assert_drained(b):
    """Post-drain pool state: nothing mapped, cached pages are the only
    thing off the free list, and every invariant holds."""
    assert b.pool.used_pages == 0
    assert b.pool.free_pages + b.pool.cached_pages == b.pool.n_pages
    assert int(b.pool.refcount.sum()) == 0
    b.prefix.check()          # includes pool.check()


def test_prefix_parity_and_skipped_prefill(setup):
    """Cache on == cache off, token for token, with a real token hit rate
    (the shared pages mean most prompts prefill only their suffix)."""
    cfg, model, params, requests = setup
    base = dict(max_len=64, batch=4, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8)
    off, _ = _run(model, params, ServeConfig(**base), requests, max_new=12)
    on, b = _run(model, params, ServeConfig(**base, prefix_cache=True),
                 requests, max_new=12)
    for rid, _ in requests:
        assert on[rid] == off[rid], (rid, on[rid], off[rid])
        assert len(on[rid]) == 12
    s = b.prefix_stats()
    assert s["hits"] == 3                 # all but the first admission
    assert s["prefill_skipped"] == 3 * 16  # two shared pages per hit
    assert s["hit_rate"] > 0.5
    _assert_drained(b)


def test_prefix_parity_vs_dense_reference(setup):
    """The cached path also matches the schedule-free dense reference —
    sharing composes with the paged engine, not just mirrors it."""
    cfg, model, params, requests = setup
    scfg = ServeConfig(max_len=64, batch=4, dtype=jnp.float32, sync_every=4,
                       paged=True, page_size=8, prefix_cache=True)
    ref = reference_decode(model, params, scfg, requests, max_new=10)
    got, b = _run(model, params, scfg, requests, max_new=10)
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
    _assert_drained(b)


def test_prefix_refills_rehit_cache(setup):
    """More requests than slots: refills between segments re-hit the
    radix (the prefix pages survive their first holders' retirement in
    the evictable-cached state) and outputs stay schedule-independent."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(3)
    system = rng.integers(0, cfg.vocab, size=16).tolist()
    requests = [(i, system + rng.integers(
        0, cfg.vocab, size=int(rng.integers(1, 6))).tolist())
        for i in range(7)]
    base = dict(max_len=64, batch=2, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8)
    off, _ = _run(model, params, ServeConfig(**base), requests, max_new=8)
    on, b = _run(model, params, ServeConfig(**base, prefix_cache=True),
                 requests, max_new=8)
    for rid, _ in requests:
        assert on[rid] == off[rid], (rid, on[rid], off[rid])
    s = b.prefix_stats()
    assert s["hits"] == 6                 # every admission after the first
    assert s["evicted_pages"] == 0        # pool was never under pressure
    _assert_drained(b)


def test_prefix_eos_mid_batch(setup):
    """EOS retirement mid-batch releases the retiring slot's private pages
    while its shared prefix pages stay resident for the cache — parity
    with the cache-off engine is unchanged."""
    cfg, model, params, requests = setup
    base = dict(max_len=64, batch=4, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8)
    free, _ = _run(model, params, ServeConfig(**base), requests, max_new=12)
    eos = free[requests[0][0]][4]
    off, _ = _run(model, params, ServeConfig(**base), requests, max_new=12,
                  eos_id=eos)
    assert any(len(v) < 12 for v in off.values())
    on, b = _run(model, params, ServeConfig(**base, prefix_cache=True),
                 requests, max_new=12, eos_id=eos)
    for rid, _ in requests:
        assert on[rid] == off[rid], (rid, on[rid], off[rid])
    _assert_drained(b)


def test_prefix_eviction_under_constrained_pool(setup):
    """Two alternating system prompts through a pool too small to cache
    both: admission pressure reclaims cached pages (LRU, leaf-first) and
    the outputs still match the cache-off engine exactly."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(9)
    sys_a = rng.integers(0, cfg.vocab, size=16).tolist()
    sys_b = rng.integers(0, cfg.vocab, size=16).tolist()
    requests = [(i, (sys_a if i % 2 == 0 else sys_b) + rng.integers(
        0, cfg.vocab, size=int(rng.integers(1, 5))).tolist())
        for i in range(6)]
    base = dict(max_len=64, batch=1, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8, total_pages=4)
    off, _ = _run(model, params, ServeConfig(**base), requests, max_new=6)
    on, b = _run(model, params, ServeConfig(**base, prefix_cache=True),
                 requests, max_new=6)
    for rid, _ in requests:
        assert on[rid] == off[rid], (rid, on[rid], off[rid])
    assert b.prefix_stats()["evicted_pages"] > 0
    _assert_drained(b)


def test_prefix_same_round_hit(setup):
    """Two identical-prefix prompts admitted in the *same* refill round:
    the second matches pages the first is about to fill in the very same
    join call (per layer the pooled scatter precedes the gather), so the
    hit happens with zero intervening decode steps."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(4)
    system = rng.integers(0, cfg.vocab, size=16).tolist()
    requests = [(i, system + rng.integers(
        0, cfg.vocab, size=int(rng.integers(1, 5))).tolist())
        for i in range(3)]
    base = dict(max_len=64, batch=3, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8)
    off, _ = _run(model, params, ServeConfig(**base), requests, max_new=8)
    on, b = _run(model, params, ServeConfig(**base, prefix_cache=True),
                 requests, max_new=8)
    for rid, _ in requests:
        assert on[rid] == off[rid], (rid, on[rid], off[rid])
    # all three joined in one round; 2 and 3 still hit pages written by 1
    assert b.prefix_stats()["hits"] == 2
    _assert_drained(b)


def test_prefix_mla_suffix_prefill():
    """The suffix-only prefill also covers MLA's latent cache: resuming a
    paged prefill at depth 8 reproduces the one-shot prefill's logits."""
    cfg = get_config("deepseek-v3-671b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    b, plen, ps = 2, 12, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, plen)), jnp.int32)
    n_pages = b * 4
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, -1)
    caches = model.init_paged_caches(b, n_pages, ps, jnp.float32)
    logits_full, _ = model.prefill_paged(
        params, {"tokens": toks}, caches, table, dtype=jnp.float32)
    # two-phase: prefix pages first, then the suffix at cache_len=8
    caches = model.init_paged_caches(b, n_pages, ps, jnp.float32)
    _, caches = model.prefill_paged(
        params, {"tokens": toks[:, :8]}, caches, table, dtype=jnp.float32)
    logits_sfx, _ = model.prefill_paged(
        params, {"tokens": toks[:, 8:]}, caches, table, dtype=jnp.float32,
        cache_len=jnp.full((b,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(logits_sfx[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_prefix_requires_paged_and_rejects_ssm(setup):
    cfg, model, params, _ = setup
    with pytest.raises(ValueError, match="paged"):
        Batcher(model, params,
                ServeConfig(max_len=64, batch=2, prefix_cache=True))
    mcfg = get_config("mamba2-370m").reduced()
    mmodel = Model(mcfg)
    mparams = pm.unwrap(mmodel.init(jax.random.key(0)))
    with pytest.raises(ValueError, match="SSM"):
        Batcher(mmodel, mparams,
                ServeConfig(max_len=64, batch=2, paged=True, page_size=8,
                            prefix_cache=True))
