"""Overload protection: deadlines/cancellation, the degradation ladder,
and the progress watchdog must shed load *chosen, bounded, reversibly* —
and stay invisible in the tokens of every request that completes.

Covers the pure policy layer (``DegradationController`` hysteresis /
severity / time-in-state, ``project_finish_s`` abstention,
``Watchdog`` re-arm) with no model in the loop, then the scheduler's
actions: client/deadline/timeout cancellation from the queue and
mid-flight (pages released, allocator invariants green), RETRY_AFTER
shed rejections, deadline-attainment accounting at retire *and* cancel,
the CANCEL/DEGRADE/WATCHDOG trace events, the chaos ``stall_at`` /
``burst_at`` drills (watchdog trips, dumps the flight bundle via the
``$REPRO_FLIGHT_PATH`` override, and the run still finishes), and
bit-exact parity of completing requests under active degradation.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.chaos import ChaosInjector
from repro.serve.engine import ServeConfig
from repro.serve.overload import (DEGRADED, HEALTHY, RETRY_AFTER,
                                  SHEDDING, DegradationController,
                                  Watchdog, project_finish_s)
from repro.serve.scheduler import Batcher
from repro.serve.telemetry import MetricsRegistry


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=6, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, total_pages=24,
            admission_mode="optimistic")


def _requests(cfg, n=6, lo=8, hi=14, seed=1):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, cfg.vocab,
                             size=int(rng.integers(lo, hi))).tolist())
            for i in range(n)]


def _batcher(model, params, chaos=None, **kw):
    return Batcher(model, params, ServeConfig(**{**BASE, **kw}),
                   chaos=chaos)


# ---------------------------------------------------------------------------
# DegradationController: hysteresis state machine (pure host policy)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _observe_n(ctl, n, **sig):
    for r in range(n):
        ctl.observe(round=r, **sig)
    return ctl.state


def test_controller_climbs_and_descends_with_hysteresis():
    ctl = DegradationController(up_rounds=2, down_rounds=3)
    # one hot round is not enough; the cool round resets the streak
    ctl.observe(burn=1.5, pressure=0.0, queue_depth=0)
    ctl.observe(burn=0.0, pressure=0.0, queue_depth=0)
    ctl.observe(burn=1.5, pressure=0.0, queue_depth=0)
    assert ctl.state == HEALTHY
    assert _observe_n(ctl, 2, burn=1.5, pressure=0.0,
                      queue_depth=0) == DEGRADED
    # severity 2 climbs DEGRADED -> SHEDDING, again after up_rounds
    assert _observe_n(ctl, 2, burn=2.5, pressure=0.0,
                      queue_depth=0) == SHEDDING
    # recovery is deliberate: down_rounds per rung, two rungs down
    assert _observe_n(ctl, 3, burn=0.0, pressure=0.0,
                      queue_depth=0) == DEGRADED
    assert not ctl.recovered_to_healthy
    assert _observe_n(ctl, 3, burn=0.0, pressure=0.0,
                      queue_depth=0) == HEALTHY
    assert ctl.recovered_to_healthy
    assert [(f, t) for _, f, t, _, _ in ctl.transitions] == [
        (HEALTHY, DEGRADED), (DEGRADED, SHEDDING),
        (SHEDDING, DEGRADED), (DEGRADED, HEALTHY)]


def test_controller_severity_pressure_needs_queue_for_shed():
    ctl = DegradationController()
    # a full pool with an empty queue is not starvation: severity 1
    assert ctl.severity(burn=0.0, pressure=1.0, queue_depth=0) == 1
    assert ctl.severity(burn=0.0, pressure=1.0, queue_depth=3) == 2
    assert ctl.severity(burn=0.95, pressure=0.0, queue_depth=9) == 0


def test_controller_rung_properties():
    ctl = DegradationController(up_rounds=1)
    assert not (ctl.shed_speculation or ctl.shrink_chunk
                or ctl.freeze_growth or ctl.shedding)
    ctl.observe(burn=1.5, pressure=0.0, queue_depth=0)
    assert ctl.state == DEGRADED
    assert ctl.shed_speculation and ctl.shrink_chunk
    assert not ctl.freeze_growth and not ctl.shedding
    ctl.observe(burn=2.5, pressure=0.0, queue_depth=0)
    assert ctl.state == SHEDDING
    assert ctl.freeze_growth and ctl.shedding


def test_controller_time_in_state_and_reset():
    clk = FakeClock()
    ctl = DegradationController(up_rounds=1, clock=clk)
    clk.t = 5.0
    ctl.observe(burn=1.5, pressure=0.0, queue_depth=0)   # -> DEGRADED at 5
    clk.t = 7.0
    tis = ctl.stats()["time_in_state"]
    assert tis[HEALTHY] == pytest.approx(5.0)
    assert tis[DEGRADED] == pytest.approx(2.0)           # open interval
    ctl.reset()
    assert ctl.state == DEGRADED                         # rung survives
    assert ctl.stats()["time_in_state"][DEGRADED] == pytest.approx(0.0)
    assert not ctl.stats()["transitions"]
    assert not ctl.recovered_to_healthy


def test_controller_validates_thresholds():
    with pytest.raises(ValueError, match="rounds"):
        DegradationController(up_rounds=0)
    with pytest.raises(ValueError, match="degrade_burn"):
        DegradationController(degrade_burn=3.0, shed_burn=2.0)
    with pytest.raises(ValueError, match="degrade_pressure"):
        DegradationController(degrade_pressure=0.9, shed_pressure=0.5)


# ---------------------------------------------------------------------------
# project_finish_s: abstains without samples, optimistic with them
# ---------------------------------------------------------------------------

def test_projection_abstains_without_samples():
    m = MetricsRegistry()
    assert project_finish_s(m, 10, queued=True) is None
    assert project_finish_s(m, 10, queued=False) is None
    # a TTFT mean alone is enough for the queued estimate (decode term
    # falls back to zero — still optimistic, never pessimistic)
    m.observe("lat.ttft_s", 2.0)
    assert project_finish_s(m, 10, queued=True) == pytest.approx(2.0)
    assert project_finish_s(m, 10, queued=False) is None


def test_projection_uses_observed_means():
    m = MetricsRegistry()
    m.observe("lat.ttft_s", 1.0)
    m.observe("lat.ttft_s", 3.0)
    m.observe("lat.tpot_s", 0.5)
    assert project_finish_s(m, 5, queued=True) == pytest.approx(
        2.0 + 4 * 0.5)
    assert project_finish_s(m, 5, queued=False) == pytest.approx(2.5)
    assert project_finish_s(m, 0, queued=False) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# Watchdog: trip once per `limit` unchanged rounds, then re-arm
# ---------------------------------------------------------------------------

def test_watchdog_trips_and_rearms():
    wd = Watchdog(limit=3)
    assert not wd.tick((1,))
    assert not wd.tick((2,))          # progress: counter resets
    assert not wd.tick((2,))
    assert not wd.tick((2,))
    assert wd.tick((2,))              # 3rd unchanged round: trip
    assert wd.trips == 1
    assert not wd.tick((2,))          # re-armed: counting again
    assert not wd.tick((2,))
    assert wd.tick((2,))
    assert wd.trips == 2
    with pytest.raises(ValueError, match=">= 1"):
        Watchdog(limit=0)


def test_watchdog_rounds_config_validated(setup):
    cfg, model, params = setup
    with pytest.raises(ValueError, match="watchdog"):
        _batcher(model, params, watchdog_rounds=0)


# ---------------------------------------------------------------------------
# cancellation: queue-side, mid-flight, reason codes, accounting
# ---------------------------------------------------------------------------

def test_client_cancel_queued_request(setup):
    cfg, model, params = setup
    b = _batcher(model, params)
    b.submit(0, [1, 2, 3])
    b.submit(1, [4, 5, 6])
    assert b.cancel(0)
    assert b.cancelled[0] == "client"
    assert [rid for rid, _ in b.queue] == [1]
    assert not b.cancel(0)            # already terminal
    assert not b.cancel(99)           # never submitted
    assert b.overload_stats()["cancelled_by_reason"]["client"] == 1


def test_cancel_rejects_unknown_reason(setup):
    cfg, model, params = setup
    b = _batcher(model, params)
    b.submit(0, [1, 2, 3])
    with pytest.raises(ValueError, match="cancel reason"):
        b.cancel(0, reason="bored")


def test_midflight_cancel_releases_pages(setup):
    """Plant a live-looking slot (the victim-policy test idiom) and
    cancel it: pages drain through ``_release_slot``, the device row is
    done-latched, and the allocator invariant stays green."""
    cfg, model, params = setup
    b = _batcher(model, params)
    b.pool.reserve(2, 32)
    b.slot_rid[2] = 7
    b.slot_prompt[2] = list(range(32))
    b.slot_len[2] = 32
    b.slot_filled[2] = 32
    b.slot_max_tokens[2] = 48
    used = b.pool.used_pages
    assert used > 0
    assert b.cancel(7)
    assert b.cancelled[7] == "client"
    assert b.slot_rid[2] is None
    assert b.pool.used_pages == 0
    assert bool(b.done[2])
    b.pool.check()


def test_deadline_zero_cancels_before_any_work(setup):
    """An already-expired deadline is swept at the first round: the
    request is a scored miss, unstamped peers are untouched."""
    cfg, model, params = setup
    b = _batcher(model, params)
    reqs = _requests(cfg, n=3)
    b.submit(reqs[0][0], reqs[0][1], deadline_s=0.0)
    for rid, p in reqs[1:]:
        b.submit(rid, p)
    results = b.run(max_new=4)
    assert b.cancelled[0] == "deadline"
    assert 0 not in results
    assert sorted(results) == [1, 2]
    st = b.overload_stats()
    assert st["deadline_total"] == 1 and st["deadline_met"] == 0
    assert st["deadline_attainment"] == 0.0
    b.pool.check()
    assert b.pool.used_pages == 0


def test_timeout_beats_deadline_as_reason(setup):
    cfg, model, params = setup
    b = _batcher(model, params)
    b.submit(0, [1, 2, 3, 4], deadline_s=0.0, timeout_s=0.0)
    b.submit(1, [5, 6, 7, 8])
    b.run(max_new=4)
    assert b.cancelled[0] == "timeout"
    # a timeout on a deadline-stamped request is still a scored miss
    assert b.overload_stats()["deadline_total"] == 1


def test_generous_deadlines_all_met(setup):
    cfg, model, params = setup
    b = _batcher(model, params)
    reqs = _requests(cfg, n=4)
    for rid, p in reqs:
        b.submit(rid, p, deadline_s=600.0)
    results = b.run(max_new=4)
    assert sorted(results) == [r for r, _ in reqs]
    st = b.overload_stats()
    assert st["deadline_total"] == 4 and st["deadline_met"] == 4
    assert st["deadline_attainment"] == 1.0
    assert not b.cancelled


def test_cancel_traced_and_perfetto_terminal(tmp_path, setup):
    """The CANCEL event lands on the rid's timeline with its reason, and
    the Perfetto export closes the queue span on it (a cancelled request
    is terminal, not a dangling open span)."""
    cfg, model, params = setup
    b = _batcher(model, params, telemetry=True)
    b.submit(0, [1, 2, 3], deadline_s=0.0)
    b.submit(1, [4, 5, 6])
    b.run(max_new=3)
    ev = [e for e in b.telemetry.timeline(0) if e["kind"] == "CANCEL"]
    assert len(ev) == 1 and ev[0]["reason"] == "deadline"
    out = tmp_path / "trace.json"
    b.telemetry.to_perfetto(str(out))
    data = json.loads(out.read_text())
    names = [(e.get("ph"), e.get("name")) for e in data["traceEvents"]]
    assert ("i", "CANCEL") in names
    # queue async span for rid 0 opened and closed
    q = [e["ph"] for e in data["traceEvents"]
         if e.get("id") == 0 and e["ph"] in ("b", "e")]
    assert q.count("b") == q.count("e") >= 1


def test_attribution_carries_cancel_reason(setup):
    """A cancelled-after-first-token request attributes like a retired
    one, with ``cancelled`` naming the reason (synthetic timeline — the
    attribution layer is pure arithmetic over the trace)."""
    from repro.serve.attribution import explain
    from repro.serve.telemetry import Tracer
    tr = Tracer()
    tr.event("SUBMIT", 5, t=0.0, prompt_tokens=3)
    tr.event("ADMIT", 5, t=0.5, slot=0)
    tr.event("FIRST_TOKEN", 5, t=1.0, slot=0, token=9, ttft_s=1.0)
    tr.event("CANCEL", 5, t=1.5, slot=0, reason="timeout")
    a = explain(tr, 5)
    assert a is not None and a.cancelled == "timeout"
    a.check()


# ---------------------------------------------------------------------------
# shedding: RETRY_AFTER ledger, priority order, resume protection
# ---------------------------------------------------------------------------

def test_shed_queued_lowest_priority_first_with_retry_after(setup):
    cfg, model, params = setup
    b = _batcher(model, params, overload=True, overload_queue_keep=2)
    for rid, prio in ((0, 1), (1, 0), (2, 0), (3, 2)):
        b.submit(rid, [1, 2, 3], priority=prio)
    b._resumed.add(2)                 # a paid-for resume: never shed
    b.overload.state = SHEDDING
    b._shed_queued()
    assert [rid for rid, _ in b.queue] == [2, 3]
    # the unprotected class-0 request goes first, then class-1; the
    # resumed class-0 request and the class-2 one survive
    assert set(b.cancelled) == {0, 1}
    assert all(v == "shed" for v in b.cancelled.values())
    st = b.overload_stats()
    assert st["shed_requests"] == 2
    assert [r["status"] for r in st["rejections"]] == [RETRY_AFTER] * 2
    assert all(r["retry_after_s"] > 0 for r in st["rejections"])
    # shed is excluded from the deadline ledger
    assert st["deadline_total"] == 0


# ---------------------------------------------------------------------------
# watchdog drill: chaos stall -> trip -> flight bundle -> force-shed
# ---------------------------------------------------------------------------

def test_watchdog_drill_sheds_and_dumps_bundle(tmp_path, setup,
                                               monkeypatch):
    """The deterministic livelock drill: a chaos ``stall_at`` freezes
    the round body past the watchdog bound.  The run must NOT raise —
    it dumps the flight bundle (via the $REPRO_FLIGHT_PATH env
    override), sheds the blocking head, and finishes what remains."""
    cfg, model, params = setup
    bundle = tmp_path / "stall_bundle.json"
    monkeypatch.setenv("REPRO_FLIGHT_PATH", str(bundle))
    reqs = _requests(cfg, n=5)
    chaos = ChaosInjector(stall_at={2: 12}, check_invariants=True)
    b = _batcher(model, params, chaos=chaos, watchdog_rounds=4)
    for rid, p in reqs:
        b.submit(rid, p)
    results = b.run(max_new=6)                 # must not raise
    st = b.overload_stats()
    assert st["watchdog_trips"] >= 1
    assert st["cancelled_by_reason"]["shed"] >= 1
    # every request is accounted for: retired or shed, none lost
    assert set(results) | set(b.cancelled) == {r for r, _ in reqs}
    assert set(results).isdisjoint(b.cancelled)
    # the bundle landed on disk through the env override and names the
    # stall (not a generic RuntimeError)
    data = json.loads(bundle.read_text())
    assert data["schema"] == 1
    assert "WatchdogStall" in data["error"]
    assert data["pool"] is not None
    assert b.last_flight_bundle["error"] == data["error"]
    assert any(kind == "stall" for _, kind, _ in chaos.events)
    b.pool.check()
    assert b.pool.used_pages == 0


def test_watchdog_survives_stall_shorter_than_limit(setup):
    """A stall shorter than the watchdog bound is absorbed: nothing is
    shed, every request completes."""
    cfg, model, params = setup
    reqs = _requests(cfg, n=3)
    chaos = ChaosInjector(stall_at={2: 3})
    b = _batcher(model, params, chaos=chaos, watchdog_rounds=10)
    for rid, p in reqs:
        b.submit(rid, p)
    results = b.run(max_new=4)
    assert sorted(results) == [r for r, _ in reqs]
    assert b.overload_stats()["watchdog_trips"] == 0
    assert not b.cancelled


# ---------------------------------------------------------------------------
# chaos burst: reproducible spike, deterministic synthetic prompts
# ---------------------------------------------------------------------------

def test_chaos_burst_is_deterministic(setup):
    cfg, model, params = setup

    def run_once():
        # round 1: the short wave drains in a single round, so the burst
        # rides the first round's admission alongside it
        chaos = ChaosInjector(burst_at={1: 3}, check_invariants=True)
        b = _batcher(model, params, chaos=chaos)
        for rid, p in _requests(cfg, n=3):
            b.submit(rid, p)
        return b.run(max_new=4), chaos

    r1, c1 = run_once()
    r2, c2 = run_once()
    burst_rids = [ChaosInjector.BURST_RID0 + i for i in range(3)]
    for rid in burst_rids:
        assert rid in r1 and r1[rid] == r2[rid]
    assert any(kind == "burst" for _, kind, _ in c1.events)
    assert c1.events == c2.events


# ---------------------------------------------------------------------------
# degradation parity: a degraded run changes scheduling, never tokens
# ---------------------------------------------------------------------------

def test_degraded_run_is_bit_exact_for_completing_requests(setup):
    """Force the ladder to DEGRADED from round one (degrade_pressure at
    the floor of the validation range) with speculation and chunking
    armed: both get shed/shrunk, yet every request completes with tokens
    identical to the unloaded reference."""
    cfg, model, params = setup
    rng = np.random.default_rng(6)
    tok = int(rng.integers(0, cfg.vocab))
    # more requests than slots: the drain spans several rounds, so the
    # controller observes nonzero pressure while work is still running
    # (a wave that retires inside round one never leaves HEALTHY)
    reqs = [(i, [tok] * int(rng.integers(10, 16))) for i in range(9)]

    def run_once(**kw):
        # short segments: pressure is observed at round top, so slots
        # must survive a round boundary for the controller to see them
        b = _batcher(model, params, speculate_k=2, prefill_chunk=16,
                     sync_every=2, **kw)
        for rid, p in reqs:
            b.submit(rid, p)
        return b.run(max_new=8), b

    ref, _ = run_once()
    got, b = run_once(overload=True, overload_degrade_pressure=0.01,
                      overload_shed_pressure=1.0, overload_up_rounds=1,
                      overload_down_rounds=50)
    assert got == ref
    st = b.overload_stats()["controller"]
    assert st["state"] != HEALTHY
    assert st["transitions"]
    assert not b.cancelled
    b.pool.check()
    assert b.pool.used_pages == 0


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------

def test_stats_carry_overload_keys(setup):
    cfg, model, params = setup
    b = _batcher(model, params)
    for rid, p in _requests(cfg, n=2):
        b.submit(rid, p)
    b.run(max_new=3)
    lat = b.latency_stats()
    for k in ("cancellations", "shed_requests", "deadline_met",
              "deadline_total", "deadline_attainment", "watchdog_trips"):
        assert k in lat
    assert lat["deadline_attainment"] == 1.0   # vacuous without stamps
    st = b.overload_stats()
    assert st["enabled"] is False
    assert st["controller"]["state"] == HEALTHY
    assert set(st["controller"]["time_in_state"]) == {
        HEALTHY, DEGRADED, SHEDDING}


def test_reset_stats_clears_overload_ledgers(setup):
    cfg, model, params = setup
    b = _batcher(model, params, overload=True, overload_queue_keep=0)
    b.submit(0, [1, 2, 3])
    b.overload.state = SHEDDING
    b._shed_queued()
    assert b.overload_stats()["shed_requests"] == 1
    b.reset_stats()
    st = b.overload_stats()
    assert st["cancellations"] == 0 and st["shed_requests"] == 0
    assert not st["rejections"]
    assert not st["controller"]["transitions"]
    # the rung itself is live operational state, not a ledger
    assert b.overload.state == SHEDDING
