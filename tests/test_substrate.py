"""Data pipeline / checkpoint / fault-tolerance / compression tests."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, DataPipeline
from repro.distributed.compression import compress_leaf, compress_tree
from repro.ft import ElasticPlan, FailureInjector, StragglerMonitor
from repro.ft.elastic import SimulatedFailure


# ------------------------------- data -------------------------------------

def test_data_determinism():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    a = DataPipeline(cfg).batch_at(3)
    b = DataPipeline(cfg).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_sharding_partitions_global_batch():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=1)
    full = DataPipeline(cfg).batch_at(0)["tokens"]
    cfg2 = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=1,
                      n_shards=2, shard=0)
    s0 = DataPipeline(cfg2).batch_at(0)["tokens"]
    assert s0.shape == (4, 8)
    assert full.shape == (8, 8)


def test_data_checkpoint_resume():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    p = DataPipeline(cfg)
    for _ in range(5):
        next(p)
    state = p.state_dict()
    expected = p.batch_at(p.step)["tokens"]
    q = DataPipeline(cfg)
    q.load_state_dict(state)
    np.testing.assert_array_equal(next(q)["tokens"], expected)


def test_data_prefetch_thread():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    p = DataPipeline(cfg)
    want = [p.batch_at(i)["tokens"] for i in range(3)]
    p.start_prefetch()
    got = [p.next_prefetched()["tokens"] for _ in range(3)]
    p.stop()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


# ----------------------------- checkpoint ---------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.asarray(5)},
             "nested": [jnp.ones(3), {"b": jnp.zeros(2)}]}
    ck.save(5, state, extra={"note": "x"})
    restored, manifest = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert manifest["step"] == 5 and manifest["extra"]["note"] == "x"


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = {"w": jnp.zeros(2)}
    for s in (1, 2, 3):
        ck.save(s, state)
    assert ck.all_steps() == [2, 3]
    assert ck.latest_step() == 3


def test_checkpoint_async_and_atomic(tmp_path):
    ck = Checkpointer(tmp_path)
    state = {"w": jnp.full((128, 128), 3.0)}
    ck.save_async(7, state)
    ck.wait()
    restored, m = ck.restore(state)
    assert m["step"] == 7
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.zeros(4)})
    with pytest.raises(ValueError):
        ck.restore({"w": jnp.zeros(5)})


# ------------------------------- ft ---------------------------------------

def test_failure_injector():
    inj = FailureInjector((3,))
    inj.check(2)
    with pytest.raises(SimulatedFailure):
        inj.check(3)
    inj.check(3)    # fires once
    assert inj.triggered == [3]


def test_elastic_plan_prefers_model_axis():
    plan = ElasticPlan.for_devices(512, model=16, prefer_pods=2)
    assert plan.model == 16 and plan.n_devices == 512
    degraded = ElasticPlan.for_devices(496, model=16, prefer_pods=2)
    assert degraded.model == 16
    assert degraded.n_devices <= 496
    tiny = ElasticPlan.for_devices(8, model=16)
    assert tiny.model <= 8


def test_straggler_monitor_flags_outliers():
    import time
    mon = StragglerMonitor(threshold=1.5, window=16)
    for i in range(12):
        mon.step_start()
        time.sleep(0.001)
        mon.step_end(i)
    mon.step_start()
    time.sleep(0.05)
    assert mon.step_end(12) is True
    assert 12 in mon.flags


# --------------------------- compression ----------------------------------

def test_compress_leaf_error_feedback_converges():
    """EF property: accumulated quantized sum tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256), jnp.float32)
    ef = jnp.zeros(256)
    acc = np.zeros(256)
    for _ in range(50):
        deq, ef = compress_leaf(g_true, ef)
        acc += np.asarray(deq)
    np.testing.assert_allclose(acc / 50, np.asarray(g_true), atol=2e-2)


def test_compress_tree_structure():
    g = {"a": jnp.ones(8), "b": [jnp.zeros(4), jnp.full(2, 2.0)]}
    ef = jax.tree_util.tree_map(jnp.zeros_like, g)
    out, ef2 = compress_tree(g, ef)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(g)
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0, atol=1e-2)
