import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.push_scatter import push_scatter
from repro.kernels.push_scatter.ref import push_scatter_ref


@pytest.mark.parametrize("n,u,hot", [(100, 50, 16), (5000, 3000, 256),
                                     (512, 2048, 512), (64, 64, 64)])
def test_push_sweep(n, u, hot):
    rng = np.random.default_rng(n + u)
    vals = jnp.asarray(rng.standard_normal(n), jnp.float32)
    contrib = jnp.asarray(rng.standard_normal(u), jnp.float32)
    # zipf-ish destinations: heavy reuse of a few nodes (the hot set)
    pop = 1.0 / np.arange(1, n + 1) ** 1.1
    pop /= pop.sum()
    dst = jnp.asarray(rng.choice(n, size=u, p=pop), jnp.int32)
    out = push_scatter(vals, contrib, dst, hot=hot)
    ref = push_scatter_ref(vals, contrib, dst)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_push_all_cold():
    """Every destination unique -> everything takes the cold path."""
    rng = np.random.default_rng(0)
    n = 4096
    vals = jnp.zeros(n, jnp.float32)
    contrib = jnp.asarray(rng.standard_normal(512), jnp.float32)
    dst = jnp.asarray(rng.permutation(n)[:512], jnp.int32)
    out = push_scatter(vals, contrib, dst, hot=128)
    ref = push_scatter_ref(vals, contrib, dst)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_push_all_hot():
    """One destination -> pure hot-accumulator path."""
    vals = jnp.zeros(256, jnp.float32)
    contrib = jnp.ones(1024, jnp.float32)
    dst = jnp.zeros(1024, jnp.int32)
    out = push_scatter(vals, contrib, dst, hot=128)
    assert np.isclose(float(out[0]), 1024.0)
    assert np.allclose(np.asarray(out[1:]), 0.0)
