"""Paged KV-cache serving: the block-pool engine (page tables, pooled
pages, page-count bucketing, free-page admission) must produce
token-for-token identical greedy output to the dense step-by-step
reference — mixed prompt lengths, EOS mid-batch, refills, and a pool
smaller than the dense slot table — while reclaiming every retired
slot's pages."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig
from repro.serve.reference import reference_decode
from repro.serve.scheduler import Batcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    rng = np.random.default_rng(1)
    requests = [(i, rng.integers(0, cfg.vocab, size=n).tolist())
                for i, n in enumerate([3, 5, 8, 11])]
    return cfg, model, params, requests


def _run(model, params, scfg, requests, max_new, eos_id=None):
    b = Batcher(model, params, scfg, eos_id=eos_id)
    for rid, p in requests:
        b.submit(rid, p)
    return b.run(max_new=max_new), b


def test_paged_parity_greedy_mixed_lengths(setup):
    """Paged engine == dense per-token reference, bit-exact token ids,
    and the drained pool is fully free again."""
    cfg, model, params, requests = setup
    scfg = ServeConfig(max_len=64, batch=4, dtype=jnp.float32, sync_every=4,
                       paged=True, page_size=8)
    ref = reference_decode(model, params, scfg, requests, max_new=12)
    got, b = _run(model, params, scfg, requests, max_new=12)
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
        assert len(got[rid]) == 12
    assert b.pool.free_pages == b.pool.n_pages     # 100% reclamation
    assert int(b.pool.refcount.sum()) == 0
    b.pool.check()


def test_paged_parity_across_refills(setup):
    """More requests than slots: retirements free pages between segments
    and the refills join through the page table — outputs independent of
    the slot schedule."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(7)
    requests = [(i, rng.integers(0, cfg.vocab,
                                 size=int(rng.integers(3, 12))).tolist())
                for i in range(7)]
    scfg = ServeConfig(max_len=64, batch=3, dtype=jnp.float32, sync_every=4,
                       paged=True, page_size=8)
    ref = reference_decode(model, params, scfg, requests, max_new=10)
    got, b = _run(model, params, scfg, requests, max_new=10)
    assert set(got) == {rid for rid, _ in requests}
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
    assert b.pool.free_pages == b.pool.n_pages


def test_paged_pool_smaller_than_dense(setup):
    """A pool with fewer tokens than batch * max_len still drains with
    identical outputs: admission blocks on free pages, retirements
    re-admit.  This is the capacity decoupling the dense layout can't do."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(3)
    requests = [(i, rng.integers(0, cfg.vocab,
                                 size=int(rng.integers(3, 10))).tolist())
                for i in range(6)]
    base = dict(max_len=64, batch=3, dtype=jnp.float32, sync_every=4)
    ref = reference_decode(model, params, ServeConfig(**base), requests,
                           max_new=8)
    # 6 pages x 8 tokens = 48 token capacity vs dense 3 x 64 = 192
    scfg = ServeConfig(**base, paged=True, page_size=8, total_pages=6)
    got, b = _run(model, params, scfg, requests, max_new=8)
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
    assert b.pool.free_pages == 6
    util = b.kv_utilization()
    assert util["samples"] > 0 and util["peak_util"] > 0.5


def test_paged_eos_mid_batch_frees_pages(setup):
    """EOS retirement mid-batch returns the slot's pages at the segment
    boundary and keeps parity with the reference."""
    cfg, model, params, requests = setup
    scfg = ServeConfig(max_len=64, batch=4, dtype=jnp.float32, sync_every=4,
                       paged=True, page_size=8)
    free = reference_decode(model, params, scfg, requests, max_new=12)
    eos = free[requests[0][0]][4]
    ref = reference_decode(model, params, scfg, requests, max_new=12,
                           eos_id=eos)
    got, b = _run(model, params, scfg, requests, max_new=12, eos_id=eos)
    assert any(len(v) < 12 for v in ref.values())
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
        if ref[rid][-1] == eos or len(ref[rid]) < 12:
            assert got[rid][-1] == eos
    assert b.pool.free_pages == b.pool.n_pages


def test_paged_kernel_route_matches_xla(setup):
    """Routing paged decode attention through the Pallas page-table
    kernel (interpret on CPU) changes no sampled ids vs the XLA gather."""
    cfg, model, params, requests = setup
    base = dict(max_len=64, batch=4, dtype=jnp.float32, sync_every=4,
                paged=True, page_size=8)
    got_x, _ = _run(model, params, ServeConfig(**base, attn_mode="xla"),
                    requests, max_new=8)
    got_k, _ = _run(model, params, ServeConfig(**base, attn_mode="kernel"),
                    requests, max_new=8)
    for rid, _ in requests:
        assert got_x[rid] == got_k[rid], (rid, got_x[rid], got_k[rid])


def test_paged_matches_dense_engine(setup):
    """Dense engine and paged engine agree with each other too (same
    scheduler, different memory layout)."""
    cfg, model, params, requests = setup
    base = dict(max_len=64, batch=4, dtype=jnp.float32, sync_every=4)
    dense, _ = _run(model, params, ServeConfig(**base), requests, max_new=10)
    paged, _ = _run(model, params,
                    ServeConfig(**base, paged=True, page_size=16),
                    requests, max_new=10)
    for rid, _ in requests:
        assert dense[rid] == paged[rid]


def test_paged_ssm_hybrid_across_refills():
    """Hybrid SSM model (mamba2): the paged join must not clobber
    non-joining slots' recurrent SSM state when a retirement triggers a
    refill while other slots are mid-decode — SSM caches are per-slot
    (not paged), so the join's batch-axis select protects them."""
    cfg = get_config("mamba2-370m").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    rng = np.random.default_rng(2)
    requests = [(i, rng.integers(0, cfg.vocab,
                                 size=int(rng.integers(3, 9))).tolist())
                for i in range(5)]

    def run(scfg, eos=None):
        b = Batcher(model, params, scfg, eos_id=eos)
        for rid, p in requests:
            b.submit(rid, p)
        return b.run(max_new=8)

    base = dict(max_len=64, batch=2, dtype=jnp.float32, sync_every=4)
    free = run(ServeConfig(**base))
    eos = free[0][2]                   # retires slot 0 mid-stream
    dense = run(ServeConfig(**base), eos=eos)
    paged = run(ServeConfig(**base, paged=True, page_size=8), eos=eos)
    assert any(len(v) < 8 for v in dense.values())       # refill happened
    for rid, _ in requests:
        assert dense[rid] == paged[rid], (rid, dense[rid], paged[rid])


def test_paged_mla_matches_dense():
    """The paged layout also covers MLA's latent cache (pools are
    [n_pages, ps, rank] with no head dim): prefill + one decode step on
    an identity page table match the dense path."""
    cfg = get_config("deepseek-v3-671b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    b, plen, max_len, ps = 2, 5, 32, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, plen)), jnp.int32)
    logits_d, caches_d = model.prefill(params, {"tokens": toks}, max_len,
                                       dtype=jnp.float32)
    n_pages = b * (max_len // ps)
    caches_p = model.init_paged_caches(b, n_pages, ps, jnp.float32)
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, -1)
    logits_p, caches_p = model.prefill_paged(
        params, {"tokens": toks}, caches_p, table, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_d[:, -1]),
                               np.asarray(logits_p[:, -1]),
                               rtol=2e-5, atol=2e-5)
    nxt = jnp.argmax(logits_d[:, -1], axis=-1).astype(jnp.int32)[:, None]
    ld, _ = model.decode_step(params, nxt, caches_d,
                              jnp.asarray(plen, jnp.int32),
                              dtype=jnp.float32)
    lp, _ = model.decode_step(params, nxt, caches_p,
                              jnp.full((b,), plen, jnp.int32),
                              dtype=jnp.float32, pages=table)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                               rtol=2e-5, atol=2e-5)


def test_paged_rejects_oversized_request(setup):
    """A request that cannot ever fit the pool fails fast instead of
    deadlocking admission."""
    cfg, model, params, _ = setup
    scfg = ServeConfig(max_len=64, batch=2, dtype=jnp.float32,
                       paged=True, page_size=8, total_pages=4)   # 32 tokens
    b = Batcher(model, params, scfg)
    b.submit(0, list(range(1, 30)))
    with pytest.raises(ValueError, match="pages"):
        b.run(max_new=8)
