"""The bench-trajectory gate must fail loudly, never pass on the
intersection: a baseline-pinned row missing from the fresh
BENCH_serve.json is itself a regression (a bench tier silently stopped
running), named in the failure output.  Also pins the acceptance-rate
liveness gate (a dead speculative drafter degrades throughput silently)
and the ``--out`` delta-table artifact."""
import importlib.util
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
spec = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(ROOT, "scripts", "check_bench.py"))
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


def _write(tmp_path, name, rows):
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump({"schema": 1, "rows": rows}, f)
    return path


ROW = {"backend": "cpu", "tok_s": 10.0, "kv_util_mean": 0.5,
       "prefix_hit_rate": 0.0, "prefill_skipped": 0, "chunk_joins": 0,
       "acceptance_rate": 0.0, "pages_reclaimed": True}


def test_all_rows_present_and_healthy_passes(tmp_path):
    base = _write(tmp_path, "base.json", {"smoke-paged": ROW})
    fresh = _write(tmp_path, "fresh.json", {"smoke-paged": dict(ROW),
                                            "extra-local-row": dict(ROW)})
    assert check_bench.check(fresh, base) == 0


def test_missing_baseline_row_fails_with_name(tmp_path, capsys):
    """A row the baseline pins but the fresh file lacks must fail and
    name the row — not silently pass on the intersection."""
    base = _write(tmp_path, "base.json",
                  {"smoke-paged": ROW, "smoke-spec": ROW})
    fresh = _write(tmp_path, "fresh.json", {"smoke-paged": dict(ROW)})
    assert check_bench.check(fresh, base) == 1
    out = capsys.readouterr().out
    assert "smoke-spec" in out and "missing" in out


def test_acceptance_rate_liveness_gated(tmp_path):
    """acceptance_rate nonzero in the baseline must stay nonzero."""
    brow = dict(ROW, acceptance_rate=0.6)
    base = _write(tmp_path, "base.json", {"smoke-spec": brow})
    dead = _write(tmp_path, "dead.json",
                  {"smoke-spec": dict(brow, acceptance_rate=0.0)})
    live = _write(tmp_path, "live.json",
                  {"smoke-spec": dict(brow, acceptance_rate=0.2)})
    assert check_bench.check(dead, base) == 1
    assert check_bench.check(live, base) == 0


def test_throughput_collapse_fails(tmp_path):
    base = _write(tmp_path, "base.json", {"smoke-paged": ROW})
    slow = _write(tmp_path, "slow.json",
                  {"smoke-paged": dict(ROW, tok_s=1.0)})
    assert check_bench.check(slow, base, tol=0.5) == 1
    ok = _write(tmp_path, "ok.json", {"smoke-paged": dict(ROW, tok_s=6.0)})
    assert check_bench.check(ok, base, tol=0.5) == 0


def test_out_writes_delta_table(tmp_path):
    base = _write(tmp_path, "base.json", {"smoke-paged": ROW})
    fresh = _write(tmp_path, "fresh.json", {"smoke-paged": dict(ROW)})
    out_path = str(tmp_path / "delta.txt")
    assert check_bench.check(fresh, base, out_path=out_path) == 0
    with open(out_path) as f:
        body = f.read()
    assert "smoke-paged" in body and "trajectory ok" in body


AUTOTUNE_ROW = {"backend": "cpu", "winner": {"grid_order": "hb"},
                "winner_wall_s": 0.0001, "default_wall_s": 0.0002,
                "achieved_gbps": 0.1, "op_byte": 0.5}


def test_autotune_row_gates(tmp_path):
    """Baseline rows carrying winner_wall_s switch on the autotune
    gates: winner no slower than the measured default, timing hooks
    recorded real walltime, winner config present."""
    base = _write(tmp_path, "base.json", {"autotune-decode": AUTOTUNE_ROW})
    good = _write(tmp_path, "good.json",
                  {"autotune-decode": dict(AUTOTUNE_ROW)})
    assert check_bench.check(good, base) == 0
    slow = _write(tmp_path, "slow.json",
                  {"autotune-decode": dict(AUTOTUNE_ROW,
                                           winner_wall_s=0.0003)})
    assert check_bench.check(slow, base) == 1
    dead = _write(tmp_path, "dead.json",
                  {"autotune-decode": dict(AUTOTUNE_ROW,
                                           achieved_gbps=0.0)})
    assert check_bench.check(dead, base) == 1
    noconf = _write(tmp_path, "noconf.json",
                    {"autotune-decode": {k: v for k, v in
                                         AUTOTUNE_ROW.items()
                                         if k != "winner"}})
    assert check_bench.check(noconf, base) == 1


def _write_tuned(tmp_path, name, data):
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump(data, f)
    return path


def test_tuned_cache_gate(tmp_path):
    """The tune-smoke's cache artifact must be schema-1, non-empty, and
    cover every op — an empty or partial sweep fails loudly."""
    good = _write_tuned(tmp_path, "good.json", {"schema": 1, "entries": {
        f"cpu|{op}|hq4.hkv1.d16.ps8": {"config": {"grid_order": "bh"}}
        for op in ("decode", "prefill", "verify")}})
    assert check_bench.check_tuned(good) == 0
    empty = _write_tuned(tmp_path, "empty.json",
                         {"schema": 1, "entries": {}})
    assert check_bench.check_tuned(empty) > 0
    partial = _write_tuned(tmp_path, "partial.json", {
        "schema": 1, "entries": {"cpu|decode|x": {
            "config": {"grid_order": "bh"}}}})
    assert check_bench.check_tuned(partial) > 0
    badcfg = _write_tuned(tmp_path, "badcfg.json", {"schema": 1, "entries": {
        f"cpu|{op}|x": {"config": {"grid_order": "diagonal"}}
        for op in ("decode", "prefill", "verify")}})
    assert check_bench.check_tuned(badcfg) > 0
    assert check_bench.check_tuned(str(tmp_path / "missing.json")) == 1
