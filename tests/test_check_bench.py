"""The bench-trajectory gate must fail loudly, never pass on the
intersection: a baseline-pinned row missing from the fresh
BENCH_serve.json is itself a regression (a bench tier silently stopped
running), named in the failure output.  Also pins the acceptance-rate
liveness gate (a dead speculative drafter degrades throughput silently)
and the ``--out`` delta-table artifact."""
import importlib.util
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
spec = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(ROOT, "scripts", "check_bench.py"))
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


def _write(tmp_path, name, rows):
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump({"schema": 1, "rows": rows}, f)
    return path


ROW = {"backend": "cpu", "tok_s": 10.0, "kv_util_mean": 0.5,
       "prefix_hit_rate": 0.0, "prefill_skipped": 0, "chunk_joins": 0,
       "acceptance_rate": 0.0, "pages_reclaimed": True}


def test_all_rows_present_and_healthy_passes(tmp_path):
    base = _write(tmp_path, "base.json", {"smoke-paged": ROW})
    fresh = _write(tmp_path, "fresh.json", {"smoke-paged": dict(ROW),
                                            "extra-local-row": dict(ROW)})
    assert check_bench.check(fresh, base) == 0


def test_missing_baseline_row_fails_with_name(tmp_path, capsys):
    """A row the baseline pins but the fresh file lacks must fail and
    name the row — not silently pass on the intersection."""
    base = _write(tmp_path, "base.json",
                  {"smoke-paged": ROW, "smoke-spec": ROW})
    fresh = _write(tmp_path, "fresh.json", {"smoke-paged": dict(ROW)})
    assert check_bench.check(fresh, base) == 1
    out = capsys.readouterr().out
    assert "smoke-spec" in out and "missing" in out


def test_acceptance_rate_liveness_gated(tmp_path):
    """acceptance_rate nonzero in the baseline must stay nonzero."""
    brow = dict(ROW, acceptance_rate=0.6)
    base = _write(tmp_path, "base.json", {"smoke-spec": brow})
    dead = _write(tmp_path, "dead.json",
                  {"smoke-spec": dict(brow, acceptance_rate=0.0)})
    live = _write(tmp_path, "live.json",
                  {"smoke-spec": dict(brow, acceptance_rate=0.2)})
    assert check_bench.check(dead, base) == 1
    assert check_bench.check(live, base) == 0


def test_throughput_collapse_fails(tmp_path):
    base = _write(tmp_path, "base.json", {"smoke-paged": ROW})
    slow = _write(tmp_path, "slow.json",
                  {"smoke-paged": dict(ROW, tok_s=1.0)})
    assert check_bench.check(slow, base, tol=0.5) == 1
    ok = _write(tmp_path, "ok.json", {"smoke-paged": dict(ROW, tok_s=6.0)})
    assert check_bench.check(ok, base, tol=0.5) == 0


def test_out_writes_delta_table(tmp_path):
    base = _write(tmp_path, "base.json", {"smoke-paged": ROW})
    fresh = _write(tmp_path, "fresh.json", {"smoke-paged": dict(ROW)})
    out_path = str(tmp_path / "delta.txt")
    assert check_bench.check(fresh, base, out_path=out_path) == 0
    with open(out_path) as f:
        body = f.read()
    assert "smoke-paged" in body and "trajectory ok" in body
