"""Runtime serving path (jitted decode step with cache shardings) and
elastic re-shard/restore behavior on the host mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import DataConfig, DataPipeline
from repro.distributed import sharding as shd
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig, jit_decode_step


def _host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def test_jit_decode_step_executes_with_cache_shardings():
    """The same step the dry-run lowers, executed for real on a mesh:
    param/cache shardings apply and greedy decode advances."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    mesh = _host_mesh()
    params = pm.unwrap(model.init(jax.random.key(0)))
    scfg = ServeConfig(max_len=32, batch=2, dtype=jnp.float32)
    # build specs the way dryrun does
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("t", 32, 2, "decode")
    specs = model.input_specs(shape, dtype=jnp.float32)
    step = jit_decode_step(model, scfg, mesh, specs)
    caches = model.init_caches(2, 32, jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    tok2, caches = step(params, tok, caches, jnp.asarray(0, jnp.int32), {})
    tok3, caches = step(params, tok2, caches, jnp.asarray(1, jnp.int32), {})
    assert tok3.shape == (2, 1)
    assert np.isfinite(np.asarray(tok3)).all()


def test_data_pipeline_reshard_partition():
    """Elastic re-shard: two half-shards of the resharded stream jointly
    cover a different partition of the same deterministic stream."""
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=3)
    p = DataPipeline(cfg)
    for _ in range(4):
        next(p)
    q0 = p.reshard(2, 0)
    q1 = p.reshard(2, 1)
    assert q0.step == p.step == q1.step
    b0, b1 = q0.batch_at(q0.step), q1.batch_at(q1.step)
    assert b0["tokens"].shape == (4, 8) and b1["tokens"].shape == (4, 8)
    # shards are deterministic and distinct
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b0["tokens"],
                                  p.reshard(2, 0).batch_at(q0.step)["tokens"])


def test_elastic_checkpoint_restore_with_shardings(tmp_path):
    """Restore a checkpoint placing leaves with mesh shardings (the
    restore-onto-a-new-mesh path ElasticPlan drives)."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(1)))
    ck = Checkpointer(tmp_path)
    ck.save(3, params)
    mesh = _host_mesh()
    shardings = shd.param_shardings(model.abstract_ptree(), mesh)
    restored, manifest = ck.restore(params, shardings=shardings)
    assert manifest["step"] == 3
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert hasattr(leaf, "sharding")
    before = jax.tree_util.tree_leaves(params)[0]
    np.testing.assert_allclose(np.asarray(leaf), np.asarray(before))
