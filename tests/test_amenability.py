"""PIM-amenability-test unit tests (§3 semantics)."""
from repro.core.amenability import (Interaction, PrimitiveProfile, Verdict,
                                    run_test)
from repro.core.primitives import push, ss_gemm, vector_sum, wavesim
from repro.core.primitives.graphs import powerlaw


def test_vector_sum_amenable():
    rep = run_test(vector_sum.profile(vector_sum.Problem(1 << 20)))
    assert rep.verdict is Verdict.AMENABLE


def test_compute_bound_rejected():
    p = PrimitiveProfile("big-gemm", ops=1e12, mem_bytes=1e6,
                         onchip_bytes=1e9, interaction=Interaction.LOCALIZED,
                         alignable=True)
    rep = run_test(p)
    assert rep.verdict is Verdict.NOT_AMENABLE
    assert "compute-bound" in rep.guidance


def test_push_conditional_with_predictor_guidance():
    g = powerlaw(100_000, 1_000_000)
    rep = run_test(push.profile(g))
    assert rep.verdict is Verdict.CONDITIONAL
    assert "predictor" in rep.guidance or "single-bank" in rep.guidance


def test_ssgemm_conditional_and_wavesim_profiles():
    rep = run_test(ss_gemm.profile(ss_gemm.Problem(n=4)))
    assert rep.verdict in (Verdict.AMENABLE, Verdict.CONDITIONAL)
    wp = wavesim.Problem()
    pv = wavesim.profile_volume(wp)
    pf = wavesim.profile_flux(wp)
    # paper: wavesim op/byte in 0.43-1.72
    assert 0.3 < pv.op_byte < 2.5
    assert 0.3 < pf.op_byte < 2.5


def test_ssgemm_opbyte_tracks_n():
    """op/byte ~ N for skinny GEMMs (§3.2)."""
    obs = [ss_gemm.profile(ss_gemm.Problem(n=n)).op_byte for n in (2, 4, 8)]
    assert obs[0] < obs[1] < obs[2]


def test_report_renders():
    rep = run_test(vector_sum.profile(vector_sum.Problem(1024)))
    s = rep.summary()
    assert "vector-sum" in s and "guidance" in s
