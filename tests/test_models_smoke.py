"""Per-architecture smoke tests: reduced configs, one forward + train step
+ decode step on CPU; assert output shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import Frontend
from repro.models import param as pm
from repro.models.model_zoo import Model

BATCH, SEQ = 2, 16


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(BATCH, SEQ)), jnp.int32)}
    if cfg.frontend is Frontend.VISION_STUB:
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.enc_dec:
        batch["encoder_frames"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    batch = make_batch(cfg, rng)
    loss = jax.jit(lambda p, b: model.loss(p, b, dtype=jnp.float32))(
        params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert 0.1 < float(loss) < 3.0 * np.log(cfg.vocab), \
        f"{arch}: loss {float(loss)} implausible for vocab {cfg.vocab}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grad_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(1)))
    batch = make_batch(cfg, rng)
    grads = jax.jit(jax.grad(
        lambda p, b: model.loss(p, b, dtype=jnp.float32)))(params, batch)
    flat = jax.tree_util.tree_leaves(grads)
    assert flat, "no grads"
    gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in flat)))
    assert np.isfinite(gn) and gn > 0, f"{arch}: grad norm {gn}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(2)))
    batch = make_batch(cfg, rng)
    max_len = SEQ + 4
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, max_len, dtype=jnp.float32))(
            params, batch)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    extra = {}
    if cfg.enc_dec:
        extra["cross_kv"] = model_cross_kv(model, params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda p, t, c, l: model.decode_step(
        p, t, c, l, dtype=jnp.float32, extra=extra))
    for i in range(3):
        logits, caches = step(params, tok, caches,
                              jnp.asarray(SEQ + i, jnp.int32))
        assert logits.shape == (BATCH, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch} step {i}"
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


def model_cross_kv(model, params, batch):
    from repro.models.transformer import encode
    return encode(params, batch["encoder_frames"].astype(jnp.float32),
                  model.cfg)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m",
                                  "deepseek-v3-671b"])
def test_decode_matches_full_forward(arch, rng):
    """Prefill+decode must agree with a one-shot forward (cache correctness)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(3)))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 8)), jnp.int32)
    from repro.models.transformer import forward, logits_fn
    hidden, _, _ = forward(params, {"tokens": tokens}, cfg,
                           dtype=jnp.float32)
    full_logits = logits_fn(params, hidden, cfg)
    # prefill on the first 7, decode token 8
    _, caches = model.prefill(params, {"tokens": tokens[:, :7]}, 8,
                              dtype=jnp.float32)
    step_logits, _ = model.decode_step(params, tokens[:, 7:8], caches,
                                       jnp.asarray(7, jnp.int32),
                                       dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full_logits[:, 7]),
                               rtol=2e-2, atol=2e-2)
