"""Optimistic admission + page-level preemption must be invisible in the
tokens: admitting on prompt pages only, growing on demand, and evicting
victims under pool pressure (recompute-on-resume through the ordinary
chunked-prefill join) produces bit-exact greedy output vs the
worst-case-reservation reference — while actually preempting, actually
packing more live slots into the same pool, and keeping every allocator /
radix invariant green at every scheduling round.

Covers the deterministic victim policy (priority classes, most-pages /
least-progress tie-breaks, the no-livelock barrier), config validation,
the chaos harness (forced exhaustion, victim override, simulated slot
failure), feature composition (chunked prefill x prefix cache x
speculation), the queue-wait/preemption latency satellite, and a
hypothesis stress test driving all of it against ``KVPool.check()`` /
``PrefixCache.check()`` with a no-preemption parity oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.chaos import ChaosInjector
from repro.serve.engine import ServeConfig
from repro.serve.scheduler import Batcher, _pct


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=6, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, total_pages=10,
            admission_mode="optimistic")


def _requests(cfg, n=9, lo=8, hi=14, seed=1):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, cfg.vocab,
                             size=int(rng.integers(lo, hi))).tolist())
            for i in range(n)]


def _run(model, params, requests, max_new=14, chaos=None,
         priorities=None, **kw):
    b = Batcher(model, params, ServeConfig(**{**BASE, **kw}), chaos=chaos)
    for rid, p in requests:
        b.submit(rid, p, priority=(priorities or {}).get(rid, 0))
    return b.run(max_new=max_new), b


def _reference(model, params, requests, max_new=14):
    """No-preemption oracle: worst-case reservation over an ample pool."""
    return _run(model, params, requests, max_new=max_new,
                admission_mode="reserve", total_pages=64)[0]


def _assert_parity(ref, got, requests):
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])


def _assert_drained(b):
    assert b.pool.used_pages == 0
    assert b.pool.preempted_pages == 0 or b.pool.free_pages >= 0
    b.pool.check()
    if b.prefix is not None:
        b.prefix.check()


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_unknown_admission_mode_rejected(setup):
    cfg, model, params = setup
    with pytest.raises(ValueError, match="admission mode"):
        Batcher(model, params,
                ServeConfig(max_len=32, batch=2, admission_mode="eager"))


def test_optimistic_requires_paged(setup):
    cfg, model, params = setup
    with pytest.raises(ValueError, match="paged"):
        Batcher(model, params,
                ServeConfig(max_len=32, batch=2,
                            admission_mode="optimistic"))


def test_chaos_requires_paged(setup):
    cfg, model, params = setup
    with pytest.raises(ValueError, match="chaos"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2),
                chaos=ChaosInjector())


def test_optimistic_rejects_hybrid_ssm():
    """Preempting an SSM slot would discard a recurrent state recompute
    cannot rebuild from paged KV — rejected up front, before any cache
    is allocated (so no params are needed)."""
    model = Model(get_config("mamba2-370m").reduced())
    with pytest.raises(ValueError, match="attention-only"):
        Batcher(model, None,
                ServeConfig(max_len=32, batch=2, paged=True,
                            admission_mode="optimistic"))


# ---------------------------------------------------------------------------
# victim policy (deterministic, synthetic pressure — no decode needed)
# ---------------------------------------------------------------------------

def _staged_batcher(model, params, **kw):
    """A live-looking slot table without running the model: reserve pages
    by hand and plant host bookkeeping the victim policy reads."""
    b = Batcher(model, params, ServeConfig(**{**BASE, "total_pages": 32,
                                              **kw}))
    return b


def _plant(b, slot, rid, tokens, outputs=0, priority=0, pending=False):
    b.pool.reserve(slot, tokens)
    b.slot_rid[slot] = rid
    b.slot_prompt[slot] = list(range(tokens))
    b.slot_len[slot] = tokens
    b.slot_filled[slot] = tokens
    b.slot_max_tokens[slot] = tokens + 16
    b.req_priority[rid] = priority
    if pending:
        b.slot_pending[slot] = [0] * 4
    if outputs:
        b.outputs[rid] = list(range(outputs))


def test_victim_lowest_priority_first(setup):
    cfg, model, params = setup
    b = _staged_batcher(model, params)
    _plant(b, 0, 10, tokens=32, outputs=1, priority=2)
    _plant(b, 1, 11, tokens=32, outputs=1, priority=0)
    _plant(b, 2, 12, tokens=32, outputs=1, priority=1)
    assert b._pick_victim() == 1


def test_victim_tiebreak_most_pages_then_least_progress(setup):
    cfg, model, params = setup
    b = _staged_batcher(model, params)
    _plant(b, 0, 10, tokens=16, outputs=1)       # 2 pages
    _plant(b, 1, 11, tokens=32, outputs=5)       # 4 pages, more progress
    _plant(b, 2, 12, tokens=32, outputs=1)       # 4 pages, less progress
    assert b._pick_victim() == 2                 # most pages, then least
    b.pool.release(2); b.slot_rid[2] = None      # progress breaks the tie
    assert b._pick_victim() == 1


def test_victim_prefilling_counts_as_zero_progress(setup):
    cfg, model, params = setup
    b = _staged_batcher(model, params)
    _plant(b, 0, 10, tokens=32, outputs=3)
    _plant(b, 1, 11, tokens=32, pending=True)    # PREFILLING: progress 0
    assert b._pick_victim() == 1


def test_victim_slot_id_breaks_final_tie(setup):
    cfg, model, params = setup
    b = _staged_batcher(model, params)
    _plant(b, 2, 12, tokens=16, outputs=2)
    _plant(b, 4, 14, tokens=16, outputs=2)
    assert b._pick_victim() == 2


def test_victim_barrier_protection_orders_last(setup):
    """A request preempted ``admission_max_skips`` times is protected:
    the policy only picks it when nothing unprotected is left — the
    no-livelock guarantee's policy half."""
    cfg, model, params = setup
    b = _staged_batcher(model, params, admission_max_skips=2)
    _plant(b, 0, 10, tokens=32, outputs=1)       # biggest, normally first
    _plant(b, 1, 11, tokens=16, outputs=5)
    b._preempt_counts[10] = 2                    # at the barrier bound
    assert b._pick_victim() == 1
    b.pool.release(1); b.slot_rid[1] = None
    assert b._pick_victim() == 0                 # sole candidate: allowed


def test_chaos_victim_override_wins_and_validates(setup):
    cfg, model, params = setup
    chaos = ChaosInjector(victim_override=lambda bat, cands: cands[-1])
    b = _staged_batcher(model, params)
    b.chaos = chaos
    _plant(b, 0, 10, tokens=32, outputs=1)
    _plant(b, 1, 11, tokens=16, outputs=5)
    assert b._pick_victim() == 1                 # override, not policy
    assert chaos.events[-1][1] == "victim_override"
    b.chaos = ChaosInjector(victim_override=lambda bat, cands: 5)
    with pytest.raises(ValueError, match="not in candidates"):
        b._pick_victim()


# ---------------------------------------------------------------------------
# end-to-end: overload -> preemption -> resume, bit-exact
# ---------------------------------------------------------------------------

def test_overload_preempts_resumes_and_matches_reference(setup):
    """The headline contract: a pool far too small for the worst case
    admits optimistically, preempts under genuine pressure, resumes via
    recompute, and the tokens are bit-identical to the no-preemption
    oracle — at strictly higher utilization and concurrency."""
    cfg, model, params = setup
    requests = _requests(cfg)
    ref = _reference(model, params, requests)
    got, b = _run(model, params, requests)
    _assert_parity(ref, got, requests)
    assert b.preemptions > 0
    assert b.preempt_stats()["recomputed_ok"]
    assert b.preempted_token_recompute > 0
    _assert_drained(b)
    # same pool, reservation admission: strictly fewer live slots and
    # lower mean utilization (the capacity the tentpole reclaims)
    got_res, b_res = _run(model, params, requests,
                          admission_mode="reserve")
    _assert_parity(ref, got_res, requests)
    assert (b.kv_utilization()["peak_live_slots"]
            > b_res.kv_utilization()["peak_live_slots"])
    assert (b.kv_utilization()["mean_util"]
            > b_res.kv_utilization()["mean_util"])


def test_priority_class_survives_overload(setup):
    """Victims come from the low-priority class while it has members: the
    high-priority request is never preempted."""
    cfg, model, params = setup
    requests = _requests(cfg)
    ref = _reference(model, params, requests)
    got, b = _run(model, params, requests, priorities={3: 1})
    _assert_parity(ref, got, requests)
    assert b.preemptions > 0
    assert 3 not in b.preempted_rids
    _assert_drained(b)


def test_preempted_request_completes_with_barrier(setup):
    """No-livelock, end to end: with the barrier bound at 1, the first
    preemption already protects the victim — it still completes, and is
    never evicted again while unprotected slots exist."""
    cfg, model, params = setup
    requests = _requests(cfg)
    ref = _reference(model, params, requests)
    got, b = _run(model, params, requests, admission_max_skips=1)
    _assert_parity(ref, got, requests)
    assert b.preemptions > 0 and b.preempt_stats()["recomputed_ok"]
    assert not b._resumed and not b._preempt_counts
    _assert_drained(b)


# ---------------------------------------------------------------------------
# chaos harness
# ---------------------------------------------------------------------------

def test_chaos_forced_exhaustion_recovers_bit_exact(setup):
    cfg, model, params = setup
    requests = _requests(cfg)
    ref = _reference(model, params, requests)
    chaos = ChaosInjector(exhaust_at={2: 0}, release_at=(8,),
                          check_invariants=True)
    got, b = _run(model, params, requests, chaos=chaos, total_pages=20)
    _assert_parity(ref, got, requests)
    assert b.preemptions >= 1
    assert any(kind == "hold" for _, kind, _ in chaos.events)
    assert any(kind == "release_held" for _, kind, _ in chaos.events)
    assert b.pool.held_pages == 0
    _assert_drained(b)


def test_chaos_slot_failure_mid_decode_recovers(setup):
    """A simulated device-state loss on the deepest live slot is handled
    as a preemption: the request recomputes and finishes bit-exact."""
    cfg, model, params = setup
    requests = _requests(cfg, n=5)
    ref = _reference(model, params, requests)
    chaos = ChaosInjector(fail_slot_at={3: "deepest"},
                          check_invariants=True)
    got, b = _run(model, params, requests, chaos=chaos, total_pages=24)
    _assert_parity(ref, got, requests)
    assert chaos.slot_failures == 1
    assert b.preempt_stats()["slot_failures"] == 1
    assert b.preemptions >= 1 and b.preempt_stats()["recomputed_ok"]
    _assert_drained(b)


def test_chaos_slot_failure_works_in_reserve_mode(setup):
    """Recovery does not depend on optimistic admission: a reserve-mode
    slot failure re-queues and re-reserves the worst case."""
    cfg, model, params = setup
    requests = _requests(cfg, n=4)
    ref = _reference(model, params, requests)
    chaos = ChaosInjector(fail_slot_at={2: "deepest"})
    got, b = _run(model, params, requests, chaos=chaos,
                  admission_mode="reserve", total_pages=24)
    _assert_parity(ref, got, requests)
    assert b.preemptions == 1
    _assert_drained(b)


# ---------------------------------------------------------------------------
# feature composition under pressure
# ---------------------------------------------------------------------------

def test_preemption_composes_with_chunked_prefill(setup):
    cfg, model, params = setup
    requests = _requests(cfg, n=7, lo=20, hi=34, seed=3)
    ref = _reference(model, params, requests)
    got, b = _run(model, params, requests, prefill_chunk=8,
                  total_pages=12)
    _assert_parity(ref, got, requests)
    assert b.preemptions > 0 and b.chunk_joins > 0
    _assert_drained(b)


def test_preemption_composes_with_prefix_cache(setup):
    """Shared system prompt + pressure: preempted slots' registered pages
    park cached, resumes match their own history, and the radix tree
    stays consistent with the pool's partitions."""
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    system = rng.integers(0, cfg.vocab, size=16).tolist()
    requests = [(i, system + rng.integers(
        0, cfg.vocab, size=int(rng.integers(4, 10))).tolist())
        for i in range(8)]
    ref = _reference(model, params, requests)
    got, b = _run(model, params, requests, prefix_cache=True,
                  total_pages=12)
    _assert_parity(ref, got, requests)
    assert b.preemptions > 0
    assert b.prefill_skipped > 0          # resumes/matches shortcut work
    _assert_drained(b)


def test_preemption_composes_with_speculation(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(6)
    tok = int(rng.integers(0, cfg.vocab))
    requests = [(i, [tok] * int(rng.integers(8, 14))) for i in range(8)]
    ref = _reference(model, params, requests)
    got, b = _run(model, params, requests, speculate_k=2,
                  total_pages=11)
    _assert_parity(ref, got, requests)
    assert b.preemptions > 0
    _assert_drained(b)


# ---------------------------------------------------------------------------
# latency / stats satellite
# ---------------------------------------------------------------------------

def test_pct_guards_empty_lists():
    assert _pct([], 50) == 0.0
    assert _pct([2.0], 95) == 2.0


def test_latency_stats_report_queue_wait_and_preemptions(setup):
    cfg, model, params = setup
    requests = _requests(cfg)
    _, b = _run(model, params, requests)
    lat = b.latency_stats()
    assert lat["preemptions"] == b.preemptions > 0
    assert lat["preempted_token_recompute"] > 0
    assert lat["queue_wait_p95_s"] >= lat["queue_wait_p50_s"] > 0.0
    # every admission (including re-admissions) closed a wait interval
    assert len(b.queue_waits) == len(b.admit_order)
    b.reset_stats()
    assert b.latency_stats()["queue_wait_p50_s"] == 0.0


# ---------------------------------------------------------------------------
# hypothesis stress: preemption x chunked x prefix x spec vs invariants
# ---------------------------------------------------------------------------

def test_stress_preemption_traffic_invariants(setup):
    """Random overloaded traffic with every feature armed and per-round
    invariant sweeps: bit-exact vs the no-preemption oracle, allocator
    and radix checks green at every scheduling round, pool fully drained,
    and every preempted request completed (no livelock).
    (importorskip inside the test, like the other serve suites, so the
    rest of this module still runs without hypothesis; ci.sh fails
    loudly when the install is missing.)"""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    cfg, model, params = setup

    @settings(max_examples=5, deadline=None)
    @given(st.data())
    def inner(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16),
                                              label="seed"))
        n_req = data.draw(st.integers(4, 9), label="n_req")
        system = rng.integers(
            0, cfg.vocab,
            size=data.draw(st.integers(0, 16), label="system")).tolist()
        requests = [(i, system + rng.integers(
            0, cfg.vocab, size=int(rng.integers(4, 14))).tolist())
            for i in range(n_req)]
        max_new = data.draw(st.integers(4, 14), label="max_new")
        pages = data.draw(st.integers(8, 14), label="pages")
        kw: dict = {"total_pages": pages}
        if data.draw(st.booleans(), label="chunked?"):
            kw["prefill_chunk"] = 8
        if data.draw(st.booleans(), label="prefix?"):
            kw["prefix_cache"] = True
        if data.draw(st.booleans(), label="spec?"):
            kw["speculate_k"] = 2
        priorities = {i: data.draw(st.integers(0, 1), label=f"prio{i}")
                      for i in range(n_req)}
        chaos = ChaosInjector(
            exhaust_at={data.draw(st.integers(2, 5), label="xr"): 0},
            release_at=(data.draw(st.integers(7, 10), label="rr"),),
            check_invariants=True)
        ref = _reference(model, params, requests, max_new=max_new)
        got, b = _run(model, params, requests, max_new=max_new,
                      chaos=chaos, priorities=priorities, **kw)
        _assert_parity(ref, got, requests)
        assert b.preempt_stats()["recomputed_ok"]
        assert not b._resumed
        assert b.pool.held_pages == 0
        _assert_drained(b)

    inner()
