import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_group_gemm import group_gemm
from repro.kernels.moe_group_gemm.ref import group_gemm_ref


@pytest.mark.parametrize("e,c,d,f", [(2, 128, 64, 64), (8, 256, 128, 96),
                                     (4, 64, 32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_gemm_sweep(e, c, d, f, dtype):
    rng = np.random.default_rng(e * c)
    xe = jnp.asarray(rng.standard_normal((e, c, d)), dtype)
    w = jnp.asarray(rng.standard_normal((e, d, f)), dtype)
    counts = jnp.asarray(rng.integers(0, c + 1, size=e), jnp.int32)
    out = group_gemm(xe, w, counts, bc=64)
    ref = group_gemm_ref(xe, w, counts)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 10)


def test_group_gemm_empty_experts():
    """All-empty experts produce exact zeros (the skipped tiles)."""
    rng = np.random.default_rng(0)
    xe = jnp.asarray(rng.standard_normal((4, 128, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32)
    counts = jnp.asarray([0, 128, 0, 5], jnp.int32)
    out = group_gemm(xe, w, counts, bc=64)
    assert np.allclose(np.asarray(out[0]), 0.0)
    assert np.allclose(np.asarray(out[2]), 0.0)
    np.testing.assert_allclose(out, group_gemm_ref(xe, w, counts),
                               rtol=1e-4, atol=1e-3)
