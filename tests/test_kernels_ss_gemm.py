import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ss_gemm import ssgemm_compact, ssgemm_masked
from repro.kernels.ss_gemm.ops import block_occupancy
from repro.kernels.ss_gemm.ref import ssgemm_ref


def make(m, k, n, density, dtype, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    b[rng.random(k) > density] = 0.0
    return jnp.asarray(a, dtype), jnp.asarray(b, dtype)


@pytest.mark.parametrize("m,k,n", [(256, 256, 2), (512, 384, 4),
                                   (128, 1024, 8), (384, 512, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_sweep(m, k, n, dtype):
    a, b = make(m, k, n, density=0.4, dtype=dtype, seed=m + n)
    out = ssgemm_masked(a, b, bm=128, bk=128)
    ref = ssgemm_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("density", [0.0, 0.05, 0.5, 1.0])
def test_masked_density_extremes(density):
    a, b = make(256, 512, 4, density, jnp.float32, seed=3)
    out = ssgemm_masked(a, b, bm=128, bk=128)
    np.testing.assert_allclose(out, ssgemm_ref(a, b), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("budget", [1, 2, 4, 8])
def test_compact_budgets(budget):
    """Exact for any budget: overflow falls back to the dense path."""
    a, b = make(256, 1024, 4, density=0.25, dtype=jnp.float32, seed=7)
    out = ssgemm_compact(a, b, budget=budget, bm=128, bk=128)
    np.testing.assert_allclose(out, ssgemm_ref(a, b), rtol=1e-4, atol=1e-3)


def test_occupancy_mask():
    _, b = make(8, 512, 4, density=0.3, dtype=jnp.float32, seed=11)
    mask = np.asarray(block_occupancy(b, 128))
    bb = np.asarray(b).reshape(4, 128, 4)
    np.testing.assert_array_equal(mask, (bb != 0).any(axis=(1, 2)))
