"""Self-speculative decoding must be invisible in the tokens: drafting k
candidates from the slot's own history and verifying them in one
multi-token paged attention call (variable per-slot advance, K/V rollback
by not advancing ``lengths``) produces bit-exact greedy output vs the
speculate-off paged engine across every boundary case — mixed prompt
lengths, EOS landing *inside* an accepted speculation window, refills,
prefix-cache hits, chunked-prefill interleave — while ``spec_stats()``
proves drafts were actually accepted where the workload repeats.

Also covers the drafter itself (period extrapolation, repeat-last
fallback), the decode-priority ``prefill_round_tokens`` budget, config
validation, and a hypothesis traffic test driving chunked prefill +
prefix cache + speculation together against the allocator/radix
invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import param as pm
from repro.models.model_zoo import Model
from repro.serve.engine import ServeConfig, ngram_propose
from repro.serve.scheduler import Batcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    return cfg, model, params


BASE = dict(max_len=96, batch=3, dtype=jnp.float32, sync_every=4,
            paged=True, page_size=8, total_pages=36)


def _run(model, params, requests, max_new=10, eos_id=None, **kw):
    b = Batcher(model, params, ServeConfig(**{**BASE, **kw}), eos_id=eos_id)
    for rid, p in requests:
        b.submit(rid, p)
    return b.run(max_new=max_new), b


def _mixed_requests(cfg, sizes, seed=1, system=0):
    rng = np.random.default_rng(seed)
    sys_toks = rng.integers(0, cfg.vocab, size=system).tolist()
    return [(i, sys_toks + rng.integers(0, cfg.vocab, size=n).tolist())
            for i, n in enumerate(sizes)]


def _rep_requests(cfg, n, plen=10, seed=2):
    rng = np.random.default_rng(seed)
    tok = int(rng.integers(0, cfg.vocab))
    return [(i, [tok] * plen) for i in range(n)]


def _assert_parity(ref, got, requests):
    for rid, _ in requests:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])


def _assert_drained(b):
    assert b.pool.used_pages == 0
    assert int(b.pool.refcount.sum()) == 0
    b.pool.check()


# ---------------------------------------------------------------------------
# the drafter (pure function)
# ---------------------------------------------------------------------------

def test_ngram_period_extrapolation():
    """A period-3 history continues the period, self-referencing drafts
    once the copy source passes the known region."""
    h = jnp.asarray([[1, 2, 3, 1, 2, 3, 1, 2, 0, 0, 0, 0]], jnp.int32)
    d = ngram_propose(h, jnp.asarray([7]), k=5, n=2)
    assert d.tolist() == [[3, 1, 2, 3, 1]]


def test_ngram_single_token_run():
    h = jnp.asarray([[9, 4, 4, 4, 0, 0]], jnp.int32)
    d = ngram_propose(h, jnp.asarray([3]), k=3, n=2)
    assert d.tolist() == [[4, 4, 4]]


def test_ngram_no_match_repeats_current():
    h = jnp.asarray([[5, 6, 7, 0, 0, 0]], jnp.int32)
    d = ngram_propose(h, jnp.asarray([2]), k=3, n=2)
    assert d.tolist() == [[7, 7, 7]]


def test_ngram_per_slot_independent():
    """Rows draft independently: one cycling, one unmatched."""
    h = jnp.asarray([[8, 8, 8, 8, 0, 0],
                     [1, 2, 3, 4, 0, 0]], jnp.int32)
    d = ngram_propose(h, jnp.asarray([3, 3]), k=2, n=2)
    assert d.tolist() == [[8, 8], [4, 4]]


# ---------------------------------------------------------------------------
# bit-exact greedy parity, speculate-on == speculate-off
# ---------------------------------------------------------------------------

def test_spec_parity_mixed_lengths(setup):
    """Chaotic mixed-length prompts: low acceptance, identical tokens."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [9, 3, 14])
    ref, _ = _run(model, params, requests)
    got, b = _run(model, params, requests, speculate_k=3)
    _assert_parity(ref, got, requests)
    assert b.spec_stats()["steps"] > 0
    _assert_drained(b)


def test_spec_parity_repetitive_accepts(setup):
    """The repetitive workload actually exercises acceptance: > 0 drafts
    accepted and > 1 token committed per verify step on average."""
    cfg, model, params = setup
    requests = _rep_requests(cfg, 3)
    ref, _ = _run(model, params, requests, max_new=16)
    got, b = _run(model, params, requests, max_new=16, speculate_k=3)
    _assert_parity(ref, got, requests)
    s = b.spec_stats()
    assert s["accepted"] > 0
    assert s["tokens_per_step"] > 1.0
    _assert_drained(b)


def test_spec_eos_inside_window(setup):
    """EOS committed mid-window: the accepted advance truncates at the
    EOS token (kept, like the plain loop) and the slot retires with its
    pages reclaimed while batch-mates continue."""
    cfg, model, params = setup
    requests = _rep_requests(cfg, 3, seed=5)
    free, _ = _run(model, params, requests, max_new=16)
    # the cycle token appears mid-stream, so with speculation on it is
    # committed from inside an accepted window, not at position 0
    eos = free[0][3]
    ref, _ = _run(model, params, requests, max_new=16, eos_id=eos)
    assert any(len(v) < 16 for v in ref.values())
    got, b = _run(model, params, requests, max_new=16, eos_id=eos,
                  speculate_k=4)
    _assert_parity(ref, got, requests)
    for rid, out in got.items():
        if len(out) < 16:
            assert out[-1] == eos          # EOS kept, nothing after it
    _assert_drained(b)


def test_spec_parity_with_refills(setup):
    """More requests than slots: retirements trigger refills; the fresh
    slot's history row is rebuilt from the new prompt and the old
    request's stale tokens can never influence committed output."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [7, 3, 11, 5, 9, 4], seed=7)
    ref, _ = _run(model, params, requests, max_new=8)
    got, b = _run(model, params, requests, max_new=8, speculate_k=3)
    _assert_parity(ref, got, requests)
    _assert_drained(b)


def test_spec_parity_with_prefix_cache(setup):
    """Speculation over radix-cache hits: shared prefix pages sit below
    every verify write (the k-row overhang lands in private pages), so
    cache-on + spec-on matches cache-off + spec-off bit-for-bit."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [2, 5, 3, 4], seed=9, system=16)
    ref, _ = _run(model, params, requests, max_new=8)
    got, b = _run(model, params, requests, max_new=8, speculate_k=3,
                  prefix_cache=True)
    _assert_parity(ref, got, requests)
    s = b.prefix_stats()
    assert s["hits"] >= 3 and s["prefill_skipped"] > 0
    b.prefix.check()
    assert b.pool.used_pages == 0


def test_spec_parity_with_chunked_prefill(setup):
    """A long prompt chunk-prefills while other slots decode
    speculatively; the frozen slot's placeholder verify writes land in
    its private pages and are overwritten by its next chunk."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [40, 5, 23], seed=11)
    ref, _ = _run(model, params, requests)
    got, b = _run(model, params, requests, speculate_k=3,
                  prefill_chunk=16)
    _assert_parity(ref, got, requests)
    assert b.chunk_joins > 0
    _assert_drained(b)


def test_spec_kernel_route_matches_xla(setup):
    """The verify through the Pallas flash-prefill kernel (interpret on
    CPU) commits the same tokens as the XLA gather route."""
    cfg, model, params = setup
    requests = _rep_requests(cfg, 2, seed=13)
    got_x, _ = _run(model, params, requests, max_new=6, batch=2,
                    speculate_k=3, attn_mode="xla")
    got_k, _ = _run(model, params, requests, max_new=6, batch=2,
                    speculate_k=3, attn_mode="kernel")
    _assert_parity(got_x, got_k, requests)


# ---------------------------------------------------------------------------
# decode-priority chunk budget
# ---------------------------------------------------------------------------

def test_prefill_round_budget_defers_and_preserves_tokens(setup):
    """A tight per-round token budget defers continuation chunks (several
    PREFILLING slots cannot all take a chunk in one round) without
    changing any request's tokens."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [40, 33, 5], seed=15)
    ref, b0 = _run(model, params, requests, prefill_chunk=8)
    got, b1 = _run(model, params, requests, prefill_chunk=8,
                   prefill_round_tokens=8)
    _assert_parity(ref, got, requests)
    assert b1.join_stats()["budget_deferrals"] > 0
    assert b0.join_stats()["budget_deferrals"] == 0
    _assert_drained(b1)


def test_prefill_round_budget_always_progresses(setup):
    """A budget smaller than one chunk still admits one piece per round
    (no livelock) — the cap bounds the round, not the first piece."""
    cfg, model, params = setup
    requests = _mixed_requests(cfg, [24, 17], seed=17)
    ref, _ = _run(model, params, requests, prefill_chunk=16)
    got, b = _run(model, params, requests, prefill_chunk=16,
                  prefill_round_tokens=1)
    _assert_parity(ref, got, requests)
    _assert_drained(b)


def test_reset_stats_isolates_measurement_waves(setup):
    """A warm batcher re-measured after reset_stats() reports only the
    second wave: acceptance counters and latency inputs start from zero
    (steady-state benchmarking re-submits into the same instance to
    reuse its compiled executables)."""
    cfg, model, params = setup
    requests = _rep_requests(cfg, 3, seed=19)
    b = Batcher(model, params,
                ServeConfig(**{**BASE, "speculate_k": 3}))
    for rid, p in requests:
        b.submit(rid, p)
    b.run(max_new=8)
    first = b.spec_stats()
    assert first["steps"] > 0 and len(b.ttfts) == 3
    b.reset_stats()
    assert b.spec_stats()["steps"] == 0
    assert b.ttfts == [] and b.tpots == [] and not b._first_tok_t
    for rid, p in requests:
        b.submit(rid + 100, p)
    b.run(max_new=8)
    second = b.spec_stats()
    assert second["steps"] == first["steps"]          # one wave, not two
    assert {r - 100: v for r, v in b.results.items() if r >= 100} \
        == {r: v for r, v in b.results.items() if r < 100}
    assert len(b.ttfts) == 3


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_spec_validation(setup):
    cfg, model, params = setup
    with pytest.raises(ValueError, match="paged"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2,
                                           speculate_k=3))
    with pytest.raises(ValueError, match="speculate_k"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2, paged=True,
                                           speculate_k=0))
    with pytest.raises(ValueError, match="greedy"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2, paged=True,
                                           speculate_k=3, temperature=0.7))
    with pytest.raises(ValueError, match="speculate_ngram"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2, paged=True,
                                           speculate_k=3,
                                           speculate_ngram=0))
    with pytest.raises(ValueError, match="prefill_round_tokens"):
        Batcher(model, params, ServeConfig(max_len=32, batch=2, paged=True,
                                           prefill_round_tokens=0))


def test_spec_rejects_hybrid_ssm():
    """Recurrent state advances k+1 tokens per verify and cannot roll
    back — hybrid SSM models are rejected up front (before any cache is
    allocated, so no params are needed)."""
    model = Model(get_config("mamba2-370m").reduced())
    with pytest.raises(ValueError, match="attention-only"):
        Batcher(model, None, ServeConfig(max_len=32, batch=2, paged=True,
                                         speculate_k=3))


def test_spec_window_counts_toward_max_len(setup):
    """prompt + max_new + k must fit max_len: the verify writes (and the
    page reservation covers) up to lengths + k."""
    cfg, model, params = setup
    b = Batcher(model, params,
                ServeConfig(**{**BASE, "speculate_k": 4}))
    b.submit(0, list(range(1, 84)))        # 83 + 10 + 4 > 96
    with pytest.raises(ValueError, match="speculation window"):
        b.run(max_new=10)


# ---------------------------------------------------------------------------
# everything at once: hypothesis traffic
# ---------------------------------------------------------------------------

def test_spec_chunked_prefix_traffic(setup):
    """Random traffic through chunked prefill + prefix cache +
    speculation together: random prompts with shared prefixes, random
    EOS (often landing mid-window), refills — bit-exact parity vs the
    plain paged engine, allocator and radix invariants intact.
    (importorskip inside the test, like test_kvpool, so the rest of this
    module still runs without hypothesis; ci.sh fails loudly when the
    install is missing.)"""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    cfg, model, params = setup

    @settings(max_examples=5, deadline=None)
    @given(st.data())
    def traffic(data):
        rng_seed = data.draw(st.integers(0, 10 ** 6), label="seed")
        rng = np.random.default_rng(rng_seed)
        system = rng.integers(0, cfg.vocab,
                              size=data.draw(st.sampled_from([0, 8, 16]),
                                             label="system")).tolist()
        sizes = data.draw(st.lists(st.integers(1, 30), min_size=2,
                                   max_size=6), label="sizes")
        requests = [(i, system + rng.integers(0, cfg.vocab,
                                              size=n).tolist())
                    for i, n in enumerate(sizes)]
        max_new = data.draw(st.integers(2, 10), label="max_new")
        ref, _ = _run(model, params, requests, max_new=max_new)
        # an output token that exists mid-stream somewhere (or None)
        eos = None
        if data.draw(st.booleans(), label="use_eos"):
            outs = [v for v in ref.values() if len(v) > 2]
            if outs:
                eos = outs[0][1 + rng_seed % (len(outs[0]) - 1)]
                ref2, _ = _run(model, params, requests, max_new=max_new,
                               eos_id=eos)
            else:
                ref2 = ref
        else:
            ref2 = ref
        got, b = _run(model, params, requests, max_new=max_new, eos_id=eos,
                      speculate_k=data.draw(st.sampled_from([1, 3, 4]),
                                            label="k"),
                      prefill_chunk=8, prefix_cache=True,
                      prefill_round_tokens=data.draw(
                          st.sampled_from([None, 8, 24]), label="budget"))
        _assert_parity(ref2, got, requests)
        b.pool.check()
        b.prefix.check()
        assert b.pool.used_pages == 0
        assert int(b.pool.refcount.sum()) == 0

    traffic()
