"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dep: skip, don't break tier-1
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache_model import LruCache
from repro.core.commands import Kind, Loop, Seg, Subset, total_commands
from repro.core.hwspec import PimSpec
from repro.core.optimizations import (Phase, arch_aware_schedule,
                                      baseline_schedule, cache_split,
                                      sparsity_thin)
from repro.core.timing import simulate

PIM = PimSpec()
jst = st.integers


@settings(max_examples=40, deadline=None)
@given(cmds=jst(1, 64), trips=jst(1, 200), phases=jst(1, 6))
def test_arch_aware_never_slower(cmds, trips, phases):
    """Invariant: decoupled activation never loses to the baseline
    schedule (it only removes stalls, never adds commands... beyond the
    split ACT's extra issue slots, which are bounded by the saved stalls)."""
    ph = [Phase(cmds)] * phases
    base = simulate(baseline_schedule(ph, trips), PIM)
    opt = simulate(arch_aware_schedule(ph, trips), PIM)
    assert opt.time_ns <= base.time_ns * 1.02   # 2% slack: ACT issue slots


@settings(max_examples=40, deadline=None)
@given(cmds=jst(1, 40), trips=jst(1, 100), phases=jst(1, 5))
def test_schedules_equal_compute_commands(cmds, trips, phases):
    """Functional equivalence proxy: both schedules issue the same number
    of compute commands (the optimization only moves activations)."""
    from repro.core.commands import total_by_kind
    ph = [Phase(cmds)] * phases
    b = total_by_kind(baseline_schedule(ph, trips))
    o = total_by_kind(arch_aware_schedule(ph, trips))
    assert b[Kind.PIM_BCAST] == o[Kind.PIM_BCAST]


@settings(max_examples=40, deadline=None)
@given(cmds=jst(0, 10_000),
       density=st.floats(0.0, 1.0, allow_nan=False))
def test_sparsity_thin_bounds(cmds, density):
    out = sparsity_thin(cmds, density)
    assert 0 <= out <= cmds or (cmds == 0 and out == 0)
    if density == 1.0:
        assert out == cmds


@settings(max_examples=40, deadline=None)
@given(n=jst(0, 10_000), h=st.floats(0.0, 1.0, allow_nan=False))
def test_cache_split_partition(n, h):
    s = cache_split(n, h)
    assert s.hot + s.cold == n
    assert 0 <= s.hot <= n


@settings(max_examples=20, deadline=None)
@given(seed=jst(0, 1000), length=jst(1, 400))
def test_lru_hit_rate_bounds_and_repeat_hits(seed, length):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, 1 << 20, size=length) * 64
    c = LruCache(capacity_bytes=64 * 1024, ways=4)
    r1 = c.run_trace(addrs)
    assert 0 <= r1.hit_rate <= 1
    # immediately replaying a short suffix must hit (working set cached)
    tail = addrs[-8:]
    r2 = c.run_trace(tail)
    assert r2.hit_rate == 1.0


@settings(max_examples=20, deadline=None)
@given(seed=jst(0, 100), trips=jst(1, 30), cmds=jst(1, 30))
def test_loop_compression_exact(seed, trips, cmds):
    """Steady-state loop evaluation == full unroll, for random bodies."""
    rng = np.random.default_rng(seed)
    body = []
    for _ in range(rng.integers(1, 5)):
        if rng.random() < 0.4:
            body.append(Seg(Kind.ACT, Subset.ALL))
        else:
            sub = Subset.EVEN if rng.random() < 0.5 else Subset.ODD
            body.append(Seg(Kind.PIM_BCAST, sub, cmds))
    looped = simulate([Loop(tuple(body), trips)], PIM)
    unrolled = simulate(list(body) * trips, PIM)
    assert abs(looped.time_ns - unrolled.time_ns) < 1e-6 * max(
        1.0, unrolled.time_ns)


@settings(max_examples=10, deadline=None)
@given(seed=jst(0, 50))
def test_moe_routing_conservation(seed):
    """Router weights are normalized and dispatch conserves token mass
    (within capacity drops)."""
    from repro.configs import get_config
    from repro.models import param as pm
    from repro.models.moe import init_moe, route
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    params = pm.unwrap(init_moe(jax.random.key(seed), cfg))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((16, cfg.d_model)), jnp.float32)
    w, ids, probs = route(params, x, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < cfg.moe.n_experts
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=jst(0, 50), chunk=st.sampled_from([2, 4, 8]))
def test_ssd_chunk_invariance(seed, chunk):
    """SSD output must not depend on the chunk size (algebraic identity)."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(seed)
    b, l, h, p, n = 1, 16, 2, 4, 8
    xdt = jnp.asarray(rng.standard_normal((b, l, h, p)) * 0.3, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((b, l, h))) * 0.3,
                    jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, 1, n)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, 1, n)) * 0.3, jnp.float32)
    y1, s1 = ssd_chunked(xdt, a, bm, cm, chunk)
    y2, s2 = ssd_chunked(xdt, a, bm, cm, l)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)
