"""Autotuning harness for the paged-attention family: static
feasibility pruning (infeasible tilings never run), the end-to-end
sweep's winner selection + tuned-shape cache roundtrip, policy-side
loading (hit / miss / corrupt fallback / env override), and bit-exact
parity between tuned and default launches on every op."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core.hwspec import DEFAULT_TPU
from repro.kernels.decode_attn import decode_attn_policy
from repro.kernels.paged_attn import autotune as at
from repro.kernels.paged_attn import (paged_attn, paged_prefill_attn,
                                      paged_prefill_attn_ref,
                                      paged_verify_attn)

GEOM = at.Geometry(hq=4, hkv=2, d=8, page_size=8)   # g=2, tiny but GQA


def _no_env(monkeypatch):
    monkeypatch.delenv(at.ENV_VAR, raising=False)


# --------------------------------------------------------------------------
# analytic pruner
# --------------------------------------------------------------------------

def test_feasible_rejects_non_divisor_block_rows():
    ok, why = at.feasible(at.Candidate("bh", 7), op="prefill", lg=24,
                          geom=GEOM)
    assert not ok and "divide" in why


def test_feasible_rejects_block_rows_on_decode():
    ok, why = at.feasible(at.Candidate("bh", 2), op="decode", lg=2,
                          geom=GEOM)
    assert not ok and "decode" in why


def test_feasible_rejects_vmem_overflow():
    """A roofline-infeasible tiling (working set past VMEM) is rejected
    statically — it must never reach the benchmark stage."""
    tiny = dataclasses.replace(DEFAULT_TPU, vmem_bytes=64)
    ok, why = at.feasible(at.Candidate(), op="prefill", lg=16, geom=GEOM,
                          spec=tiny)
    assert not ok and "VMEM" in why
    # sanity: the same tiling fits a real VMEM
    assert at.feasible(at.Candidate(), op="prefill", lg=16, geom=GEOM)[0]


def test_prune_drops_infeasible_and_keeps_default_first():
    wl = at.make_workload("prefill", GEOM)       # lq=8, g=2 -> lg=16
    bad = at.Candidate("bh", 7)                  # 7 does not divide 16
    survivors, pruned = at.prune(wl, [at.Candidate(), at.Candidate("hb"),
                                      bad])
    assert survivors[0] == at.Candidate()
    assert bad not in survivors
    assert any(c == bad and "divide" in why for c, why in pruned)


def test_prune_budget_cut_retains_default():
    wl = at.make_workload("prefill", GEOM)
    survivors, pruned = at.prune(wl, budget=2)
    assert len(survivors) == 2
    assert survivors[0] == at.Candidate()
    assert any("budget" in why for _, why in pruned)


# --------------------------------------------------------------------------
# end-to-end sweep + cache roundtrip
# --------------------------------------------------------------------------

def test_autotune_selects_and_persists_winner_per_op(tmp_path, monkeypatch):
    _no_env(monkeypatch)
    res = at.autotune(geom=GEOM, budget=2, reps=1)
    assert set(res) == set(at.OPS)
    for op, r in res.items():
        assert isinstance(r["winner"], dict)
        # the default is always in the measured set, so the wall-time
        # argmin can never lose to it
        assert r["winner_wall_s"] <= r["default_wall_s"]
        assert r["achieved_gbps"] > 0
    path = at.save_entries(res, str(tmp_path / "tuned.json"))
    entries = at.load_entries(path)
    backend = jax.default_backend()
    for op in at.OPS:
        ent = entries[at.entry_key(backend, op, GEOM)]
        assert ent["config"] == res[op]["winner"]


def test_save_entries_merges_and_discards_unknown_schema(tmp_path,
                                                         monkeypatch):
    _no_env(monkeypatch)
    p = tmp_path / "tuned.json"
    keep = {"schema": at.SCHEMA,
            "entries": {"tpu|decode|other": {"config": {"grid_order": "hb"}}}}
    p.write_text(json.dumps(keep))
    res = at.autotune(ops=("decode",), geom=GEOM, reps=1)
    at.save_entries(res, str(p))
    entries = at.load_entries(str(p))
    assert "tpu|decode|other" in entries           # merged, not clobbered
    assert at.entry_key(jax.default_backend(), "decode", GEOM) in entries
    # an unknown on-disk schema is discarded rather than half-merged
    p.write_text(json.dumps({"schema": 99, "entries": keep["entries"]}))
    at.save_entries(res, str(p))
    assert "tpu|decode|other" not in at.load_entries(str(p))


# --------------------------------------------------------------------------
# policy-side loading
# --------------------------------------------------------------------------

def _cache_file(tmp_path, config, op="decode", geom=GEOM):
    key = at.entry_key(jax.default_backend(), op, geom)
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"schema": at.SCHEMA,
                             "entries": {key: {"config": config}}}))
    return str(p)


def test_policy_cache_hit(tmp_path, monkeypatch):
    _no_env(monkeypatch)
    path = _cache_file(tmp_path, {"grid_order": "hb"})
    with decode_attn_policy(use_tuned=True, tuned_path=path) as pol:
        assert pol.tuned_config("decode", hq=4, hkv=2, d=8,
                                page_size=8) == {"grid_order": "hb"}


def test_policy_cache_miss_returns_none(tmp_path, monkeypatch):
    _no_env(monkeypatch)
    path = _cache_file(tmp_path, {"grid_order": "hb"})
    with decode_attn_policy(use_tuned=True, tuned_path=path) as pol:
        assert pol.tuned_config("decode", hq=8, hkv=8, d=64,
                                page_size=16) is None
        assert pol.tuned_config("prefill", hq=4, hkv=2, d=8,
                                page_size=8, lg=16) is None


def test_policy_corrupt_cache_degrades_to_defaults(tmp_path, monkeypatch):
    _no_env(monkeypatch)
    p = tmp_path / "corrupt.json"
    p.write_text("{not json")
    with decode_attn_policy(use_tuned=True, tuned_path=str(p)) as pol:
        assert pol.tuned_config("decode", hq=4, hkv=2, d=8,
                                page_size=8) is None


def test_policy_sanitizes_block_rows_against_lg(tmp_path, monkeypatch):
    """Entries are keyed without Lq, so a tuned row tiling only applies
    to calls whose fused row count it divides."""
    _no_env(monkeypatch)
    path = _cache_file(tmp_path, {"grid_order": "hb", "block_rows": 6},
                       op="prefill")
    with decode_attn_policy(use_tuned=True, tuned_path=path) as pol:
        assert pol.tuned_config("prefill", hq=4, hkv=2, d=8, page_size=8,
                                lg=12) == {"grid_order": "hb",
                                           "block_rows": 6}
        assert pol.tuned_config("prefill", hq=4, hkv=2, d=8, page_size=8,
                                lg=16) == {"grid_order": "hb"}
        assert pol.tuned_config("prefill", hq=4, hkv=2, d=8, page_size=8,
                                lg=None) == {"grid_order": "hb"}


def test_env_var_disables_and_redirects(tmp_path, monkeypatch):
    path = _cache_file(tmp_path, {"grid_order": "hb"})
    for off in ("", "off", "0", "ignore"):
        monkeypatch.setenv(at.ENV_VAR, off)
        assert at.load_entries(path) == {}       # env wins over the path
    other = tmp_path / "other.json"
    other.write_text(json.dumps({"schema": at.SCHEMA,
                                 "entries": {"x|y|z": {"config": {}}}}))
    monkeypatch.setenv(at.ENV_VAR, str(other))
    assert "x|y|z" in at.load_entries(path)


def test_load_memo_invalidates_on_rewrite(tmp_path, monkeypatch):
    _no_env(monkeypatch)
    path = _cache_file(tmp_path, {"grid_order": "hb"})
    first = at.load_entries(path)
    assert first and at.load_entries(path) is first      # memo hit
    with open(path, "w") as f:
        json.dump({"schema": at.SCHEMA, "entries": {}}, f)
    assert at.load_entries(path) == {}                   # mtime/size key


# --------------------------------------------------------------------------
# tuned-vs-default parity on the live ops
# --------------------------------------------------------------------------

def _run_op(op, wl, route):
    kw = dict(mode=route, interpret=True) if route == "kernel" \
        else dict(mode=route)
    if op == "decode":
        return lambda pol_kw: _call(paged_attn, wl, dict(kw, **pol_kw),
                                    decode=True)
    fn = paged_verify_attn if op == "verify" else paged_prefill_attn
    return lambda pol_kw: _call(fn, wl, dict(kw, **pol_kw))


def _call(fn, wl, pol_kw, decode=False):
    with decode_attn_policy(**pol_kw):
        if decode:
            return np.asarray(fn(wl.q, wl.k_pages, wl.v_pages, wl.table,
                                 wl.lengths, interpret=True))
        return np.asarray(fn(wl.q, wl.k_pages, wl.v_pages, wl.table,
                             wl.q_offset, wl.lengths))


@pytest.mark.parametrize("op", at.OPS)
@pytest.mark.parametrize("route", ["kernel", "xla"])
def test_tuned_vs_default_bit_exact(op, route, tmp_path, monkeypatch):
    """With a tuned grid order persisted for this geometry, routing
    through the cache must produce bit-identical outputs to the
    defaults on both the kernel route (grid order permutes independent
    (b, h) programs) and the XLA route (which ignores launch config
    entirely)."""
    _no_env(monkeypatch)
    path = _cache_file(tmp_path, {"grid_order": "hb"}, op=op)
    wl = at.make_workload(op, GEOM)
    run = _run_op(op, wl, route)
    default = run(dict(use_tuned=False))
    tuned = run(dict(use_tuned=True, tuned_path=path))
    assert np.array_equal(default, tuned)


@pytest.mark.parametrize("br", [1, 2, 4, 8, 16])
def test_block_rows_divisors_match_oracle(br):
    """Every divisor row fold must stay numerically equivalent to the
    gather oracle (bit-exactness across folds is a backend lowering
    property — the autotuner parity-gates it; correctness is not)."""
    wl = at.make_workload("prefill", GEOM)       # lg = 16
    with decode_attn_policy(mode="kernel", interpret=True,
                            use_tuned=False):
        out = paged_prefill_attn(wl.q, wl.k_pages, wl.v_pages, wl.table,
                                 wl.q_offset, wl.lengths, block_rows=br)
    ref = paged_prefill_attn_ref(wl.q, wl.k_pages, wl.v_pages, wl.table,
                                 wl.q_offset, wl.lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
