"""PIM-offload planner over the assigned architectures (paper §3 made
executable): which ops of each (arch x shape) step are PIM-amenable, the
estimated strawman-PIM speedup, and the TPU-native action this framework
takes instead.

  PYTHONPATH=src python examples/offload_planner.py --arch deepseek-v3-671b
"""
import argparse

from repro.configs import ALL_ARCHS, get_config, shapes_for
from repro.core.planner import render


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            print(render(cfg, shape))
            print()


if __name__ == "__main__":
    main()
