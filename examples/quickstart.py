"""Quickstart: the paper in five minutes.

1. Run the PIM-amenability-test on the studied primitives.
2. Model baseline vs optimized PIM execution (the paper's headline).
3. Execute the TPU-adapted kernels (interpret mode) against their oracles.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.amenability import run_test
from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM
from repro.core.primitives import ss_gemm, vector_sum, wavesim


def main() -> None:
    print("=" * 72)
    print("1) PIM-amenability-test (paper §3)")
    print("=" * 72)
    for profile in (vector_sum.profile(vector_sum.Problem(64 << 20)),
                    wavesim.profile_volume(wavesim.Problem()),
                    ss_gemm.profile(ss_gemm.Problem(n=4))):
        print(run_test(profile, PIM, GPU).summary())
        print()

    print("=" * 72)
    print("2) Analytical PIM model: baseline vs optimized (paper §4-5)")
    print("=" * 72)
    vp = vector_sum.Problem(64 << 20)
    print(f"vector-sum     : {vector_sum.speedup(vp, PIM, GPU):.2f}x -> "
          f"{vector_sum.speedup(vp, PIM, GPU, arch_aware=True):.2f}x "
          "(arch-aware activation)")
    wp = wavesim.Problem()
    print(f"wavesim-volume : {wavesim.speedup_volume(wp, PIM, GPU):.2f}x -> "
          f"{wavesim.speedup_volume(wp, PIM, GPU, arch_aware=True):.2f}x")
    sp = ss_gemm.Problem(n=4)
    r = ss_gemm.speedups(sp, PIM, GPU)
    print(f"ss-gemm (N=4)  : {r['baseline']:.2f}x -> "
          f"{r['sparsity_aware']:.2f}x (sparsity-aware command skip)")
    print()

    print("=" * 72)
    print("3) TPU-adapted Pallas kernels vs oracles (interpret mode)")
    print("=" * 72)
    rng = np.random.default_rng(0)
    from repro.kernels.ss_gemm import ssgemm_masked
    from repro.kernels.ss_gemm.ref import ssgemm_ref
    a = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    b = rng.standard_normal((512, 4)).astype(np.float32)
    b[rng.random(512) > 0.45] = 0.0
    out = ssgemm_masked(a, jnp.asarray(b), bm=128, bk=128)
    err = float(jnp.max(jnp.abs(out - ssgemm_ref(a, jnp.asarray(b)))))
    print(f"ss-gemm kernel max |err| vs oracle: {err:.2e}")
    from repro.kernels.wavesim_volume import volume
    from repro.kernels.wavesim_volume.ref import volume_ref
    u = jnp.asarray(rng.standard_normal((16, 9, 3, 3, 3)), jnp.float32)
    err = float(jnp.max(jnp.abs(volume(u) - volume_ref(u))))
    print(f"wavesim-volume kernel max |err| vs oracle: {err:.2e}")
    from repro.kernels.decode_attn import decode_attn
    from repro.kernels.decode_attn.ref import decode_attn_ref
    q = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    err = float(jnp.max(jnp.abs(decode_attn(q, k, v, 300)
                                - decode_attn_ref(q, k, v, 300))))
    print(f"decode-attn kernel max |err| vs oracle: {err:.2e}")


if __name__ == "__main__":
    main()
