"""Batched serving example: slot-based continuous batching over the
device-resident decode loop.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
"""
import argparse

from repro.launch.serve import run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = run(args.arch, reduced=True, requests=args.requests,
              max_new=args.max_new, batch=args.batch, max_len=64,
              sync_every=args.sync_every, temperature=args.temperature)
    for rid, toks in sorted(out["results"].items()):
        print(f"request {rid}: {toks}")


if __name__ == "__main__":
    main()
