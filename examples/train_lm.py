"""End-to-end training driver example.

Default profile runs a small model for 30 steps on CPU (finishes in
minutes and demonstrably learns).  ``--profile 100m`` trains a ~100M-param
qwen2-family config for a few hundred steps — the configuration a v5e pod
would run; on CPU expect hours, so the default keeps the same code path at
laptop scale.  Checkpoint/restart and failure injection are live in both.

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --profile 100m --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.launch.train import run


def hundred_m() -> ArchConfig:
    """~100M-param qwen2-family config (d=640, 12L, 32k vocab)."""
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=12, d_model=640, n_heads=10,
        kv_heads=2, d_ff=2560, vocab=32_000, head_dim=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=("quick", "100m"), default="quick")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.profile == "quick":
        out = run("qwen2-0.5b", steps=args.steps or 30, batch=8, seq=128,
                  reduced=True, lr=3e-3, ckpt_dir=args.ckpt, ckpt_every=10,
                  fail_at=tuple(args.fail_at))
    else:
        import repro.launch.train as T
        from repro.models.model_zoo import Model
        # register the 100m config through the same driver path
        cfg = hundred_m()
        import repro.configs as C
        C.REGISTRY[cfg.name] = cfg
        out = run(cfg.name, steps=args.steps or 300, batch=16, seq=512,
                  reduced=False, lr=3e-4, accum=2, ckpt_dir=args.ckpt,
                  ckpt_every=50, fail_at=tuple(args.fail_at))
    print(f"final loss: {out['final_loss']:.4f} "
          f"(from {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
