"""Wave-simulation demo: DGM timesteps through the TPU kernels.

Propagates a Gaussian pressure pulse on a periodic mesh using the
wavesim-volume Pallas kernel (fused Kronecker operator) for the volume term
and the functional flux; prints the wavefront's motion as evidence the
physics works end-to-end.

  PYTHONPATH=src python examples/wave_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.primitives import wavesim
from repro.kernels.wavesim_volume import volume as volume_kernel


def main() -> None:
    g = (8, 8, 8)
    fields = 3
    shape = g + (fields, 3, 3, 3)
    u = np.zeros(shape, np.float32)
    # Gaussian pulse in field 0 centered mid-grid
    for i in range(g[0]):
        for j in range(g[1]):
            for k in range(g[2]):
                r2 = (i - 4) ** 2 + (j - 4) ** 2 + (k - 4) ** 2
                u[i, j, k, 0] = np.exp(-r2 / 4.0)
    u = jnp.asarray(u)

    dt, steps = 5e-3, 40
    for step in range(steps):
        flat = u.reshape((-1, fields, 3, 3, 3))
        rhs_v = volume_kernel(flat).reshape(u.shape)   # Pallas kernel
        rhs_f = wavesim.flux(u)
        u = u + dt * (rhs_v + rhs_f)
        if step % 10 == 0:
            e = np.asarray(jnp.sum(jnp.square(u), axis=(3, 4, 5, 6)))
            center = e[4, 4, 4]
            shell = e[1, 4, 4]
            print(f"step {step:3d}: energy center={center:8.4f} "
                  f"shell={shell:8.4f} total={e.sum():9.3f}")
    print("pulse propagates outward (center decays, shell rises)" )


if __name__ == "__main__":
    main()
