"""Quick calibration check of the analytical model vs paper anchors."""
import sys

from repro.core.hwspec import DEFAULT_GPU as GPU, DEFAULT_PIM as PIM, PimSpec
from repro.core.primitives import push, ss_gemm, vector_sum, wavesim
from repro.core.primitives.graphs import paper_inputs

print("== spec sanity ==")
print(f"peak hbm: {PIM.regular_bytes_per_ns_per_pch * PIM.pch_per_stack:.1f} GB/s (want 614.4)")
print(f"pim bw:   {PIM.pim_peak_gbps:.1f} GB/s (want ~2457.6, 4x)")
print(f"upper bound vs 90%-GPU: {PIM.pim_peak_gbps / GPU.effective_gbps:.2f}x")

print("\n== vector-sum (paper: >2.6x) ==")
p = vector_sum.Problem(n=64 * 1024 * 1024)
st = vector_sum.pim_time(p, PIM)
print(f"baseline: {vector_sum.speedup(p, PIM, GPU):.2f}x  act_frac={st.act_stall_frac:.2%}")
print(f"arch-aware: {vector_sum.speedup(p, PIM, GPU, arch_aware=True):.2f}x")

print("\n== wavesim (paper: volume 1.5x->2.04x, act 27%; flux act 50%, 64regs->2.63x) ==")
wp = wavesim.Problem()
for regs in (16, 32, 64):
    sv = wavesim.pim_time_volume(wp, PIM, regs=regs)
    sva = wavesim.speedup_volume(wp, PIM, GPU, regs=regs)
    svo = wavesim.speedup_volume(wp, PIM, GPU, arch_aware=True, regs=regs)
    print(f"volume r{regs}: base {sva:.2f}x (act {sv.act_stall_frac:.1%}) arch-aware {svo:.2f}x")
for regs in (16, 32, 64):
    sf = wavesim.pim_time_flux(wp, PIM, regs=regs)
    sfa = wavesim.speedup_flux(wp, PIM, GPU, regs=regs)
    sfo = wavesim.speedup_flux(wp, PIM, GPU, arch_aware=True, regs=regs)
    print(f"flux   r{regs}: base {sfa:.2f}x (act {sf.act_stall_frac:.1%}) arch-aware {sfo:.2f}x")

print("\n== ss-gemm (paper: base {1.66,0.75,0.43,0.23}; sa {>3,...,1.07@N8}) ==")
for n in (2, 4, 8, 16):
    sp = ss_gemm.Problem(n=n)
    r = ss_gemm.speedups(sp, PIM, GPU)
    print(f"N={n:2d}: base {r['baseline']:.2f}x  sparsity-aware {r['sparsity_aware']:.2f}x "
          f"(density {r['density']:.2f}, row-zero {r['row_zero_frac']:.2f})")

print("\n== push (paper: ca avg 1.20x max 1.39x; ca-GPU up to 1.68x; 4x cmdBW up to 2.02x) ==")
for g in paper_inputs():
    r = push.evaluate(g, PIM, GPU)
    pim4 = PimSpec(command_bw_mult=4.0)
    cold = int(g.n_edges * (1.0 - r.predictor_hit_rate))
    t4 = push.pim_time(g, pim4, n_updates=max(1, cold),
                       row_hit_frac=push.COLD_ROW_HIT).time_ns
    feed = push.gpu_feed_time_ns(g, GPU)
    t4 = max(t4, feed) + 0.15 * min(t4, feed)
    print(f"{g.name:22s} h_meas={g.measured_l2_hit:.2f} h_pred={r.predictor_hit_rate:.2f} "
          f"base {r.speedup_baseline:.2f}x ca {r.speedup_cache_aware:.2f}x "
          f"caGPU {r.speedup_gpu_cache_aware:.2f}x ca+4xBW {r.gpu_ns / t4:.2f}x")
sys.exit(0)
