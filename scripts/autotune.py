#!/usr/bin/env python
"""Sweep the paged-attention kernel family's launch geometry and persist
the winners to the tuned-shape cache.

Per (op, geometry) this enumerates every launch config the kernels
accept (grid order for all three ops, row-fold tiling for prefill and
verify), prunes with the analytic roofline score (infeasible tilings
never run), benchmarks the survivors through the kernel-timing telemetry
hooks, parity-gates every candidate bit-exactly against the default
shape, and writes the wall-time winner to ``benchmarks/tuned_shapes.json``
keyed ``<backend>|<op>|<geometry>`` — the cache ``DecodeAttnPolicy``
resolves at construction time.  Page size is a geometry axis (it changes
the pool layout), so ``--page-sizes`` sweeps it as separate entries.

  PYTHONPATH=src python scripts/autotune.py                 # full sweep
  python scripts/autotune.py --smoke                        # CI tier
  python scripts/autotune.py --ops decode --page-sizes 16
  python scripts/autotune.py --dry-run                      # prune only
  python scripts/autotune.py --no-save --out /tmp/t.json

``--smoke`` bounds the sweep for CI: one geometry (the first page size),
at most 8 measured candidates per op, 2 timing reps.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax  # noqa: E402

from repro.configs import get_config                      # noqa: E402
from repro.kernels.paged_attn import autotune as at       # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--ops", default=",".join(at.OPS),
                    help="comma-separated subset of decode,prefill,verify")
    ap.add_argument("--page-sizes", default="8,16",
                    help="pool page sizes to sweep (each is its own "
                         "geometry entry)")
    ap.add_argument("--b", type=int, default=2, help="workload slots")
    ap.add_argument("--lq", type=int, default=8,
                    help="prefill/verify query-block tokens")
    ap.add_argument("--pages", type=int, default=16,
                    help="pool pages in the workload")
    ap.add_argument("--budget", type=int, default=None,
                    help="max measured candidates per op (analytic rank "
                         "cuts the rest; default: all feasible)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed reps per surviving candidate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="cache file to merge winners into (default: the "
                         "committed benchmarks/tuned_shapes.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="bounded CI sweep: first page size only, "
                         "budget<=8, reps=2")
    ap.add_argument("--dry-run", action="store_true",
                    help="enumerate + prune only; nothing is benchmarked "
                         "or persisted")
    ap.add_argument("--no-save", action="store_true",
                    help="benchmark but do not write the cache")
    args = ap.parse_args()

    ops = tuple(o.strip() for o in args.ops.split(",") if o.strip())
    bad = [o for o in ops if o not in at.OPS]
    if bad:
        ap.error(f"unknown ops {bad}; choose from {at.OPS}")
    page_sizes = [int(p) for p in args.page_sizes.split(",") if p.strip()]
    budget, reps = args.budget, args.reps
    if args.smoke:
        page_sizes = page_sizes[:1]
        budget = min(budget or 8, 8)
        reps = min(reps, 2)

    cfg = get_config(args.arch).reduced()
    print(f"[autotune] arch={args.arch} backend={jax.default_backend()} "
          f"ops={','.join(ops)} page_sizes={page_sizes} "
          f"budget={budget} reps={reps}")
    for ps in page_sizes:
        geom = at.Geometry(hq=cfg.n_heads, hkv=cfg.kv_heads,
                           d=cfg.resolved_head_dim, page_size=ps)
        if args.dry_run:
            for op in ops:
                wl = at.make_workload(op, geom, b=args.b, lq=args.lq,
                                      pages=args.pages, seed=args.seed)
                cands, pruned = at.prune(wl, budget=budget)
                print(f"  {geom.key()} {op}: would run "
                      f"{[c.label() for c in cands]}; pruned "
                      f"{[(c.label(), why) for c, why in pruned]}")
            continue
        res = at.autotune(ops, geom=geom, b=args.b, lq=args.lq,
                          pages=args.pages, budget=budget, reps=reps,
                          seed=args.seed)
        for op, r in res.items():
            win = at.Candidate(**r["winner"]).label()
            print(f"  {geom.key()} {op:<8} winner {win:<12} "
                  f"{r['winner_wall_s'] * 1e3:7.2f}ms "
                  f"(default {r['default_wall_s'] * 1e3:7.2f}ms), "
                  f"{r['achieved_gbps']:.3f} GB/s, "
                  f"op/byte {r['op_byte']:.2f}  "
                  f"[{len(r['candidates'])} measured, "
                  f"{len(r['pruned'])} pruned, "
                  f"{len(r['parity_dropped'])} parity-dropped]")
        if not args.no_save:
            path = at.save_entries(res, args.out)
            print(f"[autotune] winners merged into {path}")


if __name__ == "__main__":
    main()
