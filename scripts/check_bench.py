#!/usr/bin/env python
"""Gate the serving perf trajectory: fresh BENCH_serve.json vs baseline.

Every ``serve_bench`` invocation writes its rows to ``BENCH_serve.json``;
this script compares them against the committed
``benchmarks/baselines/serve_baseline.json`` and fails (exit 1) when the
trajectory regresses — so a PR that quietly halves serving throughput or
breaks page reclamation fails CI instead of landing.  This is the
measurement discipline PrIM-style benchmarking argues for: the numbers
are only meaningful if something checks them on every change.

Checks per baseline row (extra rows in the fresh file that the baseline
does not pin are ignored, so local experiments don't trip the gate —
but every row the baseline *does* pin must be present in the fresh
``BENCH_serve.json``: a missing row fails loudly with its name, because
a silently-skipped bench tier would otherwise pass on the intersection):

* ``tok_s``: fresh >= BENCH_TOL x baseline (default 0.5 — wall-clock
  throughput varies across runners; the gate catches collapses, not
  noise).  Skipped with a note when the backends differ (a CPU baseline
  says nothing about TPU throughput).
* ``prefix_hit_rate`` / ``prefill_skipped``: must stay nonzero wherever
  the baseline has them nonzero (the radix cache still hits).
* ``acceptance_rate``: nonzero wherever the baseline has it nonzero
  (the self-speculative drafter still gets drafts accepted on the
  repetitive workload — a dead drafter silently degrades to 1
  token/step at a higher per-step cost).
* ``pages_reclaimed``: must stay truthy wherever the baseline pins it
  (retired slots still return their pages).
* ``chunk_joins``: nonzero wherever the baseline has it nonzero (long
  prompts still get chunked).
* ``preemptions``: nonzero wherever the baseline has it nonzero (the
  forced-exhaustion smoke still actually exercises preemption — a
  silently idle preemption path would pass every other gate).
* ``recomputed_ok``: must stay truthy wherever the baseline pins it
  (every preempted request completed via recompute-on-resume).
* ``slo_attainment``: wherever the baseline pins one, the fresh row must
  carry a numeric attainment in [0, 1] (the SLO monitor is still
  observing; the smokes run generous SLOs so the value itself is a
  deterministic 1.0).
* ``cancellations`` / ``shed_requests``: nonzero wherever the baseline
  has them nonzero (the overload smoke still actually cancels and
  sheds — an overload controller that never fires would pass every
  other gate while protecting nothing).
* ``recovered_to_healthy``: must stay truthy wherever the baseline pins
  it (the degradation ladder descends again once the burst drains; a
  controller stuck in SHEDDING is a one-way ratchet, not protection).
* ``deadline_attainment``: wherever the baseline pins one, the fresh
  row must carry a numeric attainment in [0, 1] (deadline accounting is
  still wired through retire *and* cancel).
* ``kv_util_mean``: in (0, 1.5] — paged sharing can push utilization
  above 1.0, but not past every-slot-shares-everything sanity.
* autotune rows (baseline has ``winner_wall_s``): the fresh sweep must
  have selected a winner config no slower than the measured default
  (the default is always in the measured set, so winner <= default by
  construction — a violation means selection broke) with
  ``achieved_gbps > 0`` (the kernel timing hooks recorded real
  walltime, not traced-only accounting).

``--tuned PATH`` additionally gates the tuned-shape cache the tuning
tier produces: schema 1, non-empty, at least one entry per op, sane
configs.  ``--tuned-only`` runs just that gate (the CI tune-smoke step
has no fresh bench rows to diff).

Always prints a one-line-per-row delta table (ci.sh runs it last as the
bench summary); ``--out PATH`` additionally writes that table to a file
so CI can upload it as an artifact next to ``BENCH_serve.json``.

  python scripts/check_bench.py [--bench PATH] [--baseline PATH]
                                [--out PATH]
  BENCH_TOL=0.4 python scripts/check_bench.py     # looser throughput gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
BENCH = os.path.join(ROOT, "BENCH_serve.json")
BASELINE = os.path.join(ROOT, "benchmarks", "baselines",
                        "serve_baseline.json")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f).get("rows", {})


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def check(bench_path: str = BENCH, baseline_path: str = BASELINE,
          tol: float | None = None, out_path: str | None = None) -> int:
    """Returns the number of failed checks (0 == gate passes)."""
    if tol is None:
        tol = float(os.environ.get("BENCH_TOL", "0.5"))
    try:
        fresh = _load(bench_path)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {bench_path}: {e}")
        return 1
    try:
        base = _load(baseline_path)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {baseline_path}: {e}")
        return 1

    failures = []
    lines = []
    for name in sorted(base):
        brow = base[name]
        frow = fresh.get(name)
        if frow is None:
            # every baseline-pinned row must be regenerated by the run:
            # a missing row means a bench tier silently stopped running,
            # which is itself a regression — never pass on the
            # intersection of the two row sets
            failures.append(f"{name}: row missing from {bench_path} "
                            "(bench tier did not run?)")
            lines.append(f"  {name:<22} MISSING")
            continue
        row_fail = []
        notes = []
        b_tok, f_tok = brow.get("tok_s"), frow.get("tok_s")
        if b_tok:
            if brow.get("backend") != frow.get("backend"):
                notes.append(f"tok/s not compared "
                             f"({brow.get('backend')} baseline vs "
                             f"{frow.get('backend')} run)")
            elif f_tok is None or f_tok < tol * b_tok:
                row_fail.append(
                    f"tok_s {_fmt(f_tok)} < {tol:.2f} x baseline "
                    f"{_fmt(b_tok)}")
        for key in ("prefix_hit_rate", "prefill_skipped", "chunk_joins",
                    "acceptance_rate", "preemptions", "cancellations",
                    "shed_requests"):
            if brow.get(key) and not frow.get(key):
                row_fail.append(f"{key} dropped to zero "
                                f"(baseline {_fmt(brow[key])})")
        if brow.get("pages_reclaimed") and not frow.get("pages_reclaimed"):
            row_fail.append("pages_reclaimed is no longer true")
        if brow.get("recomputed_ok") and not frow.get("recomputed_ok"):
            row_fail.append("recomputed_ok is no longer true "
                            "(a preempted request lost tokens)")
        if brow.get("recovered_to_healthy") \
                and not frow.get("recovered_to_healthy"):
            row_fail.append("recovered_to_healthy is no longer true "
                            "(degradation controller stuck degraded)")
        if "deadline_attainment" in brow:
            # wherever the baseline pins a deadline attainment, the fresh
            # row must carry a sane one — missing means the deadline
            # accounting silently stopped
            da = frow.get("deadline_attainment")
            if not isinstance(da, (int, float)) or isinstance(da, bool) \
                    or not 0.0 <= da <= 1.0:
                row_fail.append(f"deadline_attainment {_fmt(da)} missing "
                                "or outside [0, 1]")
        if "slo_attainment" in brow:
            # wherever the baseline pins an attainment, the fresh row
            # must carry a sane one — a missing value means the SLO
            # monitor silently stopped observing
            sa = frow.get("slo_attainment")
            if not isinstance(sa, (int, float)) or isinstance(sa, bool) \
                    or not 0.0 <= sa <= 1.0:
                row_fail.append(f"slo_attainment {_fmt(sa)} missing or "
                                "outside [0, 1]")
        util = frow.get("kv_util_mean")
        if util is not None and not 0.0 < util <= 1.5:
            row_fail.append(f"kv_util_mean {_fmt(util)} outside (0, 1.5]")
        if "winner_wall_s" in brow:
            # autotune rows: the sweep must have selected a winner no
            # slower than the default it was measured against (the
            # default is always in the measured set, so a violation
            # means the argmin broke), and the telemetry timing hooks
            # must have recorded real walltime (achieved GB/s > 0 —
            # zero means the sweep fell back to traced-only accounting)
            ww, dw = frow.get("winner_wall_s"), frow.get("default_wall_s")
            if not (isinstance(ww, (int, float)) and
                    isinstance(dw, (int, float)) and
                    not isinstance(ww, bool) and not isinstance(dw, bool)):
                row_fail.append("winner_wall_s/default_wall_s missing "
                                "(autotune sweep did not run?)")
            elif ww > dw:
                row_fail.append(f"winner_wall_s {_fmt(ww)} > default "
                                f"{_fmt(dw)} (winner selection broke)")
            if not isinstance(frow.get("winner"), dict):
                row_fail.append("winner config missing from autotune row")
            ag = frow.get("achieved_gbps")
            if not isinstance(ag, (int, float)) or isinstance(ag, bool) \
                    or ag <= 0:
                row_fail.append(f"achieved_gbps {_fmt(ag)} not > 0 "
                                "(kernel timing hooks recorded nothing)")

        delta = ""
        if b_tok and f_tok and brow.get("backend") == frow.get("backend"):
            delta = f"tok/s {_fmt(f_tok)} vs {_fmt(b_tok)} " \
                    f"({(f_tok / b_tok - 1) * 100:+.0f}%)"
        elif notes:
            delta = notes[0]
        status = "FAIL: " + "; ".join(row_fail) if row_fail else "ok"
        lines.append(f"  {name:<22} {delta:<34} {status}")
        failures.extend(f"{name}: {f}" for f in row_fail)

    report = [f"[check_bench] {bench_path} vs {baseline_path} "
              f"(BENCH_TOL={tol:.2f})"]
    report.extend(lines)
    if failures:
        report.append(f"[check_bench] {len(failures)} regression(s):")
        report.extend(f"  - {f}" for f in failures)
    else:
        report.append("[check_bench] trajectory ok")
    print("\n".join(report))
    if out_path:
        # the per-row delta table as a CI artifact next to BENCH_serve.json
        with open(out_path, "w") as f:
            f.write("\n".join(report) + "\n")
    return len(failures)


def check_trace(trace_path: str) -> int:
    """Trace liveness gate: the Perfetto export of the smoke run must be
    loadable JSON with a non-empty ``traceEvents`` list of well-formed
    ``trace_event`` records, and every submitted request must retire —
    a trace that silently stopped recording (or a request that vanished
    mid-lifecycle) fails here instead of shipping as a dead artifact.
    Returns the number of failed checks (0 == gate passes)."""
    failures = []
    try:
        with open(trace_path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"[check_bench] cannot read trace {trace_path}: {e}")
        return 1
    evs = data.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        failures.append("traceEvents missing or empty")
        evs = []
    submitted: set = set()
    retired: set = set()
    for i, e in enumerate(evs):
        if not isinstance(e, dict) or "ph" not in e or "pid" not in e:
            failures.append(f"event {i} is not a trace_event record "
                            f"(missing ph/pid): {e!r}")
            continue
        ph = e["ph"]
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            failures.append(f"event {i} ({ph} {e.get('name')}) has no "
                            "numeric ts")
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            failures.append(f"span {i} ({e.get('name')}) has no dur")
        if ph in ("b", "e") and "id" not in e:
            failures.append(f"async event {i} ({e.get('name')}) has "
                            "no id")
        if ph == "i":
            rid = (e.get("args") or {}).get("rid")
            if e.get("name") == "SUBMIT" and rid is not None:
                submitted.add(rid)
            elif e.get("name") in ("RETIRE", "CANCEL") and rid is not None:
                # CANCEL is terminal like RETIRE: a deadline-cancelled or
                # shed request left the system deliberately, it did not
                # vanish mid-lifecycle
                retired.add(rid)
    if evs and not submitted:
        failures.append("trace has no SUBMIT events (tracer not wired "
                        "into the smoke run?)")
    lost = submitted - retired
    if lost:
        failures.append("submitted rids never retired or cancelled: "
                        f"{sorted(lost)}")
    if failures:
        print(f"[check_bench] trace gate {trace_path}: "
              f"{len(failures)} failure(s):")
        for f_ in failures:
            print(f"  - {f_}")
    else:
        print(f"[check_bench] trace gate {trace_path}: ok "
              f"({len(evs)} events, {len(submitted)} requests "
              "submitted+retired)")
    return len(failures)


def check_tuned(tuned_path: str,
                ops: tuple = ("decode", "prefill", "verify")) -> int:
    """Tuned-shape cache gate: the file the CI tuning tier produces (and
    uploads as an artifact) must be a schema-1 cache with at least one
    entry per op, and every entry must carry a loadable config — a sweep
    that silently persisted nothing (or an op the sweep dropped) fails
    here instead of shipping an empty cache.  Returns the number of
    failed checks (0 == gate passes)."""
    failures = []
    try:
        with open(tuned_path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"[check_bench] cannot read tuned cache {tuned_path}: {e}")
        return 1
    if data.get("schema") != 1:
        failures.append(f"schema {data.get('schema')!r} != 1")
    entries = data.get("entries")
    if not isinstance(entries, dict) or not entries:
        failures.append("entries missing or empty (sweep persisted "
                        "nothing)")
        entries = {}
    for op in ops:
        if not any(f"|{op}|" in k for k in entries):
            failures.append(f"no tuned entry for op {op!r}")
    for key, ent in sorted(entries.items()):
        cfg = ent.get("config") if isinstance(ent, dict) else None
        if not isinstance(cfg, dict):
            failures.append(f"{key}: no config dict")
            continue
        if cfg.get("grid_order") not in (None, "bh", "hb"):
            failures.append(f"{key}: bad grid_order "
                            f"{cfg.get('grid_order')!r}")
        br = cfg.get("block_rows")
        if br is not None and (not isinstance(br, int)
                               or isinstance(br, bool) or br <= 0):
            failures.append(f"{key}: bad block_rows {br!r}")
    if failures:
        print(f"[check_bench] tuned-cache gate {tuned_path}: "
              f"{len(failures)} failure(s):")
        for f_ in failures:
            print(f"  - {f_}")
    else:
        print(f"[check_bench] tuned-cache gate {tuned_path}: ok "
              f"({len(entries)} entries, all ops covered)")
    return len(failures)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=BENCH)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tol", type=float, default=None,
                    help="throughput tolerance factor (default env "
                         "BENCH_TOL or 0.5)")
    ap.add_argument("--out", default=None,
                    help="also write the per-row delta table to this "
                         "file (uploaded as a CI artifact)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also gate a Perfetto trace: loadable, "
                         "non-empty, every submitted rid retired")
    ap.add_argument("--tuned", default=None, metavar="PATH",
                    help="also gate a tuned-shape cache: schema 1, "
                         "non-empty, >=1 entry per op, sane configs")
    ap.add_argument("--tuned-only", action="store_true",
                    help="run only the tuned-cache gate (needs --tuned); "
                         "used by the CI tune-smoke step, which has no "
                         "fresh bench rows to diff")
    args = ap.parse_args()
    if args.tuned_only:
        if not args.tuned:
            ap.error("--tuned-only requires --tuned")
        sys.exit(1 if check_tuned(args.tuned) else 0)
    fails = check(args.bench, args.baseline, args.tol, args.out)
    if args.trace:
        fails += check_trace(args.trace)
    if args.tuned:
        fails += check_tuned(args.tuned)
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
