#!/usr/bin/env python
"""Gate the serving perf trajectory: fresh BENCH_serve.json vs baseline.

Every ``serve_bench`` invocation writes its rows to ``BENCH_serve.json``;
this script compares them against the committed
``benchmarks/baselines/serve_baseline.json`` and fails (exit 1) when the
trajectory regresses — so a PR that quietly halves serving throughput or
breaks page reclamation fails CI instead of landing.  This is the
measurement discipline PrIM-style benchmarking argues for: the numbers
are only meaningful if something checks them on every change.

Checks per baseline row (rows the baseline does not pin are ignored, so
local experiments don't trip the gate):

* ``tok_s``: fresh >= BENCH_TOL x baseline (default 0.5 — wall-clock
  throughput varies across runners; the gate catches collapses, not
  noise).  Skipped with a note when the backends differ (a CPU baseline
  says nothing about TPU throughput).
* ``prefix_hit_rate`` / ``prefill_skipped``: must stay nonzero wherever
  the baseline has them nonzero (the radix cache still hits).
* ``pages_reclaimed``: must stay truthy wherever the baseline pins it
  (retired slots still return their pages).
* ``chunk_joins``: nonzero wherever the baseline has it nonzero (long
  prompts still get chunked).
* ``kv_util_mean``: in (0, 1.5] — paged sharing can push utilization
  above 1.0, but not past every-slot-shares-everything sanity.

Always prints a one-line-per-row delta table (ci.sh runs it last as the
bench summary).

  python scripts/check_bench.py [--bench PATH] [--baseline PATH]
  BENCH_TOL=0.4 python scripts/check_bench.py     # looser throughput gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
BENCH = os.path.join(ROOT, "BENCH_serve.json")
BASELINE = os.path.join(ROOT, "benchmarks", "baselines",
                        "serve_baseline.json")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f).get("rows", {})


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def check(bench_path: str = BENCH, baseline_path: str = BASELINE,
          tol: float | None = None) -> int:
    """Returns the number of failed checks (0 == gate passes)."""
    if tol is None:
        tol = float(os.environ.get("BENCH_TOL", "0.5"))
    try:
        fresh = _load(bench_path)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {bench_path}: {e}")
        return 1
    try:
        base = _load(baseline_path)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {baseline_path}: {e}")
        return 1

    failures = []
    lines = []
    for name in sorted(base):
        brow = base[name]
        frow = fresh.get(name)
        if frow is None:
            failures.append(f"{name}: row missing from {bench_path} "
                            "(bench tier did not run?)")
            lines.append(f"  {name:<22} MISSING")
            continue
        row_fail = []
        notes = []
        b_tok, f_tok = brow.get("tok_s"), frow.get("tok_s")
        if b_tok:
            if brow.get("backend") != frow.get("backend"):
                notes.append(f"tok/s not compared "
                             f"({brow.get('backend')} baseline vs "
                             f"{frow.get('backend')} run)")
            elif f_tok is None or f_tok < tol * b_tok:
                row_fail.append(
                    f"tok_s {_fmt(f_tok)} < {tol:.2f} x baseline "
                    f"{_fmt(b_tok)}")
        for key in ("prefix_hit_rate", "prefill_skipped", "chunk_joins"):
            if brow.get(key) and not frow.get(key):
                row_fail.append(f"{key} dropped to zero "
                                f"(baseline {_fmt(brow[key])})")
        if brow.get("pages_reclaimed") and not frow.get("pages_reclaimed"):
            row_fail.append("pages_reclaimed is no longer true")
        util = frow.get("kv_util_mean")
        if util is not None and not 0.0 < util <= 1.5:
            row_fail.append(f"kv_util_mean {_fmt(util)} outside (0, 1.5]")

        delta = ""
        if b_tok and f_tok and brow.get("backend") == frow.get("backend"):
            delta = f"tok/s {_fmt(f_tok)} vs {_fmt(b_tok)} " \
                    f"({(f_tok / b_tok - 1) * 100:+.0f}%)"
        elif notes:
            delta = notes[0]
        status = "FAIL: " + "; ".join(row_fail) if row_fail else "ok"
        lines.append(f"  {name:<22} {delta:<34} {status}")
        failures.extend(f"{name}: {f}" for f in row_fail)

    print(f"[check_bench] {bench_path} vs {baseline_path} "
          f"(BENCH_TOL={tol:.2f})")
    for line in lines:
        print(line)
    if failures:
        print(f"[check_bench] {len(failures)} regression(s):")
        for f in failures:
            print(f"  - {f}")
    else:
        print("[check_bench] trajectory ok")
    return len(failures)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=BENCH)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tol", type=float, default=None,
                    help="throughput tolerance factor (default env "
                         "BENCH_TOL or 0.5)")
    args = ap.parse_args()
    sys.exit(1 if check(args.bench, args.baseline, args.tol) else 0)


if __name__ == "__main__":
    main()
