#!/usr/bin/env bash
# CI gate: tier-1 tests + serving-throughput liveness checks + the
# bench-trajectory gate (scripts/check_bench.py vs the committed
# benchmarks/baselines/serve_baseline.json).
#
#   scripts/ci.sh            # fast tier: -m "not slow" + serve smokes
#   CI_FULL=1 scripts/ci.sh  # additionally run the slow-marked tests
#
# The property-test tier (tests/test_properties.py, test_kvpool.py
# hypothesis traffic) importorskips hypothesis, so a missing install
# would silently drop that coverage — fail loudly here instead.
# CI_SKIP_HYPOTHESIS=1 opts out on constrained images that cannot
# install it (the skip is then explicit, not silent).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  if [[ "${CI_SKIP_HYPOTHESIS:-0}" == "1" ]]; then
    echo "WARNING: hypothesis not installed; property-test tier will be" \
         "SKIPPED (CI_SKIP_HYPOTHESIS=1)."
  else
    echo "ERROR: hypothesis is not installed, so the property-test tier" \
         "(allocator/radix invariants under random traffic) would be" \
         "silently skipped." >&2
    echo "Fix: pip install hypothesis   (or rerun with" \
         "CI_SKIP_HYPOTHESIS=1 to skip it explicitly)" >&2
    exit 1
  fi
fi

echo "== tier-1 (fast): pytest -m 'not slow' =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow"

if [[ "${CI_FULL:-0}" == "1" ]]; then
  echo "== tier-1 (slow markers) =="
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "slow"
fi

# start the trajectory from scratch: the smokes below must regenerate
# every gated row, so check_bench fails if a tier stopped running rather
# than silently passing on stale committed numbers
rm -f BENCH_serve.json

echo "== serving throughput smoke (dense) =="
timeout 300 python benchmarks/serve_bench.py --smoke

echo "== serving throughput smoke (paged KV cache) =="
timeout 300 python benchmarks/serve_bench.py --paged --smoke

echo "== serving smoke (paged + shared-prefix radix cache) =="
# repeated-system-prompt workload; the smoke asserts a nonzero prefix
# hit rate and that prefill tokens were actually skipped
timeout 300 python benchmarks/serve_bench.py --paged --prefix-cache --smoke

echo "== serving smoke (chunked prefill) =="
# long-prompt workload; the smoke asserts chunk continuations actually
# ran (PREFILLING slots resumed across join rounds)
timeout 300 python benchmarks/serve_bench.py --paged --prefill-chunk 16 --smoke

echo "== serving smoke (self-speculative decoding) =="
# repetitive-continuation workload; the smoke asserts the n-gram drafter
# got drafts accepted (acceptance_rate > 0) at bit-identical output
timeout 300 python benchmarks/serve_bench.py --paged --speculate 3 --smoke

echo "== serving smoke (optimistic admission + forced preemption) =="
# tiny pool + chaos-forced exhaustion (free list raided at round 2,
# returned at round 5); the smoke asserts at least one slot was actually
# preempted and every preempted request completed via recompute-on-resume.
# --trace-out records the run's request-lifecycle trace: the chaos run is
# the richest one (preempt/resume, chaos instants), so it is the one CI
# archives as trace_smoke.json and gates below; --attr-out decomposes the
# same trace into per-request TTFT/TPOT bottleneck components
# (attribution_report.json rides along as an artifact)
timeout 300 python benchmarks/serve_bench.py --paged --optimistic --smoke \
  --trace-out trace_smoke.json --attr-out attribution_report.json

echo "== flight-recorder drill (forced PageError -> debug bundle) =="
# crash-only machinery rots unless something crashes: force a real
# allocator fault mid-run and gate the debug bundle the dying scheduler
# wrote (loadable, ring events precede the failure round, pool snapshot
# partitions cover every page); flight_bundle.json rides as an artifact
timeout 300 python scripts/flight_drill.py --out flight_bundle.json

echo "== bench trajectory vs committed baseline =="
# fails on throughput collapse / lost hit rate / dead drafter / broken
# reclamation, and doubles as the one-line-per-row bench delta summary;
# the table is also written to bench_delta.txt for the CI artifact.
# --trace additionally gates the chaos smoke's Perfetto trace: loadable,
# non-empty, every submitted request retired
python scripts/check_bench.py --out bench_delta.txt --trace trace_smoke.json
