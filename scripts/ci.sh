#!/usr/bin/env bash
# CI gate: tier-1 tests + a serving-throughput liveness check.
#
#   scripts/ci.sh          # from anywhere inside the repo
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== serving throughput smoke =="
timeout 300 python benchmarks/serve_bench.py --smoke
