#!/usr/bin/env bash
# CI gate: tier-1 tests + serving-throughput liveness checks.
#
#   scripts/ci.sh          # fast tier: -m "not slow" + dense/paged smokes
#   CI_FULL=1 scripts/ci.sh  # additionally run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 (fast): pytest -m 'not slow' =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow"

if [[ "${CI_FULL:-0}" == "1" ]]; then
  echo "== tier-1 (slow markers) =="
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "slow"
fi

echo "== serving throughput smoke (dense) =="
timeout 300 python benchmarks/serve_bench.py --smoke

echo "== serving throughput smoke (paged KV cache) =="
timeout 300 python benchmarks/serve_bench.py --paged --smoke

echo "== serving smoke (paged + shared-prefix radix cache) =="
# repeated-system-prompt workload; the smoke asserts a nonzero prefix
# hit rate and that prefill tokens were actually skipped
timeout 300 python benchmarks/serve_bench.py --paged --prefix-cache --smoke
