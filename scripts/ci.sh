#!/usr/bin/env bash
# CI gate: tier-1 tests + serving-throughput liveness checks + the
# bench-trajectory gate (scripts/check_bench.py vs the committed
# benchmarks/baselines/serve_baseline.json) + the kernel tune-smoke
# (bounded autotune sweep; the tuned-shape cache it writes is gated and
# uploaded as an artifact).
#
#   scripts/ci.sh            # fast tier: -m "not slow" + serve smokes
#   CI_FULL=1 scripts/ci.sh  # additionally run the slow-marked tests
#
# The property-test tier (tests/test_properties.py, test_kvpool.py
# hypothesis traffic) importorskips hypothesis, so a missing install
# would silently drop that coverage — fail loudly here instead.
# CI_SKIP_HYPOTHESIS=1 opts out on constrained images that cannot
# install it (the skip is then explicit, not silent).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  if [[ "${CI_SKIP_HYPOTHESIS:-0}" == "1" ]]; then
    echo "WARNING: hypothesis not installed; property-test tier will be" \
         "SKIPPED (CI_SKIP_HYPOTHESIS=1)."
  else
    echo "ERROR: hypothesis is not installed, so the property-test tier" \
         "(allocator/radix invariants under random traffic) would be" \
         "silently skipped." >&2
    echo "Fix: pip install hypothesis   (or rerun with" \
         "CI_SKIP_HYPOTHESIS=1 to skip it explicitly)" >&2
    exit 1
  fi
fi

echo "== tier-1 (fast): pytest -m 'not slow' =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow"

if [[ "${CI_FULL:-0}" == "1" ]]; then
  echo "== tier-1 (slow markers) =="
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "slow"
fi

# start the trajectory from scratch: the smokes below must regenerate
# every gated row, so check_bench fails if a tier stopped running rather
# than silently passing on stale committed numbers
rm -f BENCH_serve.json tuned_shapes.json

# Each smoke tier is "description|serve_bench args".  run_smoke checks
# that the tier actually refreshed BENCH_serve.json (ns-resolution mtime
# before/after): a smoke that exits 0 without writing its row would
# otherwise surface only as a confusing MISSING failure at the
# check_bench step — or worse, pass on a row a previous tier wrote.
run_smoke() {
  local desc=$1; shift
  echo "== serving smoke (${desc}) =="
  local before="absent"
  [[ -f BENCH_serve.json ]] && before=$(stat -c %y BENCH_serve.json)
  local rc=0
  timeout 300 python benchmarks/serve_bench.py "$@" || rc=$?
  if [[ $rc -eq 124 ]]; then
    # name the hung tier and show the last row that *did* land, so the
    # CI log says "overload smoke hung; the last completed tier was X"
    # instead of a bare timeout with no context
    echo "ERROR: smoke '${desc}' timed out after 300s" >&2
    python - <<'EOF' >&2 || true
import json
try:
    rows = json.load(open("BENCH_serve.json")).get("rows", {})
except Exception:
    rows = {}
if rows:
    name = list(rows)[-1]
    print(f"last completed bench row ({name}): "
          f"{json.dumps(rows[name], default=str)}")
else:
    print("no bench rows were written before the timeout")
EOF
    exit 1
  elif [[ $rc -ne 0 ]]; then
    echo "ERROR: smoke '${desc}' failed (exit ${rc})" >&2
    exit "$rc"
  fi
  local after="absent"
  [[ -f BENCH_serve.json ]] && after=$(stat -c %y BENCH_serve.json)
  if [[ "$after" == "absent" || "$after" == "$before" ]]; then
    echo "ERROR: smoke '${desc}' left BENCH_serve.json stale" \
         "(exit 0 but no row written)" >&2
    exit 1
  fi
}

SMOKES=(
  # dense baseline engine
  "dense|--smoke"
  # paged KV-cache block pool
  "paged KV cache|--paged --smoke"
  # repeated-system-prompt workload; asserts nonzero prefix hit rate
  # and that prefill tokens were actually skipped
  "paged + shared-prefix radix cache|--paged --prefix-cache --smoke"
  # long-prompt workload; asserts chunk continuations actually ran
  # (PREFILLING slots resumed across join rounds)
  "chunked prefill|--paged --prefill-chunk 16 --smoke"
  # repetitive-continuation workload; asserts the n-gram drafter got
  # drafts accepted (acceptance_rate > 0) at bit-identical output
  "self-speculative decoding|--paged --speculate 3 --smoke"
  # tiny pool + chaos-forced exhaustion; asserts at least one slot was
  # preempted and every preempted request completed via
  # recompute-on-resume.  --trace-out records the richest lifecycle
  # trace (preempt/resume, chaos instants) as trace_smoke.json for the
  # gate below; --attr-out decomposes it into per-request TTFT/TPOT
  # bottleneck components (attribution_report.json rides as an artifact)
  "optimistic admission + forced preemption|--paged --optimistic --smoke \
--trace-out trace_smoke.json --attr-out attribution_report.json"
  # chaos burst into a tight pool with the degradation controller on;
  # asserts requests were actually cancelled and shed (check_bench gates
  # cancellations/shed_requests nonzero + recovered_to_healthy + a sane
  # deadline_attainment on the smoke-overload row)
  "overload protection|--paged --overload --smoke"
  # bounded kernel-autotune sweep (<=4 measured candidates per op,
  # 2 reps, one geometry): winners land as autotune-* rows and persist
  # to tuned_shapes.json, gated + uploaded as the tuning-tier artifact
  "kernel autotune tier|--autotune-compare --smoke \
--tuned-out tuned_shapes.json"
)
for entry in "${SMOKES[@]}"; do
  # shellcheck disable=SC2086  # args are a flat flag list, split wanted
  run_smoke "${entry%%|*}" ${entry#*|}
done

echo "== flight-recorder drill (forced PageError -> debug bundle) =="
# crash-only machinery rots unless something crashes: force a real
# allocator fault mid-run and gate the debug bundle the dying scheduler
# wrote (loadable, ring events precede the failure round, pool snapshot
# partitions cover every page); flight_bundle.json rides as an artifact
timeout 300 python scripts/flight_drill.py --out flight_bundle.json

echo "== bench trajectory vs committed baseline =="
# fails on throughput collapse / lost hit rate / dead drafter / broken
# reclamation, and doubles as the one-line-per-row bench delta summary;
# the table is also written to bench_delta.txt for the CI artifact.
# --trace additionally gates the chaos smoke's Perfetto trace (loadable,
# non-empty, every submitted request retired); --tuned gates the
# tune-smoke's cache (schema 1, >=1 entry per op, sane configs)
python scripts/check_bench.py --out bench_delta.txt \
  --trace trace_smoke.json --tuned tuned_shapes.json
