#!/usr/bin/env python
"""Flight-recorder drill: crash the scheduler on purpose, gate the bundle.

The flight recorder (``ServeConfig.flight_recorder``) is the always-on
bounded ring the scheduler dumps when a :class:`PageError` escapes the
run loop.  Like any crash-only machinery it rots unless something
actually crashes — so CI runs this drill: a tiny serving wave with a
chaos injector that, at a configured round, drives a *real* allocator
fault through the real pool (a double ``reserve`` for a live slot),
then validates the debug bundle the dying run wrote:

* the bundle file exists and is loadable JSON with ``schema == 1``;
* ``error`` names PageError and ``round`` is the failure round;
* the event ring is non-empty and every event's round precedes (or is)
  the failure round — the recorder captured the run *up to* the fault,
  not some stale or future state;
* the slot table, pool snapshot, config and metrics sections are
  present, and the pool snapshot partitions cover every page.

Exit 0 when all checks pass, 1 otherwise (CI fails loudly).

  python scripts/flight_drill.py [--out flight_bundle.json] [--round N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax                                      # noqa: E402
import jax.numpy as jnp                         # noqa: E402
import numpy as np                              # noqa: E402

from repro.configs import get_config            # noqa: E402
from repro.models import param as pm            # noqa: E402
from repro.models.model_zoo import Model        # noqa: E402
from repro.serve.chaos import ChaosInjector     # noqa: E402
from repro.serve.engine import ServeConfig      # noqa: E402
from repro.serve.kvpool import PageError        # noqa: E402
from repro.serve.scheduler import Batcher       # noqa: E402


class PoolFaultInjector(ChaosInjector):
    """From ``fault_round`` on, at the first round with a live slot,
    issue a second ``reserve`` for it — the pool itself raises (slot
    already holds pages), so the fault travels the same allocator path
    a real double-mapping bug would."""

    def __init__(self, fault_round: int):
        super().__init__(check_invariants=True)
        self.fault_round = fault_round
        self.fired = False

    def on_round(self, batcher) -> None:
        super().on_round(batcher)
        if (not self.fired and batcher.round >= self.fault_round
                and batcher.pool is not None):
            live = [i for i, rid in enumerate(batcher.slot_rid)
                    if rid is not None]
            if live:
                self.fired = True
                batcher.pool.reserve(live[0], 1)


def drill(out_path: str, fault_round: int = 3) -> list[str]:
    """Run the forced-crash wave; return a list of gate failures."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(0)))
    scfg = ServeConfig(max_len=48, batch=2, dtype=jnp.float32,
                       sync_every=4, paged=True, page_size=8,
                       total_pages=10, flight_path=out_path)
    b = Batcher(model, params, scfg,
                chaos=PoolFaultInjector(fault_round))
    rng = np.random.default_rng(0)
    for rid in range(3):
        b.submit(rid, rng.integers(0, cfg.vocab, size=10).tolist())
    try:
        b.run(max_new=8)
    except PageError as err:
        print(f"[flight_drill] PageError raised as planned: {err}")
    else:
        return ["the injected pool fault never raised — drill is dead"]

    failures: list[str] = []
    try:
        with open(out_path) as f:
            bundle = json.load(f)
    except (OSError, ValueError) as e:
        return [f"bundle {out_path} not loadable: {e}"]
    if bundle.get("schema") != 1:
        failures.append(f"bundle schema {bundle.get('schema')!r} != 1")
    if "PageError" not in bundle.get("error", ""):
        failures.append(f"error field does not name PageError: "
                        f"{bundle.get('error')!r}")
    fail_round = bundle.get("round")
    events = bundle.get("events") or []
    if not events:
        failures.append("event ring is empty")
    for e in events:
        if e.get("round") is not None and e["round"] > fail_round:
            failures.append(f"event {e.get('kind')} at round {e['round']} "
                            f"postdates the failure round {fail_round}")
            break
    for section in ("config", "slot_table", "pool", "metrics"):
        if not bundle.get(section):
            failures.append(f"bundle section {section!r} missing/empty")
    pool = bundle.get("pool") or {}
    if pool:
        partitions = (len(pool.get("free", []))
                      + len(pool.get("cached", []))
                      + len(pool.get("preempted", []))
                      + len(pool.get("held", []))
                      + sum(len(p) for p in pool.get("slot_pages", [])))
        if partitions != pool.get("n_pages"):
            failures.append(
                f"pool snapshot partitions cover {partitions} pages "
                f"!= n_pages {pool.get('n_pages')}")
    if not failures:
        print(f"[flight_drill] bundle ok: {len(events)} ring events, "
              f"failure at round {fail_round}, "
              f"last event round {events[-1].get('round')}, "
              f"{len(pool.get('free', []))} free pages at death "
              f"-> {out_path}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="flight_bundle.json",
                    help="where the dying run writes its debug bundle")
    ap.add_argument("--round", type=int, default=3,
                    help="scheduling round at which the pool fault fires")
    args = ap.parse_args()
    failures = drill(args.out, args.round)
    if failures:
        print(f"[flight_drill] {len(failures)} failure(s):")
        for f_ in failures:
            print(f"  - {f_}")
        sys.exit(1)


if __name__ == "__main__":
    main()
