"""Training driver: data pipeline + jitted step + checkpoint/restart +
failure handling + straggler monitoring.

Local runs use whatever devices exist (``make_host_mesh``); on a pod the
same driver runs under the production mesh.  The loop survives injected
failures by restoring the latest checkpoint — onto a *smaller* elastic
mesh if devices were lost — and continues the exact data stream.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 50 --batch 8 --seq 128 --reduced --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config
from ..data import DataConfig, DataPipeline
from ..ft import FailureInjector, StragglerMonitor
from ..ft.elastic import SimulatedFailure
from ..models.model_zoo import Model
from ..train import optimizer as opt
from ..train.train_loop import (TrainConfig, make_train_state,
                                make_train_step, split_microbatches)


def run(arch: str, *, steps: int = 20, batch: int = 8, seq: int = 128,
        reduced: bool = True, ckpt_dir: str | None = None,
        ckpt_every: int = 10, accum: int = 1, lr: float = 3e-4,
        fail_at: tuple[int, ...] = (), seed: int = 0,
        log_every: int = 5, compress_grads: bool = False) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    tcfg = TrainConfig(opt=opt.OptConfig(lr=lr, warmup_steps=max(2, steps // 10),
                                         total_steps=steps),
                       accum=accum, remat=not reduced,
                       compress_grads=compress_grads)
    data = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                   global_batch=batch, seed=seed))
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    injector = FailureInjector(fail_at)
    monitor = StragglerMonitor()

    state = make_train_state(model, jax.random.key(seed), tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        state, manifest = ckpt.restore(state)
        start = manifest["step"]
        data.load_state_dict(manifest["extra"].get("data", {"step": start}))
        print(f"[train] restored step {start}", flush=True)

    losses = []
    step = start
    while step < steps:
        try:
            injector.check(step)
            monitor.step_start()
            raw = data.batch_at(step)
            batch_dev = {k: jnp.asarray(v) for k, v in raw.items()}
            batch_dev = split_microbatches(batch_dev, tcfg.accum)
            state, metrics = step_fn(state, batch_dev)
            if monitor.step_end(step):
                print(f"[train] step {step}: straggler flagged "
                      f"(rate {monitor.straggle_rate:.0%})", flush=True)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step}: loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}",
                      flush=True)
            step += 1
            data.step = step
            if ckpt and step % ckpt_every == 0:
                ckpt.save_async(step, state,
                                extra={"data": data.state_dict(),
                                       "arch": arch})
        except SimulatedFailure as exc:
            print(f"[train] {exc}; restoring from checkpoint", flush=True)
            if ckpt is None or ckpt.latest_step() is None:
                print("[train] no checkpoint; restarting from scratch",
                      flush=True)
                state = make_train_state(model, jax.random.key(seed), tcfg)
                step = 0
            else:
                ckpt.wait()
                state, manifest = ckpt.restore(state)
                step = manifest["step"]
                data.load_state_dict(
                    manifest["extra"].get("data", {"step": step}))
                print(f"[train] resumed at step {step}", flush=True)
    if ckpt:
        ckpt.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "straggle_rate": monitor.straggle_rate}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", dest="ckpt_dir")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    out = run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
              reduced=args.reduced, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, accum=args.accum, lr=args.lr,
              fail_at=tuple(args.fail_at),
              compress_grads=args.compress_grads)
    print(f"[train] done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
