"""Serving driver: continuous batching through the device-resident decode
loop (slot table + fused ``lax.scan`` segments, see repro.serve.scheduler).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import param as pm
from ..models.model_zoo import Model
from ..serve.engine import ServeConfig
from ..serve.scheduler import Batcher


def run(arch: str, *, reduced: bool = True, requests: int = 4,
        max_new: int = 8, batch: int = 4, max_len: int = 64,
        seed: int = 0, sync_every: int = 8, temperature: float = 0.0,
        eos_id: int | None = None, attn_mode: str = "auto",
        paged: bool = False, page_size: int = 16,
        total_pages: int | None = None, prefix_cache: bool = False,
        shared_prefix: int = 0, admission: str = "fifo",
        prefill_chunk: int | None = None,
        prefill_round_tokens: int | None = None,
        speculate_k: int | None = None,
        speculate_ngram: int = 2, optimistic: bool = False,
        trace_out: str | None = None,
        ttft_slo: float | None = None,
        tpot_slo: float | None = None,
        overload: bool = False,
        deadline_s: float | None = None,
        timeout_s: float | None = None,
        watchdog_rounds: int = 100_000) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = pm.unwrap(model.init(jax.random.key(seed)))
    scfg = ServeConfig(max_len=max_len, batch=batch, sync_every=sync_every,
                       temperature=temperature, attn_mode=attn_mode,
                       paged=paged, page_size=page_size,
                       total_pages=total_pages, prefix_cache=prefix_cache,
                       admission=admission, prefill_chunk=prefill_chunk,
                       prefill_round_tokens=prefill_round_tokens,
                       speculate_k=speculate_k,
                       speculate_ngram=speculate_ngram,
                       admission_mode="optimistic" if optimistic
                       else "reserve",
                       telemetry=bool(trace_out),
                       ttft_slo_s=ttft_slo, tpot_slo_s=tpot_slo,
                       overload=overload,
                       watchdog_rounds=watchdog_rounds)
    b = Batcher(model, params, scfg, eos_id=eos_id, seed=seed)
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab, size=shared_prefix).tolist()
    for rid in range(requests):
        prompt = system + rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(4, 12))).tolist()
        b.submit(rid, prompt, deadline_s=deadline_s, timeout_s=timeout_s)
    t0 = time.perf_counter()
    results = b.run(max_new=max_new)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in results.values())
    util = b.kv_utilization()
    pstats = b.prefix_stats()
    mode = (f"paged pool {b.pool.n_pages}x{b.pool.page_size}" if paged
            else "dense")
    if prefill_chunk:
        j = b.join_stats()
        mode += (f" + chunked prefill ({prefill_chunk} tok/chunk, "
                 f"{j['chunk_joins']} continuations, max join stall "
                 f"{j['max_join_s'] * 1e3:.0f}ms)")
        if prefill_round_tokens:
            mode += (f" + round budget ({prefill_round_tokens} tok, "
                     f"{j['budget_deferrals']} deferrals)")
    if prefix_cache:
        mode += (f" + prefix cache (hit rate "
                 f"{pstats['hit_rate']:.0%}, "
                 f"{pstats['prefill_skipped']} prefill tokens skipped)")
    sstats = b.spec_stats()
    if speculate_k:
        mode += (f" + speculative k={speculate_k} (acceptance "
                 f"{sstats['acceptance_rate']:.0%}, "
                 f"{sstats['tokens_per_step']:.2f} tok/step)")
    kstats = b.preempt_stats()
    if optimistic:
        mode += (f" + optimistic admission ({kstats['preemptions']} "
                 f"preemptions, {kstats['recompute_tokens']} tokens "
                 "recomputed)")
    lat = b.latency_stats()
    slo = b.slo_stats()
    print(f"[serve] {len(results)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s on {jax.default_backend()}, {mode}, "
          f"KV util {util['mean_util']:.0%}, TTFT p50 "
          f"{lat['ttft_p50_s'] * 1e3:.0f}ms)")
    if slo["enabled"]:
        print(f"[serve] SLO attainment {slo['slo_attainment']:.0%} "
              f"(ttft<={ttft_slo}s, tpot<={tpot_slo}s; burn rate "
              f"ttft {slo['burn_rate_ttft']:.2f} / "
              f"tpot {slo['burn_rate_tpot']:.2f} over the last "
              f"{slo['window']} samples)")
    ostats = b.overload_stats()
    if overload or deadline_s is not None or timeout_s is not None \
            or ostats["cancellations"]:
        ctl = ostats["controller"]
        by = ", ".join(f"{r}={n}" for r, n
                       in ostats["cancelled_by_reason"].items() if n)
        print(f"[serve] overload: {ostats['cancellations']} cancelled "
              f"({by or 'none'}), {ostats['shed_requests']} shed, "
              f"deadline attainment {ostats['deadline_attainment']:.0%} "
              f"({ostats['deadline_met']}/{ostats['deadline_total']}), "
              f"controller {ctl['state']}, "
              f"watchdog trips {ostats['watchdog_trips']}")
    attribution = None
    if trace_out:
        from ..serve.attribution import attribution_report
        attribution = attribution_report(b.telemetry)
        if attribution["requests"]:
            dom = attribution["dominant_ttft_component"]
            share = attribution["ttft"][dom]["share"]
            print(f"[serve] dominant TTFT component: {dom} "
                  f"({share:.0%} of total TTFT across "
                  f"{attribution['requests']} requests)")
        b.telemetry.to_perfetto(trace_out)
        print(f"[serve] wrote Perfetto trace -> {trace_out} "
              f"({len(b.telemetry.events)} events; open at "
              "ui.perfetto.dev)")
    return {"results": results, "tok_per_s": toks / dt, "kv_util": util,
            "prefix": pstats, "spec": sstats, "latency": lat,
            "preempt": kstats, "slo": slo, "overload": ostats,
            "attribution": attribution}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--attn-mode", default="auto",
                    choices=("auto", "kernel", "xla"))
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: block pool + per-slot page tables")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--total-pages", type=int, default=None,
                    help="pool size in pages (default: dense-equivalent)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix radix cache over the page pool "
                         "(needs --paged): requests matching a cached "
                         "page-aligned prompt prefix share its pages and "
                         "prefill only their suffix; retired prefix pages "
                         "stay resident (evictable, LRU) at zero reserved "
                         "capacity")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens "
                         "to every request (exercises --prefix-cache)")
    ap.add_argument("--admission", default="fifo",
                    choices=("fifo", "skip-ahead"),
                    help="paged admission order: fifo blocks on the queue "
                         "head; skip-ahead admits the first queued request "
                         "whose pages fit (bounded lookahead, aged so a "
                         "blocked head cannot starve)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill (needs --paged): prefill each "
                         "prompt's uncached suffix at most this many "
                         "tokens per join round (multiple of --page-size), "
                         "interleaving long-prompt admission with decode "
                         "segments to bound the join stall")
    ap.add_argument("--prefill-round-tokens", type=int, default=None,
                    help="decode-priority budget: cap the total prefill "
                         "tokens (chunks + admissions) one refill round "
                         "may take, deferring the rest to later rounds")
    ap.add_argument("--speculate", type=int, default=None,
                    help="self-speculative decoding (needs --paged, "
                         "greedy): draft this many tokens per step from "
                         "the slot's own history (n-gram lookup) and "
                         "verify them in one multi-token paged attention "
                         "call — bit-identical output, fewer steps on "
                         "repetitive continuations")
    ap.add_argument("--speculate-ngram", type=int, default=2,
                    help="history-match width of the draft lookup")
    ap.add_argument("--optimistic", action="store_true",
                    help="optimistic admission (needs --paged): admit on "
                         "the prompt's pages only and grow on demand, "
                         "preempting the lowest-priority / most-pages / "
                         "least-progress slot on pool pressure "
                         "(recompute-on-resume, bit-identical output)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record the run's request-lifecycle trace and "
                         "write it as Chrome/Perfetto trace_event JSON "
                         "(open at ui.perfetto.dev); also prints the "
                         "dominant TTFT bottleneck component from the "
                         "latency-attribution report")
    ap.add_argument("--ttft-slo", type=float, default=None, metavar="S",
                    help="TTFT SLO in seconds: the run reports per-class "
                         "attainment and windowed burn rate")
    ap.add_argument("--tpot-slo", type=float, default=None, metavar="S",
                    help="per-output-token SLO in seconds (see "
                         "--ttft-slo)")
    ap.add_argument("--overload", action="store_true",
                    help="enable the SLO-burn/pool-pressure degradation "
                         "controller (HEALTHY -> DEGRADED -> SHEDDING "
                         "with hysteresis): sheds speculation, shrinks "
                         "prefill chunks, freezes optimistic growth, and "
                         "sheds lowest-priority queued work under "
                         "sustained overload")
    ap.add_argument("--deadline-s", type=float, default=None, metavar="S",
                    help="stamp every request with this completion "
                         "deadline; expired or provably-unreachable "
                         "requests are cancelled and their pages "
                         "reclaimed")
    ap.add_argument("--timeout-s", type=float, default=None, metavar="S",
                    help="hard per-request wall-clock timeout (cancelled "
                         "with reason 'timeout' when exceeded)")
    ap.add_argument("--watchdog-rounds", type=int, default=100_000,
                    help="progress watchdog: rounds without any forward "
                         "progress before the scheduler dumps a flight "
                         "bundle and force-sheds the blocking request")
    args = ap.parse_args()
    run(args.arch, reduced=args.reduced, requests=args.requests,
        max_new=args.max_new, batch=args.batch, max_len=args.max_len,
        sync_every=args.sync_every, temperature=args.temperature,
        eos_id=args.eos_id, attn_mode=args.attn_mode, paged=args.paged,
        page_size=args.page_size, total_pages=args.total_pages,
        prefix_cache=args.prefix_cache, shared_prefix=args.shared_prefix,
        admission=args.admission, prefill_chunk=args.prefill_chunk,
        prefill_round_tokens=args.prefill_round_tokens,
        speculate_k=args.speculate, speculate_ngram=args.speculate_ngram,
        optimistic=args.optimistic, trace_out=args.trace_out,
        ttft_slo=args.ttft_slo, tpot_slo=args.tpot_slo,
        overload=args.overload, deadline_s=args.deadline_s,
        timeout_s=args.timeout_s, watchdog_rounds=args.watchdog_rounds)


if __name__ == "__main__":
    main()
