import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the step function (train / prefill / decode) is jit'd with explicit
shardings, ``.lower(...)``'d on ShapeDtypeStruct inputs, ``.compile()``'d,
and its ``memory_analysis()`` / ``cost_analysis()`` / collective schedule
recorded to ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh multi                             # one cell

Cells are resumable: existing artifacts are skipped unless --force.
The per-cell compile runs in a fresh subprocess by default (--fork) so a
pathological cell cannot take down the sweep.
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time
import traceback

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mesh(kind: str):
    from .mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def _accum_for(arch_cfg) -> int:
    # keep microbatch activations ~2k tokens per data-shard row
    return 8 if arch_cfg.d_model >= 4096 else 2


def _train_dtypes(arch_cfg):
    """Param/moment dtypes: bf16 state for the near-trillion class."""
    import jax.numpy as jnp
    big = arch_cfg.d_model >= 6144 or (arch_cfg.moe is not None
                                       and arch_cfg.moe.n_experts >= 64)
    return (jnp.bfloat16, jnp.bfloat16) if big else (jnp.float32,
                                                     jnp.float32)


def lower_cell(arch: str, shape_name: str, mesh_kind: str):
    """Build the jitted step for one cell and lower it (no compile)."""
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..configs.base import SHAPES
    from ..distributed import sharding as shd
    from ..distributed.act_sharding import activation_policy
    from ..models.model_zoo import Model
    from ..serve.engine import ServeConfig, jit_decode_step
    from ..train import optimizer as opt
    from ..train.train_loop import (TrainConfig, batch_shardings,
                                    jit_train_step, split_microbatches)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    mesh = _mesh(mesh_kind)
    specs = model.input_specs(shape)

    if shape.kind == "train":
        pdt, mdt = _train_dtypes(cfg)
        tcfg = TrainConfig(opt=opt.OptConfig(moment_dtype=mdt),
                           accum=_accum_for(cfg), remat=True,
                           param_dtype=pdt)
        batch = split_microbatches(specs["batch"], tcfg.accum)
        params = model.abstract_params(dtype=pdt)
        state = {"params": params,
                 "opt": {"mu": jax.tree_util.tree_map(
                     lambda s: jax.ShapeDtypeStruct(s.shape, mdt), params),
                     "nu": jax.tree_util.tree_map(
                     lambda s: jax.ShapeDtypeStruct(s.shape, mdt), params),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)},
                 "ef": None}
        step = jit_train_step(model, tcfg, mesh, specs["batch"])
        with activation_policy(mesh):
            return step.lower(state, batch), mesh

    if shape.kind == "prefill":
        scfg = ServeConfig(max_len=shape.seq_len, batch=shape.global_batch)
        params = model.abstract_params(dtype=jnp.bfloat16)
        pshard = shd.param_shardings(model.abstract_ptree(), mesh)
        bshard = shd.data_shardings(specs["batch"], mesh)

        def prefill_step(p, b):
            return model.prefill(p, b, scfg.max_len, dtype=jnp.bfloat16)

        step = jax.jit(prefill_step, in_shardings=(pshard, bshard))
        with activation_policy(mesh):
            return step.lower(params, specs["batch"]), mesh

    # decode
    scfg = ServeConfig(max_len=shape.seq_len, batch=shape.global_batch)
    params = model.abstract_params(dtype=jnp.bfloat16)
    step = jit_decode_step(model, scfg, mesh, specs)
    with activation_policy(mesh):
        return step.lower(params, specs["tokens"], specs["caches"],
                          specs["cache_len"], specs["extra"]), mesh


COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:[a-z0-9]+\[[^\]]*\])(?:, [a-z0-9]+\[[^\]]*\])*|\([^)]*\))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\(",
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes_str, kind = m.group(2), m.group(3)
        total = 0.0
        for sm in SHAPE_RE.finditer(shapes_str):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0.0) + total
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_path: pathlib.Path) -> dict:
    t0 = time.time()
    lowered, mesh = lower_cell(arch, shape_name, mesh_kind)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as exc:
        mem_info = {"error": str(exc)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
    except Exception as exc:
        cost = {"error": str(exc)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    try:
        from ..roofline.hlo_analyzer import analyze_hlo
        hlo_stats = analyze_hlo(hlo).as_dict()
    except Exception as exc:
        hlo_stats = {"error": str(exc)}
    n_dev = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_devices": int(n_dev),
        "mesh_shape": {k: int(v) for k, v in
                       zip(mesh.axis_names, mesh.devices.shape)},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "cost_raw": {k: v for k, v in cost.items()
                     if k in ("flops", "bytes accessed", "transcendentals")},
        "collective_bytes": coll,
        "hlo_stats": hlo_stats,
        "hlo_bytes": len(hlo),
    }
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    return record


def all_cells():
    from ..configs import ALL_ARCHS, get_config, shapes_for
    for arch in ALL_ARCHS:
        for shape in shapes_for(get_config(arch)):
            for mesh_kind in ("single", "multi"):
                yield arch, shape.name, mesh_kind


def cell_path(arch: str, shape: str, mesh_kind: str) -> pathlib.Path:
    return ARTIFACTS / f"{arch}__{shape}__{mesh_kind}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fork", action="store_true",
                    help="run each cell in a fresh subprocess")
    args = ap.parse_args()

    if args.all:
        cells = list(all_cells())
    else:
        if not (args.arch and args.shape and args.mesh):
            ap.error("--all or all of --arch/--shape/--mesh")
        cells = [(args.arch, args.shape, args.mesh)]

    failures = []
    for arch, shape, mesh_kind in cells:
        path = cell_path(arch, shape, mesh_kind)
        tag = f"{arch} x {shape} x {mesh_kind}"
        if path.exists() and not args.force:
            print(f"[skip] {tag}", flush=True)
            continue
        if args.fork and len(cells) > 1:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
            if args.force:
                cmd.append("--force")
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=7200)
            ok = r.returncode == 0 and path.exists()
            print(f"[{'ok' if ok else 'FAIL'}] {tag}", flush=True)
            if not ok:
                failures.append(tag)
                err = (r.stderr or "")[-2000:]
                (path.parent / f"FAIL_{path.stem}.log").parent.mkdir(
                    parents=True, exist_ok=True)
                (path.parent / f"FAIL_{path.stem}.log").write_text(err)
            continue
        try:
            rec = run_cell(arch, shape, mesh_kind, path)
            print(f"[ok] {tag}: compile {rec['compile_s']}s "
                  f"flops={rec.get('flops')} "
                  f"coll={ {k: f'{v/1e9:.2f}GB' for k, v in rec['collective_bytes'].items()} }",
                  flush=True)
            # headline evidence for EXPERIMENTS.md §Dry-run
            print(f"     memory: {rec['memory']}", flush=True)
        except Exception:
            failures.append(tag)
            print(f"[FAIL] {tag}\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"FAILED cells: {failures}", flush=True)
        return 1
    print("all cells ok", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
