"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single-pod: 16x16 = 256 chips (data, model).  Multi-pod:
2x16x16 = 512 chips (pod, data, model) — the pod axis is the slowest
(DCN-connected) dimension and carries only data parallelism.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run "
            "under launch/dryrun.py (XLA_FLAGS host-platform device count) "
            "or on real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever-fits mesh for local runs/examples (1 device -> (1, 1))."""
    n = len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
