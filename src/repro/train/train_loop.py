"""Train-step factory: value_and_grad + microbatch accumulation + AdamW,
jitted with explicit in/out shardings and donated state.

Gradient accumulation is a ``lax.scan`` over microbatches (compute/comm
overlap: each microbatch's backward reduce-scatters overlap the next
microbatch's forward under XLA latency-hiding scheduling), with grads
accumulated in f32.  Optional int8 gradient compression (error feedback)
from repro.distributed.compression hooks in before the optimizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from . import optimizer as opt
from ..distributed import sharding as shd
from ..models import param as pm
from ..models.model_zoo import Model


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt.OptConfig = opt.OptConfig()
    accum: int = 1                   # microbatches per step
    remat: bool = True
    dtype: Any = jnp.bfloat16        # activation dtype
    param_dtype: Any = jnp.float32
    compress_grads: bool = False     # int8 error-feedback all-reduce


def make_train_state(model: Model, key: jax.Array, cfg: TrainConfig,
                     mesh: Mesh | None = None):
    """Init params+opt state, optionally sharded onto a mesh."""
    ptree = model.init(key)
    params = pm.unwrap(ptree)
    if cfg.param_dtype != jnp.float32:
        params = jax.tree_util.tree_map(
            lambda x: x.astype(cfg.param_dtype)
            if x.dtype == jnp.float32 else x, params)
    state = {"params": params, "opt": opt.init_state(params, cfg.opt),
             "ef": None}
    if cfg.compress_grads:
        state["ef"] = jax.tree_util.tree_map(jnp.zeros_like, params)
    return state


def state_shardings(model: Model, cfg: TrainConfig, mesh: Mesh):
    ptree = model.abstract_ptree()
    pshard = shd.param_shardings(ptree, mesh)
    return {"params": pshard,
            "opt": {"mu": pshard, "nu": pshard,
                    "step": shd.replicated(mesh)},
            "ef": pshard if cfg.compress_grads else None}


def batch_shardings(batch_specs: dict, mesh: Mesh, accum: int):
    """Batch arrays are [accum, mb, ...] when accumulating: dim1 = batch."""
    return shd.data_shardings(batch_specs, mesh,
                              batch_dim=1 if accum > 1 else 0)


def split_microbatches(batch: dict, accum: int) -> dict:
    if accum == 1:
        return batch

    def split(x):
        b = x.shape[0]
        shape = (accum, b // accum) + x.shape[1:]
        if isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, x.dtype)
        return x.reshape(shape)
    return jax.tree_util.tree_map(split, batch)


def make_train_step(model: Model, cfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` arrays are [accum, micro, ...] when cfg.accum > 1.
    """

    def loss_fn(params, microbatch):
        return model.loss(params, microbatch, dtype=cfg.dtype,
                          remat=cfg.remat)

    def grads_fn(params, batch):
        if cfg.accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        def micro(carry, mb):
            loss_acc, gacc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (loss_acc + loss, gacc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(
            micro, (jnp.zeros((), jnp.float32), g0), batch)
        inv = 1.0 / cfg.accum
        return loss_sum * inv, jax.tree_util.tree_map(
            lambda g: g * inv, gsum)

    def train_step(state, batch):
        loss, grads = grads_fn(state["params"], batch)
        ef = state.get("ef")
        if cfg.compress_grads and ef is not None:
            from ..distributed.compression import compress_tree
            grads, ef = compress_tree(grads, ef)
        params, opt_state, metrics = opt.apply_updates(
            state["params"], grads, state["opt"], cfg.opt)
        new_state = {"params": params, "opt": opt_state, "ef": ef}
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def jit_train_step(model: Model, cfg: TrainConfig, mesh: Mesh,
                   batch_specs: dict):
    """AOT-friendly jitted step with explicit shardings."""
    step = make_train_step(model, cfg)
    sshard = state_shardings(model, cfg, mesh)
    bshard = batch_shardings(batch_specs, mesh, cfg.accum)
    mshard = {"loss": shd.replicated(mesh), "grad_norm": shd.replicated(mesh),
              "lr": shd.replicated(mesh)}
    return jax.jit(step,
                   in_shardings=(sshard, bshard),
                   out_shardings=(sshard, mshard),
                   donate_argnums=(0,))
