"""Training: optimizer, step factory, grad accumulation, remat."""
