"""AdamW + schedules, pure JAX (no external deps).

Moments inherit the parameters' sharding (fully-sharded optimizer state —
ZeRO/FSDP semantics fall out of the 2-D param sharding).  ``moment_dtype``
lets trillion-parameter-class configs halve optimizer memory (bf16 moments
with error-compensating f32 update math).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32


def schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_state(params: Any, cfg: OptConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)  # noqa: E731
    return {"mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params: Any, grads: Any, state: dict,
                  cfg: OptConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
    c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = mu32 / c1
        vhat = nu32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat = jax.tree_util.tree_map(upd, params, grads, state["mu"],
                                  state["nu"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
