"""Fault tolerance: failure injection, elastic re-meshing, stragglers.

At thousand-node scale the assumptions are: (1) nodes *will* fail
mid-run, (2) the job must resume from the last checkpoint on a smaller
(or repaired) mesh without data loss or duplication, (3) slow nodes must
not silently set the fleet's pace.

* :class:`FailureInjector` — deterministic chaos hook for tests/examples:
  raises ``SimulatedFailure`` at configured steps.
* :class:`ElasticPlan` — given the surviving device count, picks the
  largest (data, model) mesh the checkpoint can restore onto (model axis
  preserved when possible — param layouts survive; the data/FSDP axis
  shrinks) and re-partitions the data pipeline.
* :class:`StragglerMonitor` — per-step wall-time tracker: flags steps
  slower than ``threshold`` x the trailing median and recommends eviction
  of persistently slow ranks (the host-level mitigation; in-step, XLA's
  collectives already gang-schedule).
"""
from __future__ import annotations

import dataclasses
import statistics
import time


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps: tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.triggered: list[int] = []

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.triggered.append(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pod: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pod

    @staticmethod
    def for_devices(n_available: int, *, model: int = 16,
                    prefer_pods: int = 1) -> "ElasticPlan":
        """Largest restorable mesh: keep the model axis (so parameter
        layouts survive), shrink pod first, then the data/FSDP axis."""
        for pod in range(prefer_pods, 0, -1):
            if n_available < model * pod:
                continue
            data = n_available // (model * pod)
            if data >= 1:
                return ElasticPlan(data=data, model=model, pod=pod)
        # degenerate: shrink model too (params re-layout on restore)
        m = model
        while m > 1 and n_available < m:
            m //= 2
        return ElasticPlan(data=max(1, n_available // m), model=m)

    def make_mesh(self):
        import jax
        shape = ((self.pod, self.data, self.model) if self.pod > 1
                 else (self.data, self.model))
        names = (("pod", "data", "model") if self.pod > 1
                 else ("data", "model"))
        devs = jax.devices()[:self.n_devices]
        return jax.make_mesh(shape, names, devices=devs)


class StragglerMonitor:
    def __init__(self, threshold: float = 1.5, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.flags: list[int] = []
        self._t0: float | None = None

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> bool:
        """Returns True if this step straggled."""
        dt = time.perf_counter() - self._t0
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 8:
            med = statistics.median(hist)
            if dt > self.threshold * med:
                self.flags.append(step)
                return True
        return False

    @property
    def straggle_rate(self) -> float:
        return len(self.flags) / max(1, len(self.times))

    def should_evict(self, recent: int = 16, max_flags: int = 4) -> bool:
        """Persistent straggling -> recommend rank eviction + elastic
        re-mesh (the driver acts on this)."""
        cutoff = max(0, len(self.times) - recent)
        return sum(1 for f in self.flags
                   if f >= cutoff) >= max_flags
