from .elastic import ElasticPlan, FailureInjector, StragglerMonitor  # noqa: F401
