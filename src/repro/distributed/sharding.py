"""Logical-axis sharding rules (MaxText-style) -> NamedShardings.

Parameters carry logical axis names (see repro.models.param.P); the rules
here map them onto the production mesh:

  * ``model`` carries tensor/expert parallelism: heads, mlp hidden, vocab,
    experts;
  * ``data`` doubles as the FSDP axis: the *embed* dim of every weight is
    sharded over it (params + optimizer state fully sharded; XLA inserts
    the per-layer all-gathers under the layer scan = FSDP semantics);
  * ``batch`` shards over ``(pod, data)``.

Conflict + divisibility handling: a mesh axis is used at most once per
tensor (first dim wins), and any mapping whose axis-size product does not
divide the dim falls back to fewer axes (then replication).  That rule is
what lets kv_heads=2 models replicate KV while kv_heads=32 models shard it,
and batch=1 long-context cells replicate batch — with no per-arch tables.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models import param as pm

LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    # §Perf iter 2: vocab-dim sharding over BOTH axes for embedding/LM-head
    # tables; their embed dim stays replicated ("embed_r") so the logits
    # contraction never partial-sums over a sharded d (the observed 17.9
    # GB/step all-reduce).  Other weights keep embed->data (FSDP).
    "vocab": ("model", "data"),
    "embed_r": (),
    "embed": ("data",),          # FSDP
    # §Perf iter 3: context parallelism — when an arch's head count does
    # not divide the model axis (qwen2 14H, starcoder2 24H, whisper 6H),
    # attention would otherwise replicate across all 16 model ranks; the
    # attention layer shards its sequence dim instead.
    "ctx": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "q_lora": ("model",),
    "kv_lora": (),
    "mlp": ("model",),
    "experts": ("model",),
    "conv": (),
    "state": (),
    "seq": (),
    "layers": (),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def spec_for(axes: tuple[str | None, ...], shape: tuple[int, ...],
             mesh: Mesh, rules: dict | None = None) -> PartitionSpec:
    rules = rules or LOGICAL_RULES
    used: set[str] = set()
    entries: list[Any] = []
    for dim, name in zip(shape, axes):
        targets = rules.get(name, ()) if name else ()
        targets = tuple(t for t in targets
                        if t in mesh.axis_names and t not in used)
        # progressively drop axes until the product divides the dim
        while targets:
            prod = math.prod(_axis_size(mesh, t) for t in targets)
            if prod > 1 and dim % prod == 0:
                break
            targets = targets[:-1]
        if targets and math.prod(_axis_size(mesh, t)
                                 for t in targets) > 1:
            used.update(targets)
            entries.append(targets if len(targets) > 1 else targets[0])
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def param_shardings(param_tree: Any, mesh: Mesh,
                    rules: dict | None = None) -> Any:
    """Tree of P -> tree of NamedSharding (stacked segment params get a
    leading replicated 'layers' dim, detected by rank mismatch)."""
    def leaf(p: pm.P):
        axes = tuple(p.axes)
        shape = p.value.shape
        if len(axes) == len(shape) - 1:      # vmap-stacked (scan segment)
            axes = (None,) + axes
        elif len(axes) != len(shape):
            raise ValueError(f"axes {axes} vs shape {shape}")
        return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))

    return jax.tree_util.tree_map(leaf, param_tree, is_leaf=pm.is_param)


def like_tree(shardings: Any, value_tree: Any) -> Any:
    """Match a P-structured sharding tree to an unwrapped value tree."""
    return shardings


def batch_spec(shape: tuple[int, ...], mesh: Mesh,
               extra: tuple[str | None, ...] | None = None) -> PartitionSpec:
    """Sharding for an activation whose dim0 is batch."""
    axes = ("batch",) + (extra or (None,) * (len(shape) - 1))
    return spec_for(axes, shape, mesh)


def data_shardings(tree: Any, mesh: Mesh, *, batch_dim: int = 0) -> Any:
    """Shard every array in a pytree along its batch dim (replicate rest)."""
    def leaf(x):
        shape = x.shape
        axes: list[str | None] = [None] * len(shape)
        if len(shape) > batch_dim:
            axes[batch_dim] = "batch"
        return NamedSharding(mesh, spec_for(tuple(axes), shape, mesh))
    return jax.tree_util.tree_map(leaf, tree)


def cache_shardings(caches: Any, mesh: Mesh) -> Any:
    """KV/SSM cache shardings: [layers, batch, seq|*, heads-ish, ...].

    dim0 = stacked layers (replicated), dim1 = batch.  Attention caches
    shard their *sequence* dim over the model axis (§Perf iter 5: split-KV
    decode — every model rank attends over a KV slice; the online-softmax
    combine is a tiny all-reduce, vs. re-gathering the cache every step,
    which the baseline measured at 106 GB/step for internvl2 decode).
    kv_heads pick up the model axis only when the seq dim can't.
    """
    def leaf(path, x):
        shape = x.shape
        axes: list[str | None] = [None] * len(shape)
        if len(shape) >= 2:
            axes[1] = "batch"
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        if "state" in key and len(shape) >= 4:     # [L, B, H, P, N]
            axes[2] = "heads"
        elif "conv" in key and len(shape) >= 4:    # [L, B, k, C]
            axes[3] = "mlp"
        elif len(shape) == 4:                      # MLA latent [L, B, S, r]
            axes[2] = "ctx"
        elif len(shape) >= 5:                      # attn [L, B, S, H, D]
            axes[2] = "ctx"
            axes[3] = "kv_heads"
        return NamedSharding(mesh, spec_for(tuple(axes), shape, mesh))
    return jax.tree_util.tree_map_with_path(leaf, caches)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())
