"""Activation sharding constraints (a la MaxText's logical constraints).

Without constraints, XLA's sharding propagation is free to carry the FSDP
weight sharding into activations — it will happily compute the *full
global batch* on every device over a d_model/16 slice (observed in the
baseline dry-run: per-device dots of shape [524288, 56] for qwen2 train;
§Perf iteration 1).  Pinning activations to batch sharding at block
boundaries forces the partitioner into the intended data-parallel plan:
weights all-gather per layer (FSDP), activations stay [batch/N, ...].

The policy is a context manager so model code stays mesh-agnostic: smoke
tests run without a mesh (constraints no-op), the dry-run/train paths
activate the policy around tracing.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .sharding import spec_for

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "act_sharding_mesh", default=None)


@contextlib.contextmanager
def activation_policy(mesh: Mesh):
    token = _ACTIVE.set(mesh)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def constrain(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    """Apply a logical-axes sharding constraint if a policy is active."""
    mesh = _ACTIVE.get()
    if mesh is None or not hasattr(x, "shape"):
        return x
    if len(axes) != len(x.shape):
        return x
    spec = spec_for(axes, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def constrain_btd(x: jax.Array) -> jax.Array:
    """[batch, seq, d] activations: shard batch, replicate the rest."""
    return constrain(x, ("batch", None, None))


def axis_size(name: str) -> int:
    mesh = _ACTIVE.get()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def context_shard_wanted(n_heads: int, seq_len: int) -> bool:
    """Context parallelism pays when heads can't shard the model axis."""
    m = axis_size("model")
    return m > 1 and n_heads % m != 0 and seq_len > 1 and seq_len % m == 0


def constrain_ctx(x: jax.Array) -> jax.Array:
    """[batch, seq, d]: shard the sequence dim over the model axis."""
    return constrain(x, ("batch", "ctx", None))
