"""Gradient compression: int8 quantization with error feedback.

Before the optimizer consumes gradients, each leaf is quantized to int8
with a per-tensor scale; the quantization residual is carried in an error-
feedback buffer and added back next step (Seide et al. / EF-SGD semantics;
convergence verified in tests/test_substrate.py and the train-integration
test).

Scope note (honest accounting): under plain pjit, XLA performs the
gradient cross-replica reduction inside the backward pass in f32 — this
module's quantization runs *after* that, so it bounds optimizer-state
noise but does not shrink wire traffic by itself.  Wire-level int8
reduction requires owning the collective (per-shard grads inside
shard_map + a manual quantized psum); that integration is logged as
§Perf future work alongside the shard_map MoE a2a.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / INT8_MAX + 1e-12
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jnp.ndarray,
                  ef: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (decompressed gradient, new error-feedback buffer)."""
    g32 = g.astype(jnp.float32) + ef.astype(jnp.float32)
    q, scale = quantize(g32)
    deq = dequantize(q, scale)
    return deq, (g32 - deq).astype(ef.dtype)


def compress_tree(grads: Any, ef: Any) -> tuple[Any, Any]:
    pairs = jax.tree_util.tree_map(compress_leaf, grads, ef)
    out_g = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
    out_e = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return out_g, out_e
