"""Distributed runtime: logical-axis sharding, collectives, compression."""
