"""Oracle: GQA attention gathered through a page table — one-token decode
and multi-token (suffix) prefill at per-slot depth offsets."""
import math

import jax
import jax.numpy as jnp

from ..decode_attn.ref import decode_attn_ref


def gather_pages(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """pool: [N, ps, ...]; table: [B, P] int32 page ids (entries >= N are
    unallocated and clamp to the last page — callers mask by length).
    Returns the contiguous view [B, P * ps, ...]."""
    n, ps = pool.shape[:2]
    gathered = pool[jnp.minimum(table, n - 1)]        # [B, P, ps, ...]
    return gathered.reshape((table.shape[0], table.shape[1] * ps)
                            + pool.shape[2:])


def paged_attn_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                   v_pages: jnp.ndarray, table: jnp.ndarray,
                   lengths: jnp.ndarray) -> jnp.ndarray:
    """q: [B, Hq, D]; k_pages/v_pages: [N, ps, Hkv, D]; table: [B, P];
    lengths: [B] int32 — slot b attends over its first lengths[b] tokens
    in page-table order."""
    k = gather_pages(k_pages, table)
    v = gather_pages(v_pages, table)
    return decode_attn_ref(q, k, v, lengths)


def paged_prefill_attn_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, table: jnp.ndarray,
                           q_offset: jnp.ndarray,
                           kv_len: jnp.ndarray) -> jnp.ndarray:
    """Multi-token causal GQA attention through a page table: q [B, L, Hq,
    D] are suffix queries sitting at per-slot depths ``q_offset`` [B] (the
    cached-prefix lengths of a suffix-only prefill); slot b's query at
    position ``q_offset[b] + t`` attends over its first
    ``min(q_offset[b] + t + 1, kv_len[b])`` gathered tokens.

    The math mirrors models.attention._dense_attn's vectorized branch
    exactly (same einsum contractions, f32 score masking, weights cast
    back to the query dtype) so routing a prefill through the pages is
    bit-identical to the dense path the parity tests pin."""
    b, lq, hq, d = q.shape
    k = gather_pages(k_pages, table).astype(q.dtype)
    v = gather_pages(v_pages, table).astype(q.dtype)
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, lq, hkv, g, d)
    lk = k.shape[1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / math.sqrt(d)
    scores = scores.astype(jnp.float32)
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                           (b,))
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    kpos = jnp.arange(lk)
    qpos = off[:, None, None] + jnp.arange(lq)[:, None]       # [B, Lq, 1]
    mask = (kpos[None, None, :] <= qpos) \
        & (kpos[None, None, :] < kvl[:, None, None])          # [B, Lq, Lk]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(b, lq, hq, v.shape[-1])
