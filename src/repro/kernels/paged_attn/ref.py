"""Oracle: one-token GQA attention gathered through a page table."""
import jax.numpy as jnp

from ..decode_attn.ref import decode_attn_ref


def gather_pages(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """pool: [N, ps, ...]; table: [B, P] int32 page ids (entries >= N are
    unallocated and clamp to the last page — callers mask by length).
    Returns the contiguous view [B, P * ps, ...]."""
    n, ps = pool.shape[:2]
    gathered = pool[jnp.minimum(table, n - 1)]        # [B, P, ps, ...]
    return gathered.reshape((table.shape[0], table.shape[1] * ps)
                            + pool.shape[2:])


def paged_attn_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                   v_pages: jnp.ndarray, table: jnp.ndarray,
                   lengths: jnp.ndarray) -> jnp.ndarray:
    """q: [B, Hq, D]; k_pages/v_pages: [N, ps, Hkv, D]; table: [B, P];
    lengths: [B] int32 — slot b attends over its first lengths[b] tokens
    in page-table order."""
    k = gather_pages(k_pages, table)
    v = gather_pages(v_pages, table)
    return decode_attn_ref(q, k, v, lengths)
