from .ops import paged_attn, paged_attn_xla, paged_prefill_attn  # noqa: F401
from .ref import (gather_pages, paged_attn_ref,  # noqa: F401
                  paged_prefill_attn_ref)
