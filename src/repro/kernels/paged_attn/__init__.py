# the autotune *submodule* stays addressable (autotune.autotune(...) runs
# a sweep); its data types are re-exported flat
from . import autotune  # noqa: F401
from .autotune import (Candidate, Geometry,  # noqa: F401
                       enumerate_candidates, entry_key, load_entries,
                       make_workload, prune, resolve_cache_path,
                       save_entries)
from .ops import (PagedAttnTelemetry, amenability_reports,  # noqa: F401
                  attn_telemetry,
                  paged_attn, paged_attn_xla,
                  paged_prefill_attn, paged_prefill_attn_pallas,
                  paged_verify_attn)
from .ref import (gather_pages, paged_attn_ref,  # noqa: F401
                  paged_prefill_attn_ref)
