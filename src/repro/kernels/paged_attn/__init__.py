from .ops import paged_attn, paged_attn_xla  # noqa: F401
from .ref import gather_pages, paged_attn_ref  # noqa: F401
