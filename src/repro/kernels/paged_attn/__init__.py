from .ops import (PagedAttnTelemetry, amenability_reports,  # noqa: F401
                  attn_telemetry,
                  paged_attn, paged_attn_xla,
                  paged_prefill_attn, paged_prefill_attn_pallas,
                  paged_verify_attn)
from .ref import (gather_pages, paged_attn_ref,  # noqa: F401
                  paged_prefill_attn_ref)
