"""Paged GQA prefill attention (flash-prefill over a page table).

The suffix-only prefill regime (PR 3's radix cache, and chunked prefill on
top of it): a block of ``Lq`` new prompt tokens per slot, sitting at a
per-slot absolute depth ``q_offset[b]`` (its resident cached-prefix /
already-prefilled length), attends causally over everything below it —
shared prefix pages, earlier chunks and the block's own K/V, all resident
in the pooled ``[n_pages, page_size, Hkv, D]`` allocation and named by the
``[B, max_pages]`` table.  Unlike :mod:`kernel` (one query row, pure
memory-bound), the query block here re-uses every fetched page across
``Lq * G`` rows, so the kernel is the compute-bound sibling: same page
walk, fatter matmuls.

Layout mirrors the decode kernel.  The page table, per-slot query offsets
and per-slot live lengths are scalar-prefetched, so the BlockSpec index
map for grid step ``(b, h, r, p)`` redirects the K/V DMA to physical page
``table[b, p]`` — the gather costs nothing extra.  Queries are pre-folded
to ``[B, Hkv, Lq * G, D]`` (row ``r`` is query token ``r // G``, group
member ``r % G``) so the block keeps D on the 128-lane axis and the fused
(query, group) rows on sublanes; the flash accumulator (m, l, acc) is
staged in VMEM across the page walk.

Command skipping (§5.1.2) at page granularity, same two levels as decode:

* inside the kernel, ``pl.when(page_base < kv_len)`` makes every page past
  a slot's live depth a no-op (the accumulator carries through) and the
  dead page's DMA is redirected to the slot's first page, so no fresh HBM
  line is touched;
* causality adds a third skip decode does not have: a page strictly above
  *every* query row of the block (``page_base > q_offset + top_row // G``)
  is dead too — with chunked prefill most of the table is either below the
  chunk (prefix: mask-free full compute) or above it (skipped), so the
  per-chunk work stays O(depth), not O(table width);
* the caller prunes the grid by slicing the table to the page-count
  bucket, exactly like the decode path.

Tunable launch geometry (see :mod:`autotune`):

* ``block_rows`` tiles the fused ``Lq * G`` sublane axis: instead of one
  block of every query row, the grid grows a row-block axis of
  ``Lq * G // block_rows`` steps, each staging a ``[block_rows, D]``
  query block and its own flash accumulator across the page walk.
  Smaller row blocks shrink the VMEM working set and let the causal
  top-skip fire per row block (a deep row block never pays for pages
  only the shallow rows need), at the cost of re-walking the pages once
  per block.  ``block_rows`` must divide ``Lq * G``; per query row the
  accumulation sequence over pages is unchanged, so outputs are
  numerically equivalent — but not guaranteed bit-identical on every
  backend, because XLA may lower the block matmuls differently by
  shape (CPU interpret does, by ulps).  The autotuner parity-gates
  candidates against the default shape and discards non-exact ones, so
  *tuned* configs are always bit-exact on the backend that tuned them.
* ``grid_order`` picks the outer-axis majorness exactly as in the decode
  kernel (``"bh"`` slot-major, ``"hb"`` head-major).  The row-block and
  page axes always stay innermost, pages last — the accumulator scratch
  must see one (slot, head, row-block)'s full page walk contiguously.

The fully-masked-row hazard of flash attention (a row whose max stays
``-inf`` would normalize garbage) cannot arise here: page 0 holds key
position 0, which every query row ``q_offset + t >= 0`` may attend to, so
after the first live page every row's running max is finite.  Rows of a
slot with ``kv_len == 0`` never enter compute and produce zeros.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .kernel import GRID_ORDERS, _axes


def _make_kernel(ps: int, g: int, scale: float, b_axis: int):
    def kernel(tbl_ref, off_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref):
        bi = pl.program_id(b_axis)
        r = pl.program_id(2)
        p = pl.program_id(3)
        np_ = pl.num_programs(3)
        off = off_ref[bi]
        ln = len_ref[bi]
        br = m_ref.shape[0]               # rows of this block (<= Lq * G)

        @pl.when(p == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, -1e30)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        base = p * ps
        # fused row r*br + j is query token (r*br + j) // g at absolute
        # position off + that token index
        row0 = r * br
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
        qpos = off + rows // g                                # [br, 1]
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)

        # page-granular command skipping, both ends of the causal window:
        # pages past the slot's live depth AND pages strictly above every
        # query row of this row block do no compute (their DMA was
        # redirected to the slot's first page, so no new HBM line was
        # pulled either)
        @pl.when((base < ln) & (base <= off + (row0 + br - 1) // g))
        def _():
            q = q_ref[0, 0]                  # [br, D]
            k = k_ref[0, :, 0, :]            # [ps, D]
            v = v_ref[0, :, 0, :]
            scores = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # [br, ps]
            live = (kpos <= qpos) & (kpos < ln)               # [br, ps]
            scores = jnp.where(live, scores, -1e30)
            m_prev = m_ref[...]              # [br, 1]
            m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
            pexp = jnp.exp(scores - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + pexp.sum(axis=-1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                pexp.astype(jnp.float32), v.astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(p == np_ - 1)
        def _():
            o_ref[0, 0] = (acc_ref[...]
                           / jnp.maximum(l_ref[...], 1e-30)
                           ).astype(o_ref.dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("g", "interpret",
                                             "block_rows", "grid_order"))
def paged_prefill_attn_kernel(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray, table: jnp.ndarray,
                              q_offset: jnp.ndarray, kv_len: jnp.ndarray,
                              *, g: int, interpret: bool = True,
                              block_rows: int | None = None,
                              grid_order: str = "bh") -> jnp.ndarray:
    """q: [B, Hkv, Lq * G, D] fused query rows (row ``r`` = token ``r // g``
    of group member ``r % g``); k_pages/v_pages: [N, ps, Hkv, D] pooled
    pages; table: [B, P] int32, every entry < N (callers clamp sentinels);
    q_offset/kv_len: [B] int32 per-slot depth of the query block and total
    live KV length (``q_offset + Lq`` for a suffix prefill).
    ``block_rows`` (must divide ``Lq * G``; default: all rows in one
    block) and ``grid_order`` tune the launch geometry — outputs are
    numerically equivalent across valid settings; bit-exactness per
    backend is verified by the autotuner (see module docstring)."""
    b, hkv, lg, d = q.shape
    ps = k_pages.shape[1]
    p_max = table.shape[1]
    br = lg if block_rows is None else int(block_rows)
    if br <= 0 or lg % br:
        raise ValueError(f"block_rows={block_rows} must divide the fused "
                         f"query-row count Lq*G={lg}")
    b_axis, h_axis = _axes(grid_order)
    grid = [0, 0, lg // br, p_max]
    grid[b_axis], grid[h_axis] = b, hkv
    grid = tuple(grid)

    def kv_map(i0, i1, r, p, tbl, off, ln):
        bi, h = (i0, i1)[b_axis], (i0, i1)[h_axis]
        # dead pages (past the live depth, or above the whole row block)
        # re-fetch the slot's first page instead of pulling a fresh line
        base = p * ps
        dead = (base >= ln[bi]) | (base > off[bi] + (r * br + br - 1) // g)
        pg = jnp.where(dead, tbl[bi, 0], tbl[bi, p])
        return (pg, 0, h, 0)

    def q_map(i0, i1, r, p, tbl, off, ln):
        bi, h = (i0, i1)[b_axis], (i0, i1)[h_axis]
        return (bi, h, r, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, br, d), q_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, br, d), q_map),
        scratch_shapes=[pltpu.VMEM((br, 1), jnp.float32),
                        pltpu.VMEM((br, 1), jnp.float32),
                        pltpu.VMEM((br, d), jnp.float32)],
    )
    return pl.pallas_call(
        _make_kernel(ps, g, 1.0 / math.sqrt(d), b_axis),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, lg, d), q.dtype),
        interpret=interpret)(table, q_offset, kv_len, q, k_pages, v_pages)
