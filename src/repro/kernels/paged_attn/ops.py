"""Public paged decode-attention op.

``paged_attn`` is the page-table analogue of ``decode_attn``: one-token
GQA queries against K/V pages gathered through a per-slot page table, with
per-slot live lengths.  Grid pruning is shape-driven — callers slice the
table to a host-known bound on the deepest live slot's page count (the
serving engine's page-count bucketing), so the kernel grid *is* the pruned
page count; per-slot skipping inside the kernel handles the rest.

Routing (kernel vs XLA gather, interpret on/off) reuses the
``DecodeAttnPolicy`` from :mod:`repro.kernels.decode_attn` — the decision
is about the backend, not about which cache layout is in play.

Sentinel handling: unallocated table entries are ``>= n_pages`` (the
pool's OOB id, chosen so cache *scatters* through them drop).  For reads
they are clamped to a valid page here, once, and masked by ``lengths``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from .kernel import paged_attn_kernel
from .prefill_kernel import paged_prefill_attn_kernel
from .ref import gather_pages


class PagedAttnTelemetry:
    """Host-side timing hooks for the paged-attention ops.

    Disabled by default, in which case every op takes a single
    ``if not enabled`` branch and nothing else — no timing, no device
    sync, no allocation.  When enabled, each public op records under a
    ``(op, route)`` key (op in ``decode`` / ``prefill`` / ``verify``,
    route in ``kernel`` / ``xla``):

    * ``calls`` — total invocations;
    * ``traced_calls`` — the subset seen under a jax trace (inside
      ``jit`` / ``scan``), where the op runs once per *compile*, not per
      step, and wall time would be trace time — so those calls are
      counted but never timed or synced;
    * ``tokens`` — query-token volume (B × Lq), from static shapes so
      it is meaningful for traced calls too;
    * ``wall_s`` — eager-call wall time, measured around a
      ``block_until_ready`` on the op's output.  Only eager calls pay
      this sync; jitted serving paths are untouched by design.
    """

    def __init__(self):
        self.enabled = False
        self.stats: dict = {}

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        self.stats = {}

    def _bump(self, op: str, route: str, tokens: int, *,
              traced: bool = False, wall: float = 0.0) -> None:
        d = self.stats.setdefault((op, route), {
            "calls": 0, "traced_calls": 0, "tokens": 0, "wall_s": 0.0})
        d["calls"] += 1
        d["traced_calls"] += int(traced)
        d["tokens"] += tokens
        d["wall_s"] += wall

    def snapshot(self) -> dict:
        """Flat ``{"op.route": {...}}`` copy for reporting."""
        return {f"{op}.{route}": dict(d)
                for (op, route), d in sorted(self.stats.items())}


_TELEMETRY = PagedAttnTelemetry()


def attn_telemetry() -> PagedAttnTelemetry:
    """The module-level :class:`PagedAttnTelemetry` instance shared by
    every op in this module."""
    return _TELEMETRY


def _recorded(op: str, route: str, q: jnp.ndarray, fn, *args, **kw):
    """Run ``fn(*args, **kw)``, attributing it to ``(op, route)``.

    Token volume comes from ``q``'s static shape (B × Lq; Lq = 1 for
    [B, H, D] decode queries).  Traced calls are counted but not timed:
    a ``block_until_ready`` under trace would be wrong twice over (it
    measures tracing, and it would land inside the caller's jit)."""
    tel = _TELEMETRY
    tokens = int(q.shape[0]) * (int(q.shape[1]) if q.ndim == 4 else 1)
    if isinstance(q, jax.core.Tracer):
        tel._bump(op, route, tokens, traced=True)
        return fn(*args, **kw)
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    tel._bump(op, route, tokens, wall=time.perf_counter() - t0)
    return out


def _clamp_table(table: jnp.ndarray, n_pages: int) -> jnp.ndarray:
    return jnp.minimum(table.astype(jnp.int32), n_pages - 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_attn_jit(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, table: jnp.ndarray,
                    lengths: jnp.ndarray, *,
                    interpret: bool = True) -> jnp.ndarray:
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    tbl = _clamp_table(table, k_pages.shape[0])
    ln = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    out = paged_attn_kernel(qg, k_pages, v_pages, tbl, ln,
                            interpret=interpret)
    return out.reshape(b, hq, d)


def paged_attn(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
               table: jnp.ndarray, lengths: jnp.ndarray, *,
               interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, D] one-token queries; k_pages/v_pages: [N, ps, Hkv, D]
    pooled pages; table: [B, P] int32; slot b attends over the first
    ``lengths[b]`` tokens of its pages in table order."""
    if not _TELEMETRY.enabled:
        return _paged_attn_jit(q, k_pages, v_pages, table, lengths,
                               interpret=interpret)
    return _recorded("decode", "kernel", q, _paged_attn_jit,
                     q, k_pages, v_pages, table, lengths,
                     interpret=interpret)


def paged_attn_xla(q: jnp.ndarray, k_pages: jnp.ndarray,
                   v_pages: jnp.ndarray, table: jnp.ndarray,
                   lengths: jnp.ndarray) -> jnp.ndarray:
    """Gather-then-attend fallback: identical math on the XLA path (used
    off-TPU where the Pallas interpreter would sit in the hot loop)."""
    if _TELEMETRY.enabled:
        return _recorded("decode", "xla", q, _paged_attn_xla_impl,
                         q, k_pages, v_pages, table, lengths)
    return _paged_attn_xla_impl(q, k_pages, v_pages, table, lengths)


def _paged_attn_xla_impl(q, k_pages, v_pages, table, lengths):
    from ..decode_attn.ref import decode_attn_ref
    k = gather_pages(k_pages, table)
    v = gather_pages(v_pages, table)
    return decode_attn_ref(q, k, v, lengths).astype(q.dtype)


def paged_prefill_attn_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray, table: jnp.ndarray,
                              q_offset: jnp.ndarray, kv_len: jnp.ndarray, *,
                              interpret: bool = True) -> jnp.ndarray:
    """The Pallas flash-prefill path (see :mod:`prefill_kernel`): q
    [B, L, Hq, D] causal suffix queries at per-slot depths ``q_offset``
    [B], over pooled pages masked to ``kv_len``.  Queries are folded to
    [B, Hkv, L * G, D] so the kernel's block rows fuse (token, group) and
    D stays on the lane axis; K/V are cast to the query dtype (the pool
    may hold a narrower storage dtype)."""
    b, lq, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qf = q.reshape(b, lq, hkv, g, d).transpose(0, 2, 1, 3, 4)
    qf = qf.reshape(b, hkv, lq * g, d)
    tbl = _clamp_table(table, k_pages.shape[0])
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                           (b,))
    ln = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    out = paged_prefill_attn_kernel(qf, k_pages.astype(q.dtype),
                                    v_pages.astype(q.dtype), tbl, off, ln,
                                    g=g, interpret=interpret)
    return out.reshape(b, hkv, lq, g, d).transpose(0, 2, 1, 3, 4) \
              .reshape(b, lq, hq, d)


def paged_prefill_attn(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, table: jnp.ndarray,
                       q_offset: jnp.ndarray,
                       kv_len: jnp.ndarray, *,
                       _op: str | None = None) -> jnp.ndarray:
    """Prefill-attention through the page table: multi-token causal GQA
    queries ``q`` [B, L, Hq, D] at per-slot depths ``q_offset`` [B] over
    pooled pages, masked to each slot's ``kv_len``.

    This is the suffix-only prefill path: a joining slot whose prompt
    prefix is already resident (shared prefix pages mapped by the radix
    cache, or written by an earlier prefill chunk) computes attention for
    *only its uncached suffix*, with the gather reading the resident pages
    in place — the prefix KV is neither recomputed nor restored.  Sentinel
    table entries clamp inside the gather and are masked by ``kv_len``.

    Routing follows the same ``DecodeAttnPolicy`` as the decode ops: on
    real TPU backends (or ``mode="kernel"``) this runs the Pallas
    flash-prefill kernel (:mod:`prefill_kernel`), whose page walk skips
    dead pages at both ends of the causal window; elsewhere the XLA
    gather-then-attend reference keeps the interpreter out of the serving
    hot loop.  MLA callers (no per-head pages to walk) stay on the ref.
    """
    from ..decode_attn import active_policy
    pol = active_policy()
    if pol.kernel_wanted():
        if _TELEMETRY.enabled:
            op = _op or ("decode" if q.shape[1] == 1 else "prefill")
            return _recorded(op, "kernel", q, paged_prefill_attn_pallas,
                             q, k_pages, v_pages, table, q_offset, kv_len,
                             interpret=pol.resolve_interpret())
        return paged_prefill_attn_pallas(q, k_pages, v_pages, table,
                                         q_offset, kv_len,
                                         interpret=pol.resolve_interpret())
    from .ref import paged_prefill_attn_ref
    if _TELEMETRY.enabled:
        op = _op or ("decode" if q.shape[1] == 1 else "prefill")
        return _recorded(op, "xla", q, paged_prefill_attn_ref,
                         q, k_pages, v_pages, table, q_offset, kv_len)
    return paged_prefill_attn_ref(q, k_pages, v_pages, table,
                                  q_offset, kv_len)


def paged_verify_attn(q: jnp.ndarray, k_pages: jnp.ndarray,
                      v_pages: jnp.ndarray, table: jnp.ndarray,
                      q_offset: jnp.ndarray,
                      kv_len: jnp.ndarray) -> jnp.ndarray:
    """Speculative-decode **verify** attention: score a slot's current
    token plus its k drafts (``q`` [B, k+1, Hq, D]) in one call at the
    slot's decode depth ``q_offset = lengths``.

    This is *exactly* :func:`paged_prefill_attn` — a verify is a
    multi-token causal query block at absolute depth, indistinguishable
    from a suffix-prefill chunk at the kernel level — re-exported under
    its serving-side name so the contract is explicit:

    * the k+1 K/V rows were scattered at positions ``lengths .. lengths
      + k`` *before* the gather (``_paged_insert`` is position-indexed,
      scatters precede gathers per layer), so draft t attends over
      drafts 0..t-1 through the table like any resident token;
    * **rollback-safety** is a property of that position-indexed insert:
      committing fewer than k+1 tokens just means ``lengths`` advances
      past only the accepted prefix — the stale rows above it sit inside
      the slot's reserved speculation window, are never readable (the
      causal mask bounds every future read at the *new* ``lengths``),
      and the next verify's scatter overwrites them;
    * routing follows the same ``DecodeAttnPolicy``: the Pallas
      flash-prefill kernel on real TPU backends (Lq = k+1 rows fused
      with the GQA group on the sublane axis), the XLA gather ref
      elsewhere.  Nothing k-specific is compiled — one executable serves
      any draft that fits the reserved window.
    """
    return paged_prefill_attn(q, k_pages, v_pages, table, q_offset, kv_len,
                              _op="verify")
