"""Public paged decode-attention op.

``paged_attn`` is the page-table analogue of ``decode_attn``: one-token
GQA queries against K/V pages gathered through a per-slot page table, with
per-slot live lengths.  Grid pruning is shape-driven — callers slice the
table to a host-known bound on the deepest live slot's page count (the
serving engine's page-count bucketing), so the kernel grid *is* the pruned
page count; per-slot skipping inside the kernel handles the rest.

Routing (kernel vs XLA gather, interpret on/off) reuses the
``DecodeAttnPolicy`` from :mod:`repro.kernels.decode_attn` — the decision
is about the backend, not about which cache layout is in play.

Sentinel handling: unallocated table entries are ``>= n_pages`` (the
pool's OOB id, chosen so cache *scatters* through them drop).  For reads
they are clamped to a valid page here, once, and masked by ``lengths``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import paged_attn_kernel
from .prefill_kernel import paged_prefill_attn_kernel
from .ref import gather_pages


class PagedAttnTelemetry:
    """Host-side timing hooks for the paged-attention ops.

    Disabled by default, in which case every op takes a single
    ``if not enabled`` branch and nothing else — no timing, no device
    sync, no allocation.  When enabled, each public op records under a
    ``(op, route)`` key (op in ``decode`` / ``prefill`` / ``verify``,
    route in ``kernel`` / ``xla``):

    * ``calls`` — total invocations;
    * ``traced_calls`` — the subset seen under a jax trace (inside
      ``jit`` / ``scan``), where the op runs once per *compile*, not per
      step, and wall time would be trace time — so those calls are
      counted but never timed or synced;
    * ``tokens`` — query-token volume (B × Lq), from static shapes so
      it is meaningful for traced calls too;
    * ``wall_s`` — eager-call wall time, measured around a
      ``block_until_ready`` on the op's output.  Only eager calls pay
      this sync; jitted serving paths are untouched by design.

    Roofline accounting (live since PR 8) rides on the same hooks: each
    call also contributes analytic traffic estimates from its *static*
    shapes plus the concrete page table/length metadata when available
    (eager calls — under trace the lengths are abstract and the full
    sliced table width is assumed live):

    * ``bytes`` — physical HBM traffic: live K/V pages touched (dead
      pages the kernel's page walk skips are subtracted) × page extent ×
      dtype width × 2, plus Q read + O write + table reads;
    * ``flops`` — attention math, 4 × Hq × D per causally-visible
      (query, kv) pair;
    * ``onchip_bytes`` — logical K/V reads served by on-chip reuse
      (GQA group folding, query rows sharing a page) rather than HBM;
    * ``timed_bytes`` — the ``bytes`` of eager (timed) calls only, so
      ``achieved_gbps`` divides matched numerator/denominator.

    ``snapshot()`` derives ``achieved_gbps`` (timed bytes over eager
    wall time) and ``op_byte`` (flops over physical + on-chip bytes —
    the :class:`~repro.core.amenability.PrimitiveProfile` convention)
    per ``(op, route)``; :func:`amenability_reports` feeds the
    aggregates through the paper's amenability test.
    """

    def __init__(self):
        self.enabled = False
        self.stats: dict = {}

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        self.stats = {}

    def _bump(self, op: str, route: str, tokens: int, *,
              traced: bool = False, wall: float = 0.0,
              mem_bytes: float = 0.0, flops: float = 0.0,
              onchip_bytes: float = 0.0) -> None:
        d = self.stats.setdefault((op, route), {
            "calls": 0, "traced_calls": 0, "tokens": 0, "wall_s": 0.0,
            "bytes": 0.0, "flops": 0.0, "onchip_bytes": 0.0,
            "timed_bytes": 0.0})
        d["calls"] += 1
        d["traced_calls"] += int(traced)
        d["tokens"] += tokens
        d["wall_s"] += wall
        d["bytes"] += mem_bytes
        d["flops"] += flops
        d["onchip_bytes"] += onchip_bytes
        if not traced:
            d["timed_bytes"] += mem_bytes

    def snapshot(self) -> dict:
        """Flat ``{"op.route": {...}}`` copy for reporting, with the
        derived roofline numbers: ``achieved_gbps`` (eager-call bytes
        over eager-call wall, 0 when nothing was timed) and ``op_byte``
        (flops over physical + on-chip bytes)."""
        out: dict = {}
        for (op, route), d in sorted(self.stats.items()):
            row = dict(d)
            row["achieved_gbps"] = (
                row["timed_bytes"] / row["wall_s"] / 1e9
                if row["wall_s"] > 0.0 else 0.0)
            denom = row["bytes"] + row["onchip_bytes"]
            row["op_byte"] = row["flops"] / denom if denom else 0.0
            out[f"{op}.{route}"] = row
        return out


_TELEMETRY = PagedAttnTelemetry()


def attn_telemetry() -> PagedAttnTelemetry:
    """The module-level :class:`PagedAttnTelemetry` instance shared by
    every op in this module."""
    return _TELEMETRY


def amenability_reports(pim=None, gpu=None) -> dict:
    """Run the paper's PIM-amenability test over the *measured* op mix.

    Aggregates the telemetry's per-``(op, route)`` roofline estimates
    into one :class:`~repro.core.amenability.PrimitiveProfile` per op
    (decode / prefill / verify, routes summed — the traffic is a
    property of the math, not the backend) and feeds each through
    :func:`~repro.core.amenability.run_test`.  This is the live
    counterpart of the static profiles in ``core``: op/byte and
    mem-ratio come from what the serving wave actually executed, dead
    pages and speculative verify rows included.

    Returns ``{op: AmenabilityReport}``; empty when telemetry recorded
    nothing (disabled, or no paged-attention calls).
    """
    from ...core.amenability import Interaction, PrimitiveProfile, run_test
    interactions = {
        # one query row, dot-reduce over its resident KV — commutative
        # page-at-a-time accumulation (flash online softmax)
        "decode": Interaction.REDUCTION,
        # chunked causal block: query rows × KV pages interact within
        # the slot's own pages — localized, co-alignable per slot
        "prefill": Interaction.LOCALIZED,
        "verify": Interaction.LOCALIZED,
    }
    agg: dict = {}
    for (op, _route), d in _TELEMETRY.stats.items():
        a = agg.setdefault(op, {"flops": 0.0, "bytes": 0.0, "onchip": 0.0})
        a["flops"] += d["flops"]
        a["bytes"] += d["bytes"]
        a["onchip"] += d["onchip_bytes"]
    reports: dict = {}
    for op, a in sorted(agg.items()):
        if a["bytes"] + a["onchip"] <= 0.0:
            continue
        profile = PrimitiveProfile(
            name=f"paged-attn/{op}",
            ops=a["flops"],
            mem_bytes=a["bytes"],
            onchip_bytes=a["onchip"],
            interaction=interactions.get(op, Interaction.IRREGULAR),
            alignable=True,
            input_dependent_locality=True,
            notes="measured mix; page-table indirection makes locality "
                  "input-dependent (which pages a slot touches is data)")
        reports[op] = run_test(profile, pim, gpu)
    return reports


def _concrete_i64(x) -> "np.ndarray | None":
    """``x`` as a host int64 vector, or None when it is abstract."""
    if x is None or isinstance(x, jax.core.Tracer):
        return None
    try:
        return np.asarray(x, dtype=np.int64).reshape(-1)
    except (TypeError, ValueError):
        return None


def _traffic(q, k_pages, table, lengths, q_offset=None) -> tuple:
    """Analytic ``(mem_bytes, flops, onchip_bytes)`` for one call.

    Physical K/V traffic counts only *live* pages — the pages the
    kernel's walk actually reads.  Decode: ``ceil(lengths[b] / ps)``
    pages per slot; prefill/verify additionally bounds the walk at the
    causal end ``q_offset[b] + Lq``.  When lengths/offsets are abstract
    (the call sits under a jax trace) the full caller-sliced table
    width is assumed live — an upper bound consistent with the grid the
    kernel was actually compiled for.

    FLOPs are 4 × Hq × D per causally-visible (query, kv) pair (QKᵀ
    and PV, 2 each).  On-chip bytes are the logical K/V reads in excess
    of the physical ones: the GQA group (G query heads per KV head) and
    the Lq query rows of a chunk re-read each resident page from
    on-chip storage, not HBM.
    """
    b = int(q.shape[0])
    lq = int(q.shape[1]) if q.ndim == 4 else 1
    hq = int(q.shape[-2])
    d = int(q.shape[-1])
    ps, hkv = int(k_pages.shape[1]), int(k_pages.shape[2])
    p = int(table.shape[-1])
    item = jnp.dtype(k_pages.dtype).itemsize
    qitem = jnp.dtype(q.dtype).itemsize

    ln = _concrete_i64(lengths)
    off = _concrete_i64(q_offset) if q_offset is not None else None
    if ln is not None:
        ln = np.broadcast_to(ln, (b,)).astype(np.int64)
    if ln is None or (q_offset is not None and off is None):
        # abstract metadata: the whole sliced table is assumed live
        kv_end = np.full((b,), p * ps, dtype=np.int64)
        visible = float(b * lq * p * ps)
    elif q_offset is None:
        # decode: one query per slot sees its whole resident context
        kv_end = np.minimum(ln, p * ps)
        visible = float(kv_end.sum())
    else:
        # prefill/verify: causal suffix rows at absolute depths
        off = np.broadcast_to(off, (b,)).astype(np.int64)
        kv_end = np.minimum(np.minimum(ln, off + lq), p * ps)
        i = np.arange(lq, dtype=np.int64)[None, :]
        vis = np.minimum(off[:, None] + i + 1, ln[:, None])
        visible = float(np.clip(vis, 0, p * ps).sum())
    live_pages = np.minimum((np.maximum(kv_end, 0) + ps - 1) // ps, p)
    kv_phys = float(live_pages.sum()) * ps * hkv * d * item * 2
    mem = kv_phys + 2.0 * b * lq * hq * d * qitem + b * p * 4.0
    flops = 4.0 * hq * d * visible
    kv_logical = visible * hq * d * item * 2
    return mem, flops, max(0.0, kv_logical - kv_phys)


def _recorded(op: str, route: str, q: jnp.ndarray, fn, *args,
              traffic: tuple = (0.0, 0.0, 0.0), **kw):
    """Run ``fn(*args, **kw)``, attributing it to ``(op, route)``.

    Token volume comes from ``q``'s static shape (B × Lq; Lq = 1 for
    [B, H, D] decode queries).  Traced calls are counted but not timed:
    a ``block_until_ready`` under trace would be wrong twice over (it
    measures tracing, and it would land inside the caller's jit).
    ``traffic`` is the caller's :func:`_traffic` estimate, accumulated
    alongside."""
    tel = _TELEMETRY
    tokens = int(q.shape[0]) * (int(q.shape[1]) if q.ndim == 4 else 1)
    mem, flops, onchip = traffic
    if isinstance(q, jax.core.Tracer):
        tel._bump(op, route, tokens, traced=True, mem_bytes=mem,
                  flops=flops, onchip_bytes=onchip)
        return fn(*args, **kw)
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    tel._bump(op, route, tokens, wall=time.perf_counter() - t0,
              mem_bytes=mem, flops=flops, onchip_bytes=onchip)
    return out


def _clamp_table(table: jnp.ndarray, n_pages: int) -> jnp.ndarray:
    return jnp.minimum(table.astype(jnp.int32), n_pages - 1)


def _tuned_launch(op: str, q, k_pages, *, lg: int) -> dict:
    """The active policy's tuned launch config for this call shape
    (``{}`` on a miss / tuned loading disabled).  Shapes are static even
    under trace, so resolution works at trace time."""
    from ..decode_attn import active_policy
    return active_policy().tuned_config(
        op, hq=int(q.shape[-2]), hkv=int(k_pages.shape[2]),
        d=int(q.shape[-1]), page_size=int(k_pages.shape[1]), lg=lg) or {}


@functools.partial(jax.jit, static_argnames=("interpret", "grid_order"))
def _paged_attn_jit(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, table: jnp.ndarray,
                    lengths: jnp.ndarray, *,
                    interpret: bool = True,
                    grid_order: str = "bh") -> jnp.ndarray:
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    tbl = _clamp_table(table, k_pages.shape[0])
    ln = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    out = paged_attn_kernel(qg, k_pages, v_pages, tbl, ln,
                            interpret=interpret, grid_order=grid_order)
    return out.reshape(b, hq, d)


def paged_attn(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
               table: jnp.ndarray, lengths: jnp.ndarray, *,
               interpret: bool = True,
               grid_order: str | None = None) -> jnp.ndarray:
    """q: [B, Hq, D] one-token queries; k_pages/v_pages: [N, ps, Hkv, D]
    pooled pages; table: [B, P] int32; slot b attends over the first
    ``lengths[b]`` tokens of its pages in table order.  ``grid_order``
    None resolves through the active policy's tuned-shape cache
    (:mod:`autotune`), falling back to the ``"bh"`` default."""
    if grid_order is None:
        grid_order = _tuned_launch(
            "decode", q, k_pages,
            lg=int(q.shape[-2]) // int(k_pages.shape[2])
        ).get("grid_order", "bh")
    if not _TELEMETRY.enabled:
        return _paged_attn_jit(q, k_pages, v_pages, table, lengths,
                               interpret=interpret, grid_order=grid_order)
    return _recorded("decode", "kernel", q, _paged_attn_jit,
                     q, k_pages, v_pages, table, lengths,
                     traffic=_traffic(q, k_pages, table, lengths),
                     interpret=interpret, grid_order=grid_order)


def paged_attn_xla(q: jnp.ndarray, k_pages: jnp.ndarray,
                   v_pages: jnp.ndarray, table: jnp.ndarray,
                   lengths: jnp.ndarray) -> jnp.ndarray:
    """Gather-then-attend fallback: identical math on the XLA path (used
    off-TPU where the Pallas interpreter would sit in the hot loop)."""
    if _TELEMETRY.enabled:
        return _recorded("decode", "xla", q, _paged_attn_xla_impl,
                         q, k_pages, v_pages, table, lengths,
                         traffic=_traffic(q, k_pages, table, lengths))
    return _paged_attn_xla_impl(q, k_pages, v_pages, table, lengths)


def _paged_attn_xla_impl(q, k_pages, v_pages, table, lengths):
    from ..decode_attn.ref import decode_attn_ref
    k = gather_pages(k_pages, table)
    v = gather_pages(v_pages, table)
    return decode_attn_ref(q, k, v, lengths).astype(q.dtype)


def paged_prefill_attn_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray, table: jnp.ndarray,
                              q_offset: jnp.ndarray, kv_len: jnp.ndarray, *,
                              interpret: bool = True,
                              block_rows: int | None = None,
                              grid_order: str = "bh") -> jnp.ndarray:
    """The Pallas flash-prefill path (see :mod:`prefill_kernel`): q
    [B, L, Hq, D] causal suffix queries at per-slot depths ``q_offset``
    [B], over pooled pages masked to ``kv_len``.  Queries are folded to
    [B, Hkv, L * G, D] so the kernel's block rows fuse (token, group) and
    D stays on the lane axis; K/V are cast to the query dtype (the pool
    may hold a narrower storage dtype).  ``block_rows`` / ``grid_order``
    pass straight to the kernel's launch geometry — tuned-shape
    resolution happens in :func:`paged_prefill_attn`, not here."""
    b, lq, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qf = q.reshape(b, lq, hkv, g, d).transpose(0, 2, 1, 3, 4)
    qf = qf.reshape(b, hkv, lq * g, d)
    tbl = _clamp_table(table, k_pages.shape[0])
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                           (b,))
    ln = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    out = paged_prefill_attn_kernel(qf, k_pages.astype(q.dtype),
                                    v_pages.astype(q.dtype), tbl, off, ln,
                                    g=g, interpret=interpret,
                                    block_rows=block_rows,
                                    grid_order=grid_order)
    return out.reshape(b, hkv, lq, g, d).transpose(0, 2, 1, 3, 4) \
              .reshape(b, lq, hq, d)


def paged_prefill_attn(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, table: jnp.ndarray,
                       q_offset: jnp.ndarray,
                       kv_len: jnp.ndarray, *,
                       grid_order: str | None = None,
                       block_rows: int | None = None,
                       _op: str | None = None) -> jnp.ndarray:
    """Prefill-attention through the page table: multi-token causal GQA
    queries ``q`` [B, L, Hq, D] at per-slot depths ``q_offset`` [B] over
    pooled pages, masked to each slot's ``kv_len``.

    This is the suffix-only prefill path: a joining slot whose prompt
    prefix is already resident (shared prefix pages mapped by the radix
    cache, or written by an earlier prefill chunk) computes attention for
    *only its uncached suffix*, with the gather reading the resident pages
    in place — the prefix KV is neither recomputed nor restored.  Sentinel
    table entries clamp inside the gather and are masked by ``kv_len``.

    Routing follows the same ``DecodeAttnPolicy`` as the decode ops: on
    real TPU backends (or ``mode="kernel"``) this runs the Pallas
    flash-prefill kernel (:mod:`prefill_kernel`), whose page walk skips
    dead pages at both ends of the causal window; elsewhere the XLA
    gather-then-attend reference keeps the interpreter out of the serving
    hot loop.  MLA callers (no per-head pages to walk) stay on the ref.

    ``grid_order`` / ``block_rows`` left None resolve through the active
    policy's tuned-shape cache for this call's (backend, op, geometry)
    key — defaults when no entry matches; explicit values always win
    (the autotuner drives the sweep through them).  The XLA route has no
    launch geometry, so both knobs are ignored there.
    """
    from ..decode_attn import active_policy
    pol = active_policy()
    op = _op or ("decode" if q.shape[1] == 1 else "prefill")
    if pol.kernel_wanted():
        if grid_order is None or block_rows is None:
            g = int(q.shape[2]) // int(k_pages.shape[2])
            cfg = _tuned_launch(op, q, k_pages, lg=int(q.shape[1]) * g)
            if grid_order is None:
                grid_order = cfg.get("grid_order", "bh")
            if block_rows is None:
                block_rows = cfg.get("block_rows")
        if _TELEMETRY.enabled:
            return _recorded(op, "kernel", q, paged_prefill_attn_pallas,
                             q, k_pages, v_pages, table, q_offset, kv_len,
                             traffic=_traffic(q, k_pages, table, kv_len,
                                              q_offset=q_offset),
                             interpret=pol.resolve_interpret(),
                             block_rows=block_rows, grid_order=grid_order)
        return paged_prefill_attn_pallas(q, k_pages, v_pages, table,
                                         q_offset, kv_len,
                                         interpret=pol.resolve_interpret(),
                                         block_rows=block_rows,
                                         grid_order=grid_order)
    from .ref import paged_prefill_attn_ref
    if _TELEMETRY.enabled:
        return _recorded(op, "xla", q, paged_prefill_attn_ref,
                         q, k_pages, v_pages, table, q_offset, kv_len,
                         traffic=_traffic(q, k_pages, table, kv_len,
                                          q_offset=q_offset))
    return paged_prefill_attn_ref(q, k_pages, v_pages, table,
                                  q_offset, kv_len)


def paged_verify_attn(q: jnp.ndarray, k_pages: jnp.ndarray,
                      v_pages: jnp.ndarray, table: jnp.ndarray,
                      q_offset: jnp.ndarray,
                      kv_len: jnp.ndarray, *,
                      grid_order: str | None = None,
                      block_rows: int | None = None) -> jnp.ndarray:
    """Speculative-decode **verify** attention: score a slot's current
    token plus its k drafts (``q`` [B, k+1, Hq, D]) in one call at the
    slot's decode depth ``q_offset = lengths``.

    This is *exactly* :func:`paged_prefill_attn` — a verify is a
    multi-token causal query block at absolute depth, indistinguishable
    from a suffix-prefill chunk at the kernel level — re-exported under
    its serving-side name so the contract is explicit:

    * the k+1 K/V rows were scattered at positions ``lengths .. lengths
      + k`` *before* the gather (``_paged_insert`` is position-indexed,
      scatters precede gathers per layer), so draft t attends over
      drafts 0..t-1 through the table like any resident token;
    * **rollback-safety** is a property of that position-indexed insert:
      committing fewer than k+1 tokens just means ``lengths`` advances
      past only the accepted prefix — the stale rows above it sit inside
      the slot's reserved speculation window, are never readable (the
      causal mask bounds every future read at the *new* ``lengths``),
      and the next verify's scatter overwrites them;
    * routing follows the same ``DecodeAttnPolicy``: the Pallas
      flash-prefill kernel on real TPU backends (Lq = k+1 rows fused
      with the GQA group on the sublane axis), the XLA gather ref
      elsewhere.  Nothing k-specific is compiled — one executable serves
      any draft that fits the reserved window.
    """
    return paged_prefill_attn(q, k_pages, v_pages, table, q_offset, kv_len,
                              grid_order=grid_order, block_rows=block_rows,
                              _op="verify")
