"""Paged GQA decode attention (flash-decode over a page table).

Same regime as :mod:`repro.kernels.decode_attn` — one new token against a
deep KV cache, memory-bound, accumulator staged in VMEM across the KV walk
— but the cache is no longer a per-slot stripe: K/V pages live in one
pooled ``[n_pages, page_size, Hkv, D]`` allocation and each slot names its
pages through a ``[B, max_pages]`` table.  The indirection happens in the
BlockSpec index maps: the page table and per-slot lengths are
scalar-prefetched, so the DMA for grid step ``(b, h, p)`` fetches physical
page ``table[b, p]`` — the gather costs nothing extra, it just redirects
the block fetch.

Command skipping (§5.1.2) lands at page granularity and at two levels:

* inside the kernel, ``pl.when(page_base < len)`` makes every page past a
  slot's live length a no-op (the accumulator carries through), and a dead
  page's DMA is redirected to the slot's first page so no fresh HBM line
  is even touched;
* the caller prunes the grid itself by slicing the table to a host-known
  bound on the deepest live slot's page count (see ops.paged_attn /
  the engine's page-count bucketing) — pages past *every* slot's length
  are never launched.

The page dimension sits where decode_attn's KV-block dimension sat, so
block shapes keep D on the 128-lane axis and the page rows on sublanes.

Tunable launch geometry (see :mod:`autotune`): ``grid_order`` picks which
of the two outer grid axes is major — ``"bh"`` walks slots outermost
(each slot's heads, then pages, consecutively), ``"hb"`` walks KV heads
outermost (all slots' page walks for one head before the next head —
better pool-page locality when slots share prefix pages).  The page axis
always stays innermost: the flash accumulator scratch is carried across
grid steps and must see a slot-head's full page walk contiguously.
Either order visits the same pages with the same per-(slot, head)
accumulation sequence, so outputs are bit-identical.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

GRID_ORDERS = ("bh", "hb")     # batch-major / head-major outer walk


def _axes(grid_order: str) -> tuple[int, int]:
    """(batch_axis, head_axis) grid positions for ``grid_order``."""
    if grid_order not in GRID_ORDERS:
        raise ValueError(f"grid_order must be one of {GRID_ORDERS}, "
                         f"got {grid_order!r}")
    return (0, 1) if grid_order == "bh" else (1, 0)


def _make_kernel(ps: int, scale: float, b_axis: int):
    def kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref):
        bi = pl.program_id(b_axis)
        p = pl.program_id(2)
        np_ = pl.num_programs(2)
        ln = len_ref[bi]

        @pl.when(p == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, -1e30)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        base = p * ps
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)

        # page-granular command skipping: pages past *this slot's* live
        # length do no compute (and their DMA was redirected to page 0 of
        # the slot by the index map, so no new HBM line was pulled either)
        @pl.when(base < ln)
        def _():
            q = q_ref[0, 0]                  # [G, D]
            k = k_ref[0, :, 0, :]            # [ps, D]
            v = v_ref[0, :, 0, :]
            scores = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # [G, ps]
            live = kpos < ln                 # [1, ps] (partial last page)
            scores = jnp.where(live, scores, -1e30)
            m_prev = m_ref[...]              # [G, 1]
            m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
            pexp = jnp.exp(scores - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + pexp.sum(axis=-1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                pexp.astype(jnp.float32), v.astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(p == np_ - 1)
        def _():
            o_ref[0, 0] = (acc_ref[...]
                           / jnp.maximum(l_ref[...], 1e-30)
                           ).astype(o_ref.dtype)
    return kernel


def paged_attn_kernel(q: jnp.ndarray, k_pages: jnp.ndarray,
                      v_pages: jnp.ndarray, table: jnp.ndarray,
                      lengths: jnp.ndarray, *,
                      interpret: bool = True,
                      grid_order: str = "bh") -> jnp.ndarray:
    """q: [B, Hkv, G, D]; k_pages/v_pages: [N, ps, Hkv, D] pooled pages;
    table: [B, P] int32 physical page per (slot, logical page) — every
    entry must be < N (callers clamp sentinels); lengths: [B] int32.
    ``grid_order`` picks the outer grid majorness (see module docstring);
    the page axis is always innermost."""
    b, hkv, g, d = q.shape
    n, ps = k_pages.shape[0], k_pages.shape[1]
    p_max = table.shape[1]
    b_axis, h_axis = _axes(grid_order)
    grid = [0, 0, p_max]
    grid[b_axis], grid[h_axis] = b, hkv
    grid = tuple(grid)

    def kv_map(i0, i1, p, tbl, ln):
        bi, h = (i0, i1)[b_axis], (i0, i1)[h_axis]
        # dead pages re-fetch the slot's first page (always resident for a
        # live slot) instead of pulling a fresh line that will be skipped
        pg = jnp.where(p * ps < ln[bi], tbl[bi, p], tbl[bi, 0])
        return (pg, 0, h, 0)

    def q_map(i0, i1, p, tbl, ln):
        bi, h = (i0, i1)[b_axis], (i0, i1)[h_axis]
        return (bi, h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
    )
    return pl.pallas_call(
        _make_kernel(ps, 1.0 / math.sqrt(d), b_axis), grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret)(table, lengths, q, k_pages, v_pages)
