"""Autotuning harness for the paged-attention kernel family.

The decode / prefill / verify kernels carry tunable launch geometry
(``grid_order`` on both, ``block_rows`` on the prefill/verify row fold —
see :mod:`kernel` and :mod:`prefill_kernel`) that until now ran on
hand-picked defaults validated only under CPU interpret.  This module
mechanizes the PrIM-style sweep the paper argues every primitive needs
before "fast as the hardware allows" claims mean anything:

1. **Enumerate** candidate configs per (backend, op, geometry):
   ``grid_order`` in ``("bh", "hb")`` for every op, plus every divisor of
   the fused ``Lq * G`` row count as ``block_rows`` for prefill/verify.
   Page size is a *geometry* axis, not a candidate axis — it changes the
   pool layout, so the CLI sweeps it as separate geometries.
2. **Prune** with an analytic score that reuses PR 8's
   :func:`repro.kernels.paged_attn.ops._traffic` roofline model:
   per-candidate physical HBM traffic (row blocks re-walk the page list,
   the causal top-skip refunds pages above each block), a sublane-
   occupancy derate on compute, and a per-grid-step dispatch charge.
   Infeasible tilings (non-divisor ``block_rows``, VMEM overflow) never
   run; the feasible set is ranked and cut to ``budget``.
3. **Benchmark** survivors through the existing kernel-timing hooks
   (:func:`repro.kernels.paged_attn.ops.attn_telemetry`): one untimed
   compile/warmup call, then ``reps`` eagerly-timed calls whose wall
   time, achieved GB/s and op/byte come straight off the telemetry
   snapshot.  Every candidate's output is **parity-gated** against the
   default shape's output: a candidate that is not bit-exact on this
   backend is discarded before winner selection (XLA may lower small
   row blocks with different accumulation order — ulp drift is real on
   CPU interpret), so persisted winners are bit-exact by construction.
4. **Persist** winners to a versioned JSON cache (default
   ``benchmarks/tuned_shapes.json``) keyed
   ``"<backend>|<op>|hq{H}.hkv{K}.d{D}.ps{P}"``.
   :class:`repro.kernels.decode_attn.ops.DecodeAttnPolicy` resolves the
   cache at construction time and the ops consult it per call shape;
   the ``REPRO_TUNED_SHAPES`` env var overrides the path or (set to
   ``0`` / ``off`` / ``ignore`` / ``none`` / empty) disables loading.

Cache schema (``SCHEMA == 1``)::

    {"schema": 1,
     "entries": {"cpu|decode|hq4.hkv1.d16.ps8": {
         "config": {"grid_order": "hb"},          # winner launch config
         "wall_s": ..., "default_wall_s": ...,    # provenance
         "achieved_gbps": ..., "op_byte": ...,
         "geometry": "hq4.hkv1.d16.ps8", "op": "decode"}}}

``scripts/autotune.py`` drives full sweeps; ``serve_bench.py
--autotune-compare`` runs the bounded CI tier and writes per-candidate
rows into ``BENCH_serve.json`` for ``check_bench.py`` to gate.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from ...core.hwspec import DEFAULT_TPU, TpuSpec
from .kernel import GRID_ORDERS

SCHEMA = 1
OPS = ("decode", "prefill", "verify")
ENV_VAR = "REPRO_TUNED_SHAPES"
_ENV_OFF = ("", "0", "off", "ignore", "none")
DEFAULT_CACHE = os.path.abspath(os.path.join(
    os.path.dirname(__file__), os.pardir, os.pardir, os.pardir, os.pardir,
    "benchmarks", "tuned_shapes.json"))
# analytic per-grid-step dispatch charge (ns).  A ranking device, not a
# measurement: it makes a tiling that quadruples the grid pay for it in
# the score, at roughly a compiled-mode launch cost.
DISPATCH_NS = 300.0


@dataclasses.dataclass(frozen=True)
class Geometry:
    """The model/pool shape a tuned entry is keyed by.  ``lq`` is *not*
    part of the key — ``block_rows`` is sanitized against the runtime
    ``Lq * G`` at lookup time instead, so one entry serves every chunk
    length whose row count it divides."""
    hq: int
    hkv: int
    d: int
    page_size: int

    @property
    def g(self) -> int:
        return self.hq // self.hkv

    def key(self) -> str:
        return (f"hq{self.hq}.hkv{self.hkv}.d{self.d}"
                f".ps{self.page_size}")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One launch configuration.  ``block_rows=None`` means the default
    single-block row fold (and is the only valid value for decode)."""
    grid_order: str = "bh"
    block_rows: int | None = None

    def as_dict(self) -> dict:
        cfg = {"grid_order": self.grid_order}
        if self.block_rows is not None:
            cfg["block_rows"] = self.block_rows
        return cfg

    def label(self) -> str:
        br = "full" if self.block_rows is None else str(self.block_rows)
        return f"{self.grid_order}/br={br}"


def entry_key(backend: str, op: str, geom: Geometry) -> str:
    return f"{backend}|{op}|{geom.key()}"


@dataclasses.dataclass
class Workload:
    """Concrete arrays for one (op, geometry) benchmark point."""
    op: str
    geom: Geometry
    q: jnp.ndarray
    k_pages: jnp.ndarray
    v_pages: jnp.ndarray
    table: jnp.ndarray
    lengths: jnp.ndarray
    q_offset: jnp.ndarray | None      # None for decode
    lq: int                           # 1 for decode

    @property
    def lg(self) -> int:
        """Fused sublane row count the kernel sees."""
        return self.geom.g if self.op == "decode" else self.lq * self.geom.g


def make_workload(op: str, geom: Geometry, *, b: int = 2, lq: int = 8,
                  pages: int = 16, seed: int = 0) -> Workload:
    """Random pooled-page workload in the shape the serving engine hands
    the kernels (mirrors ``serve_bench.roofline_probe``): a permuted page
    table, per-slot offsets at least one page deep, live lengths inside
    the sliced table."""
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}, got {op!r}")
    if pages % b:
        raise ValueError(f"pages={pages} must be divisible by b={b}")
    ps, hkv, hq, d = geom.page_size, geom.hkv, geom.hq, geom.d
    p_max = pages // b
    if op != "decode" and (p_max - 1) * ps - lq <= ps:
        raise ValueError(f"workload too small: need (pages/b - 1) * "
                         f"page_size > page_size + lq "
                         f"(pages={pages}, b={b}, ps={ps}, lq={lq})")
    rng = np.random.default_rng(seed)
    kp = jnp.asarray(rng.standard_normal((pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pages, ps, hkv, d)), jnp.float32)
    tbl = jnp.asarray(rng.permutation(pages)[:b * p_max]
                      .reshape(b, p_max).astype(np.int32))
    if op == "decode":
        q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
        ln = jnp.asarray(rng.integers(ps, p_max * ps, size=b)
                         .astype(np.int32))
        return Workload(op, geom, q, kp, vp, tbl, ln, None, 1)
    q = jnp.asarray(rng.standard_normal((b, lq, hq, d)), jnp.float32)
    off = jnp.asarray(rng.integers(ps, (p_max - 1) * ps - lq, size=b)
                      .astype(np.int32))
    return Workload(op, geom, q, kp, vp, tbl, off + lq, off, lq)


def enumerate_candidates(op: str, lg: int | None = None) -> list[Candidate]:
    """Every launch config the kernels accept for ``op``: both grid
    orders, and (prefill/verify) every divisor of the fused row count as
    ``block_rows``.  The default ``Candidate()`` is always first — the
    pruner keeps it and the benchmark parity-gates against it."""
    if op == "decode" or lg is None:
        return [Candidate(o) for o in GRID_ORDERS]
    divisors = [r for r in range(1, lg) if lg % r == 0]
    out = []
    for br in [None] + divisors:
        for order in GRID_ORDERS:
            out.append(Candidate(order, br))
    return out


def vmem_working_set(geom: Geometry, *, rows: int) -> int:
    """fp32 bytes the kernel stages per grid step: q block + o block +
    k/v page blocks + the (m, l, acc) flash scratch."""
    d, ps = geom.d, geom.page_size
    return 4 * (2 * rows * d + 2 * ps * d + rows * (d + 2))


def feasible(cand: Candidate, *, op: str, lg: int, geom: Geometry,
             spec: TpuSpec = DEFAULT_TPU) -> tuple[bool, str]:
    """Static feasibility — infeasible tilings never run.  Rejects
    unknown grid orders, row tiling on decode (no row axis), non-divisor
    ``block_rows``, and tilings whose per-step working set overflows
    VMEM."""
    if cand.grid_order not in GRID_ORDERS:
        return False, f"unknown grid_order {cand.grid_order!r}"
    rows = lg
    if cand.block_rows is not None:
        if op == "decode":
            return False, "decode has no query-row axis to tile"
        if cand.block_rows <= 0 or lg % cand.block_rows:
            return False, (f"block_rows={cand.block_rows} does not divide "
                           f"the fused row count Lq*G={lg}")
        rows = cand.block_rows
    ws = vmem_working_set(geom, rows=rows)
    if ws > spec.vmem_bytes:
        return False, (f"VMEM working set {ws} B exceeds "
                       f"{spec.vmem_bytes} B")
    return True, "ok"


def _page_fetches(wl: Workload, block_rows: int | None) -> int:
    """Physical K/V page fetches across the whole grid for a candidate
    row tiling: each row block re-walks the page list, but only up to
    its own causal top (the dead-page skip redirects the rest)."""
    p_max = int(wl.table.shape[1])
    ps = wl.geom.page_size
    ln = np.asarray(wl.lengths, np.int64)
    if wl.op == "decode":
        end = np.clip(ln, 0, p_max * ps)
        return int(np.sum((end + ps - 1) // ps))
    off = np.asarray(wl.q_offset, np.int64)
    lg = wl.lg
    br = lg if block_rows is None else block_rows
    g = wl.geom.g
    total = 0
    for r in range(lg // br):
        top = off + (r * br + br - 1) // g        # deepest qpos in block
        end = np.clip(np.minimum(ln, top + 1), 0, p_max * ps)
        total += int(np.sum((end + ps - 1) // ps))
    return total


def candidate_traffic(wl: Workload, cand: Candidate) -> tuple:
    """Per-candidate ``(mem_bytes, flops, onchip_bytes)``: the base
    :func:`ops._traffic` estimate, with the K/V component re-derived
    from the candidate's actual page-fetch count (row blocks re-walk
    pages; the causal top-skip refunds pages above each block).  Bytes
    moved from HBM to the re-walk are debited from on-chip reuse."""
    from .ops import _traffic
    mem, flops, onchip = _traffic(wl.q, wl.k_pages, wl.table, wl.lengths,
                                  q_offset=wl.q_offset)
    extra = _page_fetches(wl, cand.block_rows) - _page_fetches(wl, None)
    if extra > 0:
        item = jnp.dtype(wl.k_pages.dtype).itemsize
        kv = extra * wl.geom.page_size * wl.geom.hkv * wl.geom.d * item * 2
        mem += kv
        onchip = max(0.0, onchip - kv)
    return mem, flops, onchip


def score(cand: Candidate, wl: Workload,
          spec: TpuSpec = DEFAULT_TPU) -> float:
    """Analytic time estimate (ns) for ranking: roofline max of memory
    and compute time — compute derated by sublane occupancy of the row
    block — plus a dispatch charge per grid step."""
    mem, flops, _onchip = candidate_traffic(wl, cand)
    rows = wl.lg if cand.block_rows is None else cand.block_rows
    sublane_eff = min(1.0, rows / spec.sublane_tile)
    mem_t = mem / spec.hbm_gbps
    comp_t = flops / (spec.peak_flops_per_ns * sublane_eff)
    b, p_max = int(wl.table.shape[0]), int(wl.table.shape[1])
    steps = b * wl.geom.hkv * p_max
    if wl.op != "decode":
        steps *= wl.lg // rows
    return max(mem_t, comp_t) + steps * DISPATCH_NS


def prune(wl: Workload, candidates: list[Candidate] | None = None, *,
          budget: int | None = None,
          spec: TpuSpec = DEFAULT_TPU) -> tuple[list, list]:
    """(survivors, pruned): feasible candidates ranked by analytic score
    and cut to ``budget``, with the default shape always surviving (it
    is the parity baseline and the ``default_wall_s`` reference) and
    always first.  ``pruned`` pairs each rejected candidate with its
    reason."""
    if candidates is None:
        candidates = enumerate_candidates(wl.op, wl.lg)
    kept, pruned = [], []
    for c in candidates:
        ok, why = feasible(c, op=wl.op, lg=wl.lg, geom=wl.geom, spec=spec)
        if ok:
            kept.append((score(c, wl, spec), c))
        else:
            pruned.append((c, why))
    kept.sort(key=lambda t: t[0])
    survivors = [c for _, c in kept]
    default = Candidate()
    if budget is not None and budget > 0 and len(survivors) > budget:
        cut = survivors[:budget]
        if default in survivors and default not in cut:
            cut[-1] = default
        pruned.extend((c, "over candidate budget (analytic rank)")
                      for c in survivors if c not in cut)
        survivors = cut
    if default in survivors:
        survivors.remove(default)
        survivors.insert(0, default)
    return survivors, pruned


def benchmark(wl: Workload, candidates: list[Candidate], *, reps: int = 3,
              interpret: bool | None = None) -> tuple[list, list]:
    """Measure ``candidates`` (default shape first) through the kernel
    route and the telemetry timing hooks.  Returns ``(rows, dropped)``:
    one result row per surviving candidate (config, per-call wall,
    achieved GB/s, op/byte) and the parity-gate casualties — candidates
    whose output is not bit-identical to the default shape's on this
    backend never reach winner selection."""
    from ..decode_attn import decode_attn_policy
    from . import ops as _ops
    if not candidates or candidates[0] != Candidate():
        raise ValueError("candidates[0] must be the default Candidate() — "
                         "it is the parity and default_wall_s baseline")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tel = _ops.attn_telemetry()

    def call(c: Candidate):
        if wl.op == "decode":
            return _ops.paged_attn(wl.q, wl.k_pages, wl.v_pages, wl.table,
                                   wl.lengths, interpret=interpret,
                                   grid_order=c.grid_order)
        fn = (_ops.paged_verify_attn if wl.op == "verify"
              else _ops.paged_prefill_attn)
        return fn(wl.q, wl.k_pages, wl.v_pages, wl.table, wl.q_offset,
                  wl.lengths, grid_order=c.grid_order,
                  block_rows=c.block_rows)

    rows, dropped = [], []
    ref = None
    # use_tuned=False: the sweep must measure exactly the candidate it
    # was handed, never a cached winner resolved under its None kwargs
    with decode_attn_policy(mode="kernel", interpret=interpret,
                            use_tuned=False):
        for c in candidates:
            out = np.asarray(call(c))          # compile + warmup, untimed
            if ref is None:
                ref = out
            elif not np.array_equal(out, ref):
                dropped.append({"config": c.as_dict(),
                                "reason": "output not bit-exact vs the "
                                          "default shape on this backend"})
                continue
            saved_enabled, saved_stats = tel.enabled, tel.stats
            tel.stats = {}
            tel.enabled = True
            try:
                for _ in range(reps):
                    call(c)
                snap = tel.snapshot().get(f"{wl.op}.kernel", {})
            finally:
                tel.enabled, tel.stats = saved_enabled, saved_stats
            rows.append({"config": c.as_dict(),
                         "wall_s": snap.get("wall_s", 0.0) / max(reps, 1),
                         "achieved_gbps": snap.get("achieved_gbps", 0.0),
                         "op_byte": snap.get("op_byte", 0.0)})
    return rows, dropped


def autotune(ops=OPS, *, geom: Geometry, b: int = 2, lq: int = 8,
             pages: int = 16, budget: int | None = None, reps: int = 3,
             interpret: bool | None = None, spec: TpuSpec = DEFAULT_TPU,
             seed: int = 0) -> dict:
    """Full sweep for one geometry: enumerate → prune → benchmark →
    pick the winner per op.  The winner is the wall-time argmin over the
    measured set, which always contains the default shape — so
    ``winner_wall_s <= default_wall_s`` holds by construction, and the
    parity gate guarantees the winner's output is bit-exact vs the
    default."""
    backend = jax.default_backend()
    results = {}
    for op in ops:
        wl = make_workload(op, geom, b=b, lq=lq, pages=pages, seed=seed)
        cands, pruned = prune(wl, budget=budget, spec=spec)
        rows, dropped = benchmark(wl, cands, reps=reps, interpret=interpret)
        winner = min(rows, key=lambda r: r["wall_s"])
        results[op] = {
            "key": entry_key(backend, op, geom),
            "backend": backend, "op": op, "geometry": geom.key(),
            "candidates": rows,
            "pruned": [{"config": c.as_dict(), "reason": why}
                       for c, why in pruned],
            "parity_dropped": dropped,
            "winner": winner["config"],
            "winner_wall_s": winner["wall_s"],
            "default_wall_s": rows[0]["wall_s"],
            "achieved_gbps": winner["achieved_gbps"],
            "op_byte": winner["op_byte"]}
    return results


# --------------------------------------------------------------------------
# tuned-shape cache: persistence + policy-side loading
# --------------------------------------------------------------------------

def resolve_cache_path(path: str | None = None) -> str | None:
    """The cache file to read: ``REPRO_TUNED_SHAPES`` overrides
    everything (a path, or one of ``0/off/ignore/none``/empty to disable
    loading → None); otherwise the explicit ``path``; otherwise the
    committed default."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        if env.strip().lower() in _ENV_OFF:
            return None
        return env
    return path or DEFAULT_CACHE


_load_memo: dict = {}


def load_entries(path: str | None = None) -> dict:
    """The cache's ``entries`` dict, or ``{}`` when loading is disabled,
    the file is missing/corrupt, or the schema is unknown — a broken
    cache must degrade to defaults, never break serving.  Memoized by
    (path, mtime, size) so per-policy-construction loads are one stat."""
    p = resolve_cache_path(path)
    if p is None:
        return {}
    try:
        st = os.stat(p)
    except OSError:
        return {}
    key = (p, st.st_mtime_ns, st.st_size)
    if key in _load_memo:
        return _load_memo[key]
    entries: dict = {}
    try:
        with open(p) as f:
            data = json.load(f)
        if isinstance(data, dict) and data.get("schema") == SCHEMA \
                and isinstance(data.get("entries"), dict):
            entries = data["entries"]
    except (OSError, ValueError):
        entries = {}
    _load_memo.clear()
    _load_memo[key] = entries
    return entries


def save_entries(results: dict, path: str | None = None) -> str:
    """Merge ``autotune()`` results into the cache at ``path`` (default:
    the committed ``benchmarks/tuned_shapes.json``), atomically.
    Existing entries for other (backend, op, geometry) keys are kept; an
    unknown on-disk schema is discarded rather than half-merged."""
    path = path or DEFAULT_CACHE
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    if not isinstance(data, dict) or data.get("schema") not in (None, SCHEMA):
        data = {}
    data["schema"] = SCHEMA
    entries = data.setdefault("entries", {})
    if not isinstance(entries, dict):
        entries = data["entries"] = {}
    for op, r in results.items():
        entries[r["key"]] = {
            "config": r["winner"], "op": op, "geometry": r["geometry"],
            "wall_s": round(r["winner_wall_s"], 6),
            "default_wall_s": round(r["default_wall_s"], 6),
            "achieved_gbps": round(r["achieved_gbps"], 4),
            "op_byte": round(r["op_byte"], 4)}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path
