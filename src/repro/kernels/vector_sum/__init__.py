from .ops import vector_sum  # noqa: F401
