"""vector-sum Pallas kernel: the co-aligned elementwise primitive (§4.2.2).

Block placement mirrors the paper's bank co-alignment: the same-index VMEM
tile of a, b and c interact, so one grid step touches exactly one tile of
each operand and the Pallas pipeline double-buffers the next tile's copy
while this tile computes (= architecture-aware activation hiding, §5.1.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = (8, 512)


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def vector_sum_2d(a: jnp.ndarray, b: jnp.ndarray, *,
                  interpret: bool = True) -> jnp.ndarray:
    rows, cols = a.shape
    br = min(BLOCK[0], rows)
    bc = min(BLOCK[1], cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel, grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(a, b)
