"""Public op: shape-polymorphic vector sum via the 2-D tiled kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import vector_sum_2d

LANES = 512


@functools.partial(jax.jit, static_argnames=("interpret",))
def vector_sum(a: jnp.ndarray, b: jnp.ndarray, *,
               interpret: bool = True) -> jnp.ndarray:
    if a.shape != b.shape:
        raise ValueError("shape mismatch")
    flat = a.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANES)
    pad = rows * LANES - n
    a2 = jnp.pad(flat, (0, pad)).reshape(rows, LANES)
    b2 = jnp.pad(b.reshape(-1), (0, pad)).reshape(rows, LANES)
    out = vector_sum_2d(a2, b2, interpret=interpret)
    return out.reshape(-1)[:n].reshape(a.shape)
