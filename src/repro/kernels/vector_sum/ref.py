"""Oracle for vector-sum."""
import jax.numpy as jnp


def vector_sum_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b
