"""Cache-aware hot/cold partitioned scatter-add (the §5.1.3 idea on TPU).

The paper's locality predictor routes reuse-heavy updates to the cache and
the rest to PIM.  TPU analogue: a frequency-ranked *hot set* of destination
rows lives in a dense VMEM accumulator ("the cache"); updates whose
destination falls in the hot set are accumulated in-kernel via a one-hot
matmul (scatter-as-GEMM — MXU-native, no serialization); cold updates are
emitted untouched for the XLA gather/scatter path ("PIM side", handled by
the wrapper with segment_sum).

Grid: one step per update tile; the VMEM accumulator is a scratch carried
across steps and written once at the end (pim-register accumulation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BU = 512     # updates per tile
HOT = 1024   # hot-set rows resident in VMEM


def _kernel(dst_ref, val_ref, hot_acc_ref, cold_val_ref, acc_ref):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dst = dst_ref[...]                     # [1, BU] int32 (hot id or -1)
    val = val_ref[...]                     # [1, BU]
    hot = dst >= 0
    # one-hot GEMM scatter into the resident hot accumulator
    onehot = (dst[0][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (dst.shape[1], acc_ref.shape[1]), 1))
    contrib = jax.lax.dot_general(
        jnp.where(hot, val, 0.0)[0][None, :].astype(jnp.float32),
        onehot.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] += contrib
    cold_val_ref[...] = jnp.where(hot, 0.0, val)

    @pl.when(j == nb - 1)
    def _():
        hot_acc_ref[...] = acc_ref[...]


def push_scatter_kernel(dst_hot: jnp.ndarray, val: jnp.ndarray, *,
                        hot: int = HOT, bu: int = BU,
                        interpret: bool = True):
    """dst_hot: [U] int32 — hot-set slot id, or -1 for cold updates.
    val: [U] f32.  Returns (hot_acc [hot], cold_vals [U])."""
    u = val.shape[0]
    bu = min(bu, u)
    grid = (pl.cdiv(u, bu),)
    return pl.pallas_call(
        _kernel, grid=grid,
        in_specs=[pl.BlockSpec((1, bu), lambda j: (0, j)),
                  pl.BlockSpec((1, bu), lambda j: (0, j))],
        out_specs=(pl.BlockSpec((1, hot), lambda j: (0, 0)),
                   pl.BlockSpec((1, bu), lambda j: (0, j))),
        out_shape=(jax.ShapeDtypeStruct((1, hot), jnp.float32),
                   jax.ShapeDtypeStruct((1, u), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((1, hot), jnp.float32)],
        interpret=interpret)(dst_hot.reshape(1, u), val.reshape(1, u))
