"""Public push op: predictor (degree ranking) + hot/cold execution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BU, HOT, push_scatter_kernel


def hot_set(dst: jnp.ndarray, n_nodes: int, hot: int = HOT) -> jnp.ndarray:
    """Locality predictor: the ``hot`` most-updated destinations.

    Returns [n_nodes] int32: slot id in the hot accumulator, or -1.
    (Degree ranking is the static locality predictor of §5.1.3 — reuse is
    literally update frequency for scatter-adds.)
    """
    counts = jnp.bincount(dst, length=n_nodes)
    _, top = jax.lax.top_k(counts, min(hot, n_nodes))
    slot = jnp.full((n_nodes,), -1, jnp.int32)
    return slot.at[top].set(jnp.arange(top.shape[0], dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("hot", "interpret"))
def push_scatter(values: jnp.ndarray, contrib: jnp.ndarray,
                 dst: jnp.ndarray, *, hot: int = HOT,
                 interpret: bool = True) -> jnp.ndarray:
    """values [N] += scatter(contrib [U] at dst [U]), hot/cold partitioned."""
    n = values.shape[0]
    u = contrib.shape[0]
    hot = min(hot, n)
    slot_of = hot_set(dst, n, hot)
    slots = slot_of[dst]                             # [U]: hot slot or -1
    pad = (-u) % min(BU, u)
    if pad:
        slots = jnp.concatenate([slots, jnp.full((pad,), -1, jnp.int32)])
        contrib_p = jnp.concatenate([contrib,
                                     jnp.zeros((pad,), contrib.dtype)])
        dst_p = jnp.concatenate([dst, jnp.zeros((pad,), dst.dtype)])
    else:
        contrib_p, dst_p = contrib, dst
    hot_acc, cold_vals = push_scatter_kernel(
        slots, contrib_p.astype(jnp.float32), hot=hot,
        interpret=interpret)
    # cache side: hot accumulator flushed back to its rows
    top = jnp.nonzero(slot_of >= 0, size=hot, fill_value=0)[0]
    order = slot_of[top]
    out = values.astype(jnp.float32)
    out = out.at[top].add(hot_acc[0][order])
    # PIM side: cold updates through the gather/scatter path
    out = out + jax.ops.segment_sum(cold_vals[0], dst_p, num_segments=n)
    return out.astype(values.dtype)
