"""Oracle: scatter-add of edge contributions into node values."""
import jax
import jax.numpy as jnp


def push_scatter_ref(values: jnp.ndarray, contrib: jnp.ndarray,
                     dst: jnp.ndarray) -> jnp.ndarray:
    """values: [N], contrib: [U], dst: [U] -> values + segment_sum."""
    return values + jax.ops.segment_sum(contrib, dst,
                                        num_segments=values.shape[0])
