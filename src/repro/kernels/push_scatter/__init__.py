from .ops import push_scatter  # noqa: F401
