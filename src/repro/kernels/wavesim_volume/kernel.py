"""wavesim-volume Pallas kernel.

TPU adaptation (DESIGN.md §2): the three directional derivative applies
(D (x) I (x) I + I (x) D (x) I + I (x) I (x) D) fold into ONE dense
[27, 27] reference operator W applied per (element, field) nodal vector —
so the volume term becomes a single [rows, 27] @ [27, 27] matmul per tile,
i.e. pure MXU work instead of three strided small contractions (a GPU-style
loop nest that would waste the systolic array).  Node dim is padded to 32
(and would be padded to 128 lanes on real hardware; the pad content is
zero so results are exact).

Tiles of 256 (element x field) rows stage through VMEM; W stays resident
(index_map pins block (0,0)) — the "operator broadcast as immediate"
placement from §4.2.3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.primitives.wavesim import NODES

ROWS = 256
NPAD = 32


def fused_operator(c: float = 1.0, dtype=jnp.float32) -> jnp.ndarray:
    """W[27, 27]: sum of the three directional Kronecker operators."""
    d = np.array([[-1.5, 2.0, -0.5],       # = reference_operator, pure numpy
                  [-0.5, 0.0, 0.5],        # (jit-safe constant folding)
                  [0.5, -2.0, 1.5]], dtype=np.float64)
    eye = np.eye(3)
    w = (np.kron(np.kron(d, eye), eye)
         + np.kron(np.kron(eye, d), eye)
         + np.kron(np.kron(eye, eye), d))
    w = c * w
    wp = np.zeros((NPAD, NPAD))
    # kernel computes row-vector @ W, i.e. (W^T u)^T — store transposed
    wp[:NODES, :NODES] = w.T
    return jnp.asarray(wp, dtype)


def _kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def volume_kernel(x: jnp.ndarray, w: jnp.ndarray, *,
                  rows: int = ROWS, interpret: bool = True) -> jnp.ndarray:
    """x: [R, NPAD] (element*field rows, padded nodes) @ w [NPAD, NPAD]."""
    r = x.shape[0]
    rows = min(rows, r)
    grid = (pl.cdiv(r, rows),)
    return pl.pallas_call(
        _kernel, grid=grid,
        in_specs=[pl.BlockSpec((rows, NPAD), lambda i: (i, 0)),
                  pl.BlockSpec((NPAD, NPAD), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rows, NPAD), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret)(x, w)
