from .ops import volume  # noqa: F401
