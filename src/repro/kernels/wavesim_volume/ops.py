"""Public volume op: [E, F, 3, 3, 3] nodal fields -> volume RHS."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.primitives.wavesim import NODES
from .kernel import NPAD, fused_operator, volume_kernel


@functools.partial(jax.jit, static_argnames=("c", "interpret"))
def volume(u: jnp.ndarray, c: float = 1.0, *,
           interpret: bool = True) -> jnp.ndarray:
    e, f = u.shape[:2]
    # kron fusion uses index order (i-major): flatten [3,3,3] C-order gives
    # node index i*9 + j*3 + k which matches kron(D_i, D_j, D_k) layout.
    x = u.reshape(e * f, NODES)
    x = jnp.pad(x, ((0, 0), (0, NPAD - NODES)))
    w = fused_operator(c, u.dtype)
    y = volume_kernel(x, w, interpret=interpret)
    return y[:, :NODES].reshape(u.shape)
