"""Oracle: the functional DGM volume term from the core primitive."""
import jax.numpy as jnp

from repro.core.primitives.wavesim import volume as _volume


def volume_ref(u: jnp.ndarray, c: float = 1.0) -> jnp.ndarray:
    """u: [elements, fields, 3, 3, 3] -> rhs, same shape."""
    return _volume(u, c)
