"""Oracle: one-token GQA attention over a KV cache (per-slot lengths)."""
import jax
import jax.numpy as jnp


def decode_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    length: int | jnp.ndarray) -> jnp.ndarray:
    """q: [B, Hq, D]; k/v: [B, S, Hkv, D]; slot b attends over
    k[b, :length[b]] (scalar lengths broadcast)."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d * 1.0)
    ln = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (b,))
    mask = jnp.arange(s)[None, None, None, :] < ln[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, hq, d)
