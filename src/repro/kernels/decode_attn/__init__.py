from .ops import (DecodeAttnPolicy, active_policy,  # noqa: F401
                  decode_attn, decode_attn_policy)
