from .ops import decode_attn  # noqa: F401
