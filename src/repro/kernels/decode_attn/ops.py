"""Public decode-attention op + the runtime routing policy.

``decode_attn`` accepts either one shared length or per-slot lengths
([B] int32) so a continuous-batching scheduler can keep mixed-depth
requests in one launch.  ``s_cap`` statically prunes the KV-block grid to
``cdiv(s_cap, bs)`` — the serving engine passes a host-known bound on the
deepest live slot between scan segments, so blocks past *every* slot's
length are never launched (§5.1.2 command skipping at grid granularity);
per-slot skipping inside the kernel handles the rest.

``DecodeAttnPolicy`` is how the model's attention layer decides, at trace
time, whether decode attention routes through this kernel and whether the
kernel runs interpreted.  ``interpret=None`` resolves by backend: off on
real TPU backends, on everywhere else (this is a Mosaic/TPU kernel — only
TPU can compile it).  ``mode="auto"`` routes through the kernel on TPU and
keeps the plain-XLA path elsewhere, where the interpreter's per-program
overhead would dominate the serving hot loop.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernel import BS, decode_attn_kernel


@functools.partial(jax.jit, static_argnames=("bs", "interpret", "s_cap"))
def decode_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                length: jnp.ndarray | int, *, bs: int = BS,
                interpret: bool = True,
                s_cap: int | None = None) -> jnp.ndarray:
    """q: [B, Hq, D] one-token queries; k/v: [B, S, Hkv, D] cache;
    slot b attends over the first ``length[b]`` cache rows (a scalar
    length is broadcast to every slot)."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    if s_cap is not None and s_cap < k.shape[1]:
        k, v = k[:, :s_cap], v[:, :s_cap]
    ln = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (b,))
    out = decode_attn_kernel(qg, k, v, ln, bs=bs, interpret=interpret)
    return out.reshape(b, hq, d)


# --------------------------------------------------------------------------
# routing policy (read by repro.models.attention at trace time)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeAttnPolicy:
    mode: str = "auto"              # "kernel" | "xla" | "auto"
    interpret: bool | None = None   # None -> auto (CPU interprets)
    block_size: int = BS
    kv_cap: int | None = None       # static bound on live KV depth
    use_tuned: bool = True          # consult the autotuned-shape cache
    tuned_path: str | None = None   # None -> committed default (env wins)

    def __post_init__(self):
        # resolve the tuned-shape table once, at policy construction —
        # ops then do a dict lookup per call shape, never file I/O.
        # A missing/corrupt cache (or REPRO_TUNED_SHAPES=off) degrades
        # to the hand-picked defaults; it must never break routing.
        entries: dict = {}
        if self.use_tuned:
            try:
                from ..paged_attn.autotune import load_entries
                entries = load_entries(self.tuned_path)
            except Exception:
                entries = {}
        object.__setattr__(self, "_tuned", entries)

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def tuned_config(self, op: str, *, hq: int, hkv: int, d: int,
                     page_size: int, lg: int | None = None) -> dict | None:
        """The tuned launch config for ``(backend, op, geometry)``, or
        None on a cache miss.  ``block_rows`` is sanitized against the
        caller's fused row count ``lg`` (entries are keyed without Lq,
        so a tuned row tiling is dropped when it does not divide this
        call's rows); a malformed entry degrades field-by-field."""
        ent = self._tuned.get(f"{jax.default_backend()}|{op}|"
                              f"hq{hq}.hkv{hkv}.d{d}.ps{page_size}")
        if not isinstance(ent, dict):
            return None
        cfg = dict(ent.get("config") or {})
        if cfg.get("grid_order") not in ("bh", "hb"):
            cfg.pop("grid_order", None)
        br = cfg.get("block_rows")
        if br is not None and (not isinstance(br, int) or br <= 0
                               or lg is None or lg % br):
            cfg.pop("block_rows", None)
        return cfg or None

    def kernel_wanted(self) -> bool:
        if self.mode == "kernel":
            return True
        if self.mode == "xla":
            return False
        # auto: only TPU compiles this Mosaic kernel; everywhere else the
        # interpreter would sit in the hot loop, so stay on the XLA path
        return jax.default_backend() == "tpu"


_ACTIVE = DecodeAttnPolicy()


def active_policy() -> DecodeAttnPolicy:
    return _ACTIVE


@contextlib.contextmanager
def decode_attn_policy(**kw):
    """Override the decode-attention routing policy for code traced inside
    this context (jit caches must key on anything that varies, e.g. the
    engine re-jits per kv_cap bucket)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = dataclasses.replace(prev, **{k: v for k, v in kw.items()
                                           if v is not None or k in
                                           ("interpret", "kv_cap")})
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev
