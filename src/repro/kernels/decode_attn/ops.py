"""Public decode-attention op."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BS, decode_attn_kernel


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                length: jnp.ndarray | int, *, bs: int = BS,
                interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, D] one-token queries; k/v: [B, S, Hkv, D] cache;
    attends over the first ``length`` cache rows."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    ln = jnp.asarray(length, jnp.int32).reshape(1)
    out = decode_attn_kernel(qg, k, v, ln, bs=bs, interpret=interpret)
    return out.reshape(b, hq, d)
