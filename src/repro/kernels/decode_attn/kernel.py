"""GQA decode attention (flash-decode, split-KV) — the paper's regime.

One new token against a deep KV cache is the memory-bound skinny op that
the PIM-amenability test flags (op/byte ~ 1): the cache streams HBM->VMEM
once, the queries stay resident.  The kernel mirrors the pim-register
staging pattern: the grid walks KV blocks, an online-softmax accumulator
(m, l, acc) lives in VMEM scratch across the walk (registers staging an
open row), and the output is written once at the end.  The (B, Hkv) grid
dims are embarrassingly parallel (bank-level parallelism); the KV-block dim
streams (column walk within an open row).

Lengths are *per slot* ([B] int32, scalar-prefetched): each batch row may
sit at a different depth into the cache (continuous batching), and every
KV block past that slot's live length is skipped before any compute — the
paper's §5.1.2 command skipping applied at kernel-block granularity.  The
caller can additionally prune the grid itself by slicing the cache to a
host-known bound on the deepest live slot (see ops.decode_attn's s_cap).

Block shapes keep D on the 128-lane axis and the KV block on the sublane
axis (multiples of 8/16), so HBM reads are sequential full tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BS = 512    # KV rows per block


def _make_kernel(bs: int, scale: float):
    def kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        bi = pl.program_id(0)
        s = pl.program_id(2)
        ns = pl.num_programs(2)
        ln = len_ref[bi]

        @pl.when(s == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, -1e30)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        base = s * bs
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)

        # §5.1.2 command skipping: blocks past *this slot's* length do no
        # compute at all — the accumulator simply carries through.
        @pl.when(base < ln)
        def _():
            q = q_ref[0, 0]                  # [G, D]
            k = k_ref[0, :, 0, :]            # [BS, D]
            v = v_ref[0, :, 0, :]
            scores = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # [G, BS]
            live = kpos < ln                 # [1, BS]
            scores = jnp.where(live, scores, -1e30)
            m_prev = m_ref[...]              # [G, 1]
            m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
            p = jnp.exp(scores - m_new)      # [G, BS]
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                p.astype(jnp.float32), v.astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(s == ns - 1)
        def _():
            o_ref[0, 0] = (acc_ref[...]
                           / jnp.maximum(l_ref[...], 1e-30)
                           ).astype(o_ref.dtype)
    return kernel


def decode_attn_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       lengths: jnp.ndarray, *, bs: int = BS,
                       interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hkv, G, D]; k/v: [B, S, Hkv, D]; lengths: [B] int32 per-slot
    live lengths."""
    b, hkv, g, d = q.shape
    s = k.shape[1]
    bs = min(bs, s)
    grid = (b, hkv, pl.cdiv(s, bs))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, si, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, h, si, ln: (bi, si, h, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, h, si, ln: (bi, si, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, h, si, ln: (bi, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
    )
    return pl.pallas_call(
        _make_kernel(bs, 1.0 / math.sqrt(d)), grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret)(lengths, q, k, v)
