"""Public sparse-skinny-GEMM ops: host-side operand inspection (the
paper's "check before issuing") + kernel dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BK, BM, ssgemm_compact_kernel, ssgemm_masked_kernel


def block_occupancy(b: jnp.ndarray, bk: int) -> jnp.ndarray:
    """[K/bk] int32 mask: 1 where the B k-block has any nonzero."""
    k, n = b.shape
    nk = -(-k // bk)
    pad = nk * bk - k
    bb = jnp.pad(b, ((0, pad), (0, 0))).reshape(nk, bk * n)
    return jnp.any(bb != 0, axis=1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "interpret"))
def ssgemm_masked(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = BM,
                  bk: int = BK, interpret: bool = True) -> jnp.ndarray:
    mask = block_occupancy(b, min(bk, a.shape[1]))
    return ssgemm_masked_kernel(a, b, mask, bm=bm, bk=bk,
                                interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "budget", "interpret"))
def ssgemm_compact(a: jnp.ndarray, b: jnp.ndarray, *, budget: int,
                   bm: int = BM, bk: int = BK,
                   interpret: bool = True) -> jnp.ndarray:
    """Compacted-index variant: only (up to ``budget``) occupied k-blocks
    are ever fetched.  Overflowing blocks beyond the budget are handled by
    a dense jnp fallback contribution so the op stays exact."""
    bk = min(bk, a.shape[1])
    occ = block_occupancy(b, bk)
    nk = occ.shape[0]
    order = jnp.argsort(-occ)            # live blocks first, stable-ish
    live = jnp.take(jnp.arange(nk), order)
    n_live = jnp.sum(occ)
    capped = jnp.minimum(n_live, budget)
    idx = jnp.where(jnp.arange(budget) < capped,
                    live[:budget],
                    live[jnp.maximum(capped - 1, 0)]).astype(jnp.int32)
    out = ssgemm_compact_kernel(a, b, idx, capped[None].astype(jnp.int32),
                                budget=budget, bm=bm, bk=bk,
                                interpret=interpret)
    # exactness guard: contributions of blocks beyond the budget
    over = jnp.where(jnp.arange(nk) >= budget, occ[order], 0)
    has_over = jnp.any(over > 0)

    def overflow_part():
        sel = jnp.zeros((nk,), bool).at[order].set(
            jnp.arange(nk) >= budget)
        sel = sel & (occ > 0)
        k = a.shape[1]
        keep = jnp.repeat(sel, bk)[:k]
        bz = jnp.where(keep[:, None], b, 0)
        return jnp.dot(a.astype(jnp.float32), bz.astype(jnp.float32))

    return out + jax.lax.cond(has_over, overflow_part,
                              lambda: jnp.zeros_like(out))


def ssgemm(a: jnp.ndarray, b: jnp.ndarray, *, sparsity_aware: bool = True,
           interpret: bool = True) -> jnp.ndarray:
    """Default entry point: masked skip when sparsity-aware, else dense."""
    if sparsity_aware:
        return ssgemm_masked(a, b, interpret=interpret)
    ones = jnp.ones((-(-a.shape[1] // min(BK, a.shape[1])),), jnp.int32)
    from .kernel import ssgemm_masked_kernel as k
    return k(a, b, ones, interpret=interpret)
