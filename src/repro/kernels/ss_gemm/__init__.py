from .ops import ssgemm, ssgemm_compact, ssgemm_masked  # noqa: F401
