"""Sparsity-aware blocked skinny GEMM — the §5.1.2 idea on TPU.

The paper's sparsity-aware PIM inspects each skinny-matrix operand on the
host and *skips issuing* the pim-command when it is zero.  The TPU analogue
operates at (bm x bk) tile granularity with a host-computed block-occupancy
mask delivered through scalar prefetch:

* ``masked`` variant: static (M/bm, K/bk) grid; ``@pl.when(mask[k])`` skips
  the MXU op for all-zero B tiles (saves compute slots, like skipping the
  ALU command).
* ``compact`` variant: the host compacts the nonzero k-block indices; the
  grid runs over a fixed block *budget* and the A/B index_maps chase the
  prefetched index list.  Padded trailing steps repeat the last real block
  index, so Pallas's revisit elision skips their copies — zero blocks are
  never fetched at all (the command is never issued).

A-tile layout follows the paper's Fig. 5 blocked format: contiguous-M SIMD
words, K along the fast axis, accumulation in VMEM scratch (pim-registers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BK = 256, 256


def _masked_kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[k] != 0)
    def _():
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...]


def ssgemm_masked_kernel(a: jnp.ndarray, b: jnp.ndarray,
                         block_mask: jnp.ndarray, *,
                         bm: int = BM, bk: int = BK,
                         interpret: bool = True) -> jnp.ndarray:
    m, k = a.shape
    _, n = b.shape
    bm, bk = min(bm, m), min(bk, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(k, bk))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, mask: (i, j)),
            pl.BlockSpec((bk, n), lambda i, j, mask: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i, j, mask: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, n), jnp.float32)],
    )
    return pl.pallas_call(
        _masked_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret)(block_mask, a, b)


def _compact_kernel(idx_ref, nlive_ref, a_ref, b_ref, o_ref, acc_ref):
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nlive_ref[0])
    def _():
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _():
        o_ref[...] = acc_ref[...]


def ssgemm_compact_kernel(a: jnp.ndarray, b: jnp.ndarray,
                          block_idx: jnp.ndarray, n_live: jnp.ndarray, *,
                          budget: int, bm: int = BM, bk: int = BK,
                          interpret: bool = True) -> jnp.ndarray:
    """block_idx: [budget] nonzero k-block ids (trailing entries repeat the
    last live id); n_live: [1] live count."""
    m, k = a.shape
    _, n = b.shape
    bm, bk = min(bm, m), min(bk, k)
    grid = (pl.cdiv(m, bm), budget)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, idx, nl: (i, idx[j])),
            pl.BlockSpec((bk, n), lambda i, j, idx, nl: (idx[j], 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i, j, idx, nl: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, n), jnp.float32)],
    )
    return pl.pallas_call(
        _compact_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret)(block_idx, n_live, a, b)
