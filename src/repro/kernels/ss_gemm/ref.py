"""Oracle for the sparse skinny GEMM."""
import jax.numpy as jnp


def ssgemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: [M, K] dense, b: [K, N] skinny (sparse) -> [M, N] in f32."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
