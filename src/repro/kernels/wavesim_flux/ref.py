"""Oracle: 1-D face-flux exchange along a linearized element axis."""
import jax.numpy as jnp


def flux1d_ref(hi: jnp.ndarray, lo: jnp.ndarray,
               alpha: float = 0.5) -> tuple[jnp.ndarray, jnp.ndarray]:
    """hi/lo: [E, T] element high/low face traces (periodic neighbors).

    Returns (flux_hi, flux_lo): alpha * (neighbor_trace - own_trace).
    """
    nb_hi = jnp.roll(lo, -1, axis=0)   # next element's low face
    nb_lo = jnp.roll(hi, 1, axis=0)    # previous element's high face
    return alpha * (nb_hi - hi), alpha * (nb_lo - lo)
