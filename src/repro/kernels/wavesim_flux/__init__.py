from .ops import flux1d  # noqa: F401
