"""wavesim-flux Pallas kernel: halo-exchange stencil on the element axis.

The paper places neighboring mesh elements in the same bank so face
interactions never cross banks (§4.2.3, Fig. 4b).  The VMEM analogue: each
grid step owns an element tile and *shifted views* of the same arrays act
as the neighbor halos — three in_specs over one input, index-mapped to
(i-1, i, i+1), so the neighbor traces are co-resident in VMEM with the own
tile (operand locality) and the copies pipeline (activation hiding).
Periodic wrap is applied by the wrapper via index arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BE = 256    # elements per tile


def _kernel(hi_ref, lo_ref, lo_next_ref, hi_prev_ref, fhi_ref, flo_ref, *,
            alpha: float):
    fhi_ref[...] = alpha * (lo_next_ref[...] - hi_ref[...])
    flo_ref[...] = alpha * (hi_prev_ref[...] - lo_ref[...])


def flux1d_kernel(hi: jnp.ndarray, lo: jnp.ndarray, *, alpha: float = 0.5,
                  be: int = BE, interpret: bool = True):
    """hi/lo: [E, T]; E must be a multiple of the tile size (wrapper pads).

    Neighbor halos are realized as whole shifted arrays (built by the
    wrapper with jnp.roll — a relabeling, not data movement on TPU when
    fused) so every block read stays a plain Blocked index_map.
    """
    e, t = hi.shape
    be = min(be, e)
    grid = (pl.cdiv(e, be),)
    spec = pl.BlockSpec((be, t), lambda i: (i, 0))
    lo_next = jnp.roll(lo, -1, axis=0)
    hi_prev = jnp.roll(hi, 1, axis=0)
    import functools
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct(hi.shape, hi.dtype),
                   jax.ShapeDtypeStruct(lo.shape, lo.dtype)),
        interpret=interpret)(hi, lo, lo_next, hi_prev)
