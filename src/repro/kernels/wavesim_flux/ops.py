"""Public flux op with padding to tile multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BE, flux1d_kernel


@functools.partial(jax.jit, static_argnames=("alpha", "interpret"))
def flux1d(hi: jnp.ndarray, lo: jnp.ndarray, alpha: float = 0.5, *,
           interpret: bool = True):
    e, t = hi.shape
    be = min(BE, e)
    pad = (-e) % be
    if pad:
        # periodic problem: pad with the wrapped-around elements so halos
        # at the seam stay exact, then crop.
        hi_p = jnp.concatenate([hi, hi[:pad]], axis=0)
        lo_p = jnp.concatenate([lo, lo[:pad]], axis=0)
        fhi, flo = flux1d_kernel(hi_p, lo_p, alpha=alpha, be=be,
                                 interpret=interpret)
        # seam fix: rebuild true periodic neighbors for the crop edges
        fhi = fhi[:e].at[e - 1].set(alpha * (lo[0] - hi[e - 1]))
        flo = flo[:e].at[0].set(alpha * (hi[e - 1] - lo[0]))
        return fhi, flo
    return flux1d_kernel(hi, lo, alpha=alpha, be=be, interpret=interpret)
