from .ops import group_gemm  # noqa: F401
