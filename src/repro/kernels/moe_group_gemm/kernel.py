"""Sparsity-aware grouped expert GEMM — the paper's §5.1.2 inside the LM.

MoE dispatch produces capacity-padded per-expert token slabs whose
occupancy is dynamic (most experts see few tokens at small batch — the
ss-gemm regime).  Per-expert token counts are scalar-prefetched and every
(expert, token-tile) grid step whose tile lies entirely beyond the
occupancy is *skipped* (`@pl.when`): no MXU work and, because the expert
weight block's index_map repeats between consecutive capacity steps, the
skipped steps' weight copies are elided too.  That is command skipping at
tile granularity: dynamic sparsity exploited with no sparse format and no
metadata beyond the count vector the router already has.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BC = 128   # capacity rows per tile


def _kernel(counts_ref, x_ref, w_ref, o_ref):
    e = pl.program_id(0)
    c = pl.program_id(1)
    bc = x_ref.shape[1]

    @pl.when(c * bc < counts_ref[e])
    def _():
        o_ref[0] = jax.lax.dot_general(
            x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(c * bc >= counts_ref[e])
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)


def group_gemm_kernel(xe: jnp.ndarray, w: jnp.ndarray,
                      counts: jnp.ndarray, *, bc: int = BC,
                      interpret: bool = True) -> jnp.ndarray:
    """xe: [E, C, D], w: [E, D, F], counts: [E] -> [E, C, F]."""
    e, c, d = xe.shape
    f = w.shape[2]
    bc = min(bc, c)
    grid = (e, pl.cdiv(c, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda ei, ci, cnt: (ei, ci, 0)),
            pl.BlockSpec((1, d, f), lambda ei, ci, cnt: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, f), lambda ei, ci, cnt: (ei, ci, 0)),
    )
    return pl.pallas_call(
        _kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, c, f), jnp.float32),
        interpret=interpret)(counts.astype(jnp.int32), xe, w)
