"""Public grouped-GEMM op (zeroes padded rows, like the oracle)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BC, group_gemm_kernel


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def group_gemm(xe: jnp.ndarray, w: jnp.ndarray, counts: jnp.ndarray, *,
               bc: int = BC, interpret: bool = True) -> jnp.ndarray:
    e, c, d = xe.shape
    bc_eff = min(bc, c)
    pad = (-c) % bc_eff
    if pad:
        xe = jnp.pad(xe, ((0, 0), (0, pad), (0, 0)))
    y = group_gemm_kernel(xe, w, counts, bc=bc_eff, interpret=interpret)
    y = y[:, :c]
    live = jnp.arange(c)[None, :, None] < counts[:, None, None]
    return jnp.where(live, y, 0.0)
