"""Oracle: capacity-padded grouped expert GEMM."""
import jax.numpy as jnp


def group_gemm_ref(xe: jnp.ndarray, w: jnp.ndarray,
                   counts: jnp.ndarray) -> jnp.ndarray:
    """xe: [E, C, D] expert token slabs (rows >= counts[e] are padding),
    w: [E, D, F] -> [E, C, F]; padded rows produce zeros."""
    y = jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                   w.astype(jnp.float32))
    c = xe.shape[1]
    live = jnp.arange(c)[None, :, None] < counts[:, None, None]
    return jnp.where(live, y, 0.0)
