"""Overload protection: deadline projection, graceful degradation, watchdog.

PR 7/8 gave the serving stack measurement — lifecycle traces, exact
TTFT/TPOT attribution, a windowed SLO burn rate — but nothing *acted* on
any of it: a request that could no longer meet its deadline still held
KV pages to completion, an overloaded pool kept admitting optimistically
until preemption thrashed, and the only stall defense was a
100k-dead-round ``RuntimeError``.  This module closes the observe→act
loop; the scheduler owns the actions (cancellation, admission sizing,
chunk sizing, shedding), this module owns the *policy*:

* :func:`project_finish_s` — optimistic remaining-latency estimate from
  the metrics registry's observed TTFT/TPOT means, used by the
  scheduler's deadline sweep to cancel requests whose remaining-budget
  projection can no longer meet their deadline (cancel early, free the
  pages now, instead of discovering the miss at expiry);
* :class:`DegradationController` — a hysteresis state machine
  (HEALTHY → DEGRADED → SHEDDING) driven by the windowed SLO burn rate
  and the pool-pressure gauge.  Each rung disables *throughput optics*,
  never correctness: DEGRADED sheds speculation (``speculate_k → 0``)
  and shrinks the prefill chunk (smaller join stalls); SHEDDING
  additionally freezes optimistic slot growth (admission reverts to
  worst-case reservation, so no new growth pressure) and sheds
  lowest-priority queued work with a retryable ``RETRY_AFTER``
  rejection.  Every transition is traced and reversible — degradation
  changes *when and whether* work runs, never its tokens, so every
  request that completes stays bit-exact vs an unloaded run;
* :class:`Watchdog` — a per-round progress monitor replacing the old
  idle-spin guard: when the scheduler's progress fingerprint (joins,
  commits, retirements, preemptions, cancellations) has not moved for
  ``watchdog_rounds`` rounds while work exists, the scheduler dumps the
  PR 8 flight bundle and force-sheds the blocking head instead of
  raising — the run finishes (minus the shed request) and ships its own
  postmortem.

Everything here is pure host policy over numbers the registry and pool
already expose; no device work, no new sync points.
"""
from __future__ import annotations

import time

# terminal-cancellation reason codes (the CANCEL trace event carries one)
CANCEL_REASONS = ("deadline", "timeout", "shed", "client")

# retryable-rejection status a shed queued request is answered with
RETRY_AFTER = "RETRY_AFTER"

HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
SHEDDING = "SHEDDING"
STATES = (HEALTHY, DEGRADED, SHEDDING)
_RUNG = {s: i for i, s in enumerate(STATES)}


class WatchdogStall(RuntimeError):
    """Named stall error for the flight bundle — never raised out of the
    run loop (the watchdog sheds instead), but the bundle's ``error``
    field should say *what* tripped, not a generic RuntimeError."""


def project_finish_s(metrics, remaining_tokens: int,
                     queued: bool) -> float | None:
    """Optimistic seconds-to-completion from the registry's observed
    means: a queued request still owes one TTFT (admission + prefill)
    plus ``remaining_tokens - 1`` decode steps; a decoding slot owes only
    its remaining budget at the mean TPOT.  Returns None while the means
    have no samples (never cancel on a guess) — and the estimate is
    deliberately optimistic (unloaded means, no queue-position term), so
    a projection miss means the deadline is *unreachable even in the
    best case*, the one situation where holding pages is pure waste."""
    n_tpot = metrics.count("lat.tpot_s")
    tpot = metrics.sum("lat.tpot_s") / n_tpot if n_tpot else None
    if queued:
        n_ttft = metrics.count("lat.ttft_s")
        if not n_ttft:
            return None
        ttft = metrics.sum("lat.ttft_s") / n_ttft
        return ttft + max(0, remaining_tokens - 1) * (tpot or 0.0)
    if tpot is None:
        return None
    return max(0, remaining_tokens) * tpot


class DegradationController:
    """Hysteresis ladder HEALTHY → DEGRADED → SHEDDING over two signals.

    Per scheduling round the scheduler feeds :meth:`observe` the current
    windowed SLO burn rate (max of TTFT/TPOT burn, from ``slo_stats``)
    and the pool pressure (:meth:`KVPool.pressure`: mapped + held
    fraction — pages no admission could be granted from).  Severity:

    * **2 (critical)** — burn ≥ ``shed_burn``, or the pool is at
      ``shed_pressure`` with work still queued (admission is starving);
    * **1 (hot)** — burn ≥ ``degrade_burn`` or pressure ≥
      ``degrade_pressure``;
    * **0 (cool)** — neither.

    The ladder climbs one rung after ``up_rounds`` *consecutive* rounds
    of severity above the current rung and descends one rung after
    ``down_rounds`` consecutive rounds below it (asymmetric hysteresis:
    react fast, recover deliberately, never flap on one noisy sample).
    What each rung means is exposed as the ``shed_speculation`` /
    ``shrink_chunk`` / ``freeze_growth`` / ``shedding`` properties the
    scheduler consults; the controller never touches scheduler state.
    """

    def __init__(self, *, degrade_burn: float = 1.0,
                 shed_burn: float = 2.0,
                 degrade_pressure: float = 0.9,
                 shed_pressure: float = 1.0,
                 up_rounds: int = 2, down_rounds: int = 4,
                 clock=time.perf_counter):
        if up_rounds < 1 or down_rounds < 1:
            raise ValueError("hysteresis rounds must be >= 1")
        if not (0.0 < degrade_burn <= shed_burn):
            raise ValueError("need 0 < degrade_burn <= shed_burn")
        if not (0.0 < degrade_pressure <= shed_pressure <= 1.0):
            raise ValueError(
                "need 0 < degrade_pressure <= shed_pressure <= 1")
        self.degrade_burn = degrade_burn
        self.shed_burn = shed_burn
        self.degrade_pressure = degrade_pressure
        self.shed_pressure = shed_pressure
        self.up_rounds = up_rounds
        self.down_rounds = down_rounds
        self._clock = clock
        self.state = HEALTHY
        self._since = clock()
        self._hot = 0
        self._cool = 0
        self.time_in_state = {s: 0.0 for s in STATES}
        # (round, from_state, to_state, burn, pressure)
        self.transitions: list[tuple[int, str, str, float, float]] = []
        self.recovered_to_healthy = False

    # -- rung semantics (what the scheduler consults) -------------------
    @property
    def shed_speculation(self) -> bool:
        return self.state != HEALTHY

    @property
    def shrink_chunk(self) -> bool:
        return self.state != HEALTHY

    @property
    def freeze_growth(self) -> bool:
        return self.state == SHEDDING

    @property
    def shedding(self) -> bool:
        return self.state == SHEDDING

    # -- state machine --------------------------------------------------
    def severity(self, burn: float, pressure: float,
                 queue_depth: int) -> int:
        if (burn >= self.shed_burn
                or (pressure >= self.shed_pressure and queue_depth > 0)):
            return 2
        if burn >= self.degrade_burn or pressure >= self.degrade_pressure:
            return 1
        return 0

    def observe(self, *, burn: float, pressure: float, queue_depth: int,
                round: int = 0, now: float | None = None) -> str:
        """Feed one round's signals; returns the (possibly new) state."""
        now = self._clock() if now is None else now
        sev = self.severity(burn, pressure, queue_depth)
        rung = _RUNG[self.state]
        if sev > rung:
            self._hot += 1
            self._cool = 0
            if self._hot >= self.up_rounds:
                self._transition(STATES[rung + 1], round, now,
                                 burn, pressure)
                self._hot = 0
        elif sev < rung:
            self._cool += 1
            self._hot = 0
            if self._cool >= self.down_rounds:
                self._transition(STATES[rung - 1], round, now,
                                 burn, pressure)
                self._cool = 0
        else:
            self._hot = self._cool = 0
        return self.state

    def _transition(self, to: str, round: int, now: float,
                    burn: float, pressure: float) -> None:
        self.time_in_state[self.state] += max(0.0, now - self._since)
        self.transitions.append((round, self.state, to, burn, pressure))
        if to == HEALTHY and self.state != HEALTHY:
            self.recovered_to_healthy = True
        self.state = to
        self._since = now

    # -- reporting ------------------------------------------------------
    def stats(self, now: float | None = None) -> dict:
        """Time-in-state (with the open interval accrued to ``now``),
        the transition log, and the recovery flag the overload smoke
        gates on."""
        now = self._clock() if now is None else now
        tis = dict(self.time_in_state)
        tis[self.state] += max(0.0, now - self._since)
        return {"state": self.state,
                "time_in_state": tis,
                "transitions": list(self.transitions),
                "recovered_to_healthy": self.recovered_to_healthy}

    def reset(self) -> None:
        """Per-wave measurement reset (the scheduler's ``reset_stats``):
        zero the accumulated time-in-state / transition log / recovery
        flag but keep the *current* rung and hysteresis streaks — the
        controller describes live pressure, not history."""
        self._since = self._clock()
        self.time_in_state = {s: 0.0 for s in STATES}
        self.transitions.clear()
        self.recovered_to_healthy = False


class Watchdog:
    """Per-round progress monitor (replaces the idle-spin round counter).

    The scheduler feeds :meth:`tick` a progress *fingerprint* — a tuple
    of monotone counters (joins run, tokens committed, retirements,
    preemptions, cancellations) — once per scheduling round.  Any change
    is progress; ``limit`` consecutive unchanged rounds is a stall and
    ``tick`` returns True exactly once per trip (the counter re-arms, so
    a stall that survives the first shed trips again ``limit`` rounds
    later).  Pure bookkeeping: the scheduler owns the trip *action*
    (flight-bundle dump + force-shed)."""

    def __init__(self, limit: int):
        if limit < 1:
            raise ValueError("watchdog limit must be >= 1")
        self.limit = int(limit)
        self._last: tuple | None = None
        self.stalled_rounds = 0
        self.trips = 0

    def tick(self, fingerprint: tuple) -> bool:
        if fingerprint != self._last:
            self._last = fingerprint
            self.stalled_rounds = 0
            return False
        self.stalled_rounds += 1
        if self.stalled_rounds >= self.limit:
            self.trips += 1
            self.stalled_rounds = 0
            return True
        return False
