"""Unified serving telemetry: request-lifecycle tracing + metrics registry.

The serving stack's observability used to be five ad-hoc stats dicts
(``join_stats`` / ``spec_stats`` / ``latency_stats`` / ``preempt_stats`` /
``prefix_stats``) over counters scattered through ``scheduler.py`` —
aggregates with no way to answer *why* one request's TTFT sat at p95
(queued behind an admission barrier?  preempted twice?  chunk-stalled
behind a round budget?).  The PIM-characterization literature is emphatic
that systems with in-flight resource contention are only tunable with
event-level instrumentation; this module is that layer, in two parts:

**Tracer** — typed per-request lifecycle events

    SUBMIT -> ADMIT -> PREFILL_CHUNK x n -> FIRST_TOKEN
           -> SPEC_COMMIT x n -> (PREEMPT -> RESUME ->) ...
           -> RETIRE | CANCEL(reason=deadline|timeout|shed|client)

each stamped with the scheduling round, slot id, pages held by that slot
and the pool's free-page count at the instant of the event, plus
per-round scheduler **spans** (chaos / join / decode-segment / collect)
and a pool-partition gauge sampled after every allocator mutation
(:attr:`repro.serve.kvpool.KVPool.gauge_cb`).  Chaos faults land in the
same stream (``CHAOS_*`` kinds).  Two export shapes:

* :meth:`Tracer.timeline` — the plain per-request event list, for
  programmatic consumers (the SLA scheduler this enables reads these);
* :meth:`Tracer.to_perfetto` — Chrome/Perfetto ``trace_event`` JSON,
  loadable at https://ui.perfetto.dev: one track per slot (derived
  occupancy spans ADMIT->RETIRE/PREEMPT with the lifecycle instants on
  top), one async track for queue residency (SUBMIT/PREEMPT opens,
  ADMIT closes — requests overlap there, slots never do), one track of
  scheduler spans, and counter tracks for the pool partitions.

**MetricsRegistry** — counters, gauges and fixed-bucket histograms; the
single store every ``*_stats()`` view and the ``BENCH_serve.json`` row
writer read from.  Histograms keep their raw samples next to the bucket
counts so :meth:`MetricsRegistry.percentile` reproduces the legacy
``_pct``-over-list numbers bit-for-bit, and :meth:`MetricsRegistry.reset`
is the one place per-wave measurement state is cleared (the old
``reset_stats`` forgot half its counters; a registry-wide reset cannot
drift that way again).

Naming convention: ``<subsystem>.<metric>[_<unit>]`` — e.g.
``lat.ttft_s`` (histogram, seconds), ``spec.accepted`` (counter),
``pool.free_pages`` (gauge).  Keys are flat strings; ``snapshot()``
returns one flat dict for row writers.

Zero-overhead-off contract: the scheduler only calls into the tracer
behind ``if tracer is not None`` guards at host-sync / scheduling-round
boundaries — never inside ``lax.scan`` or any jitted closure — and the
registry's counter increments are plain dict ops on the host path that
already existed.  Telemetry off (the default) adds no device work and no
per-token host work.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager

import numpy as np


def _pct(a: list[float], q: float) -> float:
    """Percentile guarded against empty inputs — the single helper every
    stats method shares (0.0 on no samples, matching the rest of the
    reportable-either-way stats contract)."""
    return float(np.percentile(np.asarray(a), q)) if a else 0.0


# default histogram bounds (seconds): serving latencies from sub-ms host
# syncs to minute-scale drains.  Samples are kept raw alongside the bucket
# counts, so the bounds shape only the bucketed export, not percentiles.
DEFAULT_BUCKETS_S = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                     0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

# raw-sample reservoir cap: below this every observation is kept verbatim
# (so smoke/test-scale percentiles are bit-identical to the unbounded
# list); past it the reservoir decimates deterministically — a long drain
# no longer grows memory per observation.
DEFAULT_SAMPLE_CAP = 4096


class _Histogram:
    """Fixed-bucket histogram that also keeps a bounded raw reservoir.

    The bucket counts plus the running ``count`` / ``sum`` are the
    fixed-cost aggregates (exportable without the samples); the raw list
    is what the legacy stats views' percentile math reads.  Up to ``cap``
    observations the list is exact — the registry refactor changes no
    reported number at test scale.  At ``cap`` the reservoir halves
    (every other sample dropped) and the keep-stride doubles, so a drain
    of any length holds at most ``cap`` floats while still covering the
    whole observation history at uniform (power-of-two) spacing.
    """

    __slots__ = ("bounds", "counts", "samples", "count", "sum",
                 "cap", "_stride", "_seen")

    def __init__(self, bounds: tuple[float, ...] = DEFAULT_BUCKETS_S,
                 cap: int = DEFAULT_SAMPLE_CAP):
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.samples: list[float] = []
        self.count = 0
        self.sum = 0.0
        self.cap = max(2, int(cap))
        self._stride = 1
        self._seen = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for i, b in enumerate(self.bounds):
            if v <= b:
                break
        else:
            i = len(self.bounds)
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        if self._seen % self._stride == 0:
            self.samples.append(v)
            if len(self.samples) >= self.cap:
                del self.samples[1::2]       # deterministic decimation
                self._stride *= 2
        self._seen += 1

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.samples.clear()                 # in place: stats views alias
        self.count = 0
        self.sum = 0.0
        self._stride = 1
        self._seen = 0


class MetricsRegistry:
    """Flat-namespace counters, gauges and histograms for the serving
    stack.  All host-side, all plain dicts — cheap enough to stay on even
    when tracing is off (the counters it holds are the ones the scheduler
    always maintained)."""

    def __init__(self):
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, _Histogram] = {}

    # -- counters ------------------------------------------------------
    def inc(self, name: str, delta: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + delta

    def value(self, name: str) -> float:
        return self._counters.get(name, 0)

    # -- gauges --------------------------------------------------------
    def set_gauge(self, name: str, v: float) -> None:
        self._gauges[name] = v

    def gauge(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    # -- histograms ----------------------------------------------------
    def hist(self, name: str,
             bounds: tuple[float, ...] = DEFAULT_BUCKETS_S) -> _Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _Histogram(bounds)
        return h

    def observe(self, name: str, v: float) -> None:
        self.hist(name).observe(v)

    def samples(self, name: str) -> list[float]:
        """The histogram's raw sample list (live object — the legacy
        attribute views on the scheduler alias this directly)."""
        return self.hist(name).samples

    def count(self, name: str) -> int:
        """Total observations (running counter — survives reservoir
        decimation, costs nothing to read)."""
        return self.hist(name).count

    def sum(self, name: str) -> float:
        return float(self.hist(name).sum)

    def percentile(self, name: str, q: float) -> float:
        """Empty-guarded percentile over the raw samples — the one
        percentile implementation (satellite: no per-method sample
        plumbing anywhere else)."""
        return _pct(self.hist(name).samples, q)

    # -- lifecycle -----------------------------------------------------
    def reset(self, gauges: bool = False) -> None:
        """Zero every counter and histogram.  Gauges describe *current*
        state, not accumulation, so they survive by default — but a
        caller that is discarding the state they describe (the scheduler
        rebuilding its pool between waves) passes ``gauges=True`` so a
        stale geometry cannot leak into the next wave's ``snapshot()``.
        This is the whole per-wave measurement reset — a counter that
        lives here cannot be forgotten by ``reset_stats`` again."""
        self._counters.clear()
        for h in self._hists.values():
            h.reset()
        if gauges:
            self._gauges.clear()

    def clear_gauges(self, prefix: str) -> None:
        """Drop every gauge under ``prefix`` (e.g. ``"pool."`` when the
        pool that set them is torn down)."""
        for name in [n for n in self._gauges if n.startswith(prefix)]:
            del self._gauges[name]

    def snapshot(self) -> dict:
        """One flat dict of everything: counters verbatim, gauges under
        their name, histograms as ``name.count`` / ``name.sum`` /
        ``name.p50`` / ``name.p95`` (running aggregates — nothing is
        recomputed over raw lists here except the percentiles, which
        read the bounded reservoir)."""
        out: dict[str, float] = dict(self._counters)
        out.update(self._gauges)
        for name, h in self._hists.items():
            out[f"{name}.count"] = h.count
            out[f"{name}.sum"] = float(h.sum)
            out[f"{name}.p50"] = _pct(h.samples, 50)
            out[f"{name}.p95"] = _pct(h.samples, 95)
        return out


# typed lifecycle event kinds (the trace-completeness tests enumerate
# these — a new kind needs a track assignment in ``to_perfetto``).
# CANCEL is a terminal state like RETIRE: it closes the rid's queue span
# (a queued cancel) or its slot span (a mid-flight cancel) and carries a
# ``reason`` attr from repro.serve.overload.CANCEL_REASONS.
LIFECYCLE_KINDS = ("SUBMIT", "ADMIT", "RESUME", "PREFILL_CHUNK",
                   "FIRST_TOKEN", "SPEC_COMMIT", "PREEMPT", "CANCEL",
                   "RETIRE")
# scheduler-global control-plane instants (rid=None -> scheduler track):
# DEGRADE marks a degradation-ladder transition, WATCHDOG a progress
# watchdog trip (flight bundle dumped, blocking head force-shed)
CONTROL_KINDS = ("DEGRADE", "WATCHDOG")
CHAOS_KINDS = ("CHAOS_HOLD", "CHAOS_RELEASE_HELD", "CHAOS_SLOT_FAILURE",
               "CHAOS_SLOT_FAILURE_NOOP", "CHAOS_VICTIM_OVERRIDE",
               "CHAOS_STALL", "CHAOS_BURST")

_PID = 1
_TID_SCHED = 0          # scheduler spans + chaos instants
_TID_QUEUE = 1          # async queue-residency spans
_TID_SLOT0 = 10         # slot s lands on tid _TID_SLOT0 + s


class Tracer:
    """Append-only event/span recorder for one batcher's lifetime.

    Everything is host-side and O(1) per call; the scheduler guards every
    call site with ``if tracer is not None`` so the off path costs
    nothing.  Timestamps are ``time.perf_counter()`` seconds relative to
    construction (``t0``); the Perfetto export converts to microseconds.

    ``ring=N`` turns the recorder into a bounded flight recorder: events,
    spans and pool samples live in ``deque(maxlen=...)`` ring buffers, so
    an arbitrarily long run holds at most the last N events — cheap
    enough to leave on even when full tracing is off.  The scheduler runs
    one such tracer unconditionally and dumps its tail as a debug bundle
    when a pool/prefix invariant trips (see ``Batcher.flight_bundle``).
    """

    def __init__(self, clock=time.perf_counter, ring: int | None = None):
        self._clock = clock
        self.t0 = clock()
        self.ring = ring
        if ring is None:
            self.events: list[dict] = []
            self.spans: list[dict] = []
            self.pool_samples: list[tuple[float, dict]] = []
        else:
            self.events = deque(maxlen=int(ring))
            self.spans = deque(maxlen=int(ring))
            self.pool_samples = deque(maxlen=int(ring))

    def now(self) -> float:
        return self._clock()

    # -- recording -----------------------------------------------------
    def event(self, kind: str, rid: int | None, *, round: int = 0,
              slot: int | None = None, pages_held: int = 0,
              pool_free: int = 0, t: float | None = None, **attrs) -> None:
        """One typed lifecycle/fault event.  ``rid=None`` marks a
        scheduler-global event (chaos faults); ``slot=None`` marks a
        queue-side event (SUBMIT, or ADMIT in dense mode where there is
        no pool)."""
        e = {"t": self._clock() if t is None else t, "kind": kind,
             "rid": rid, "round": round, "slot": slot,
             "pages_held": pages_held, "pool_free": pool_free}
        if attrs:
            e.update(attrs)
        self.events.append(e)

    def add_span(self, name: str, round: int, t0: float, t1: float) -> None:
        self.spans.append({"name": name, "round": round,
                           "t0": t0, "t1": max(t0, t1)})

    @contextmanager
    def span(self, name: str, round: int = 0):
        t0 = self._clock()
        try:
            yield
        finally:
            self.add_span(name, round, t0, self._clock())

    def pool_gauge(self, counts: dict) -> None:
        """Pool-partition sample (called from ``KVPool.gauge_cb`` after
        every allocator mutation)."""
        self.pool_samples.append((self._clock(), dict(counts)))

    def tail(self) -> list[dict]:
        """The retained events, oldest first, as plain copies — the
        flight-recorder bundle payload (for an unbounded tracer this is
        simply every event)."""
        return [dict(e) for e in self.events]

    # -- plain export --------------------------------------------------
    def rids(self) -> list[int]:
        seen = []
        for e in self.events:
            if e["rid"] is not None and e["rid"] not in seen:
                seen.append(e["rid"])
        return seen

    def timeline(self, rid: int) -> list[dict]:
        """The request's events in time order (copies — callers may
        annotate without corrupting the trace)."""
        return sorted((dict(e) for e in self.events if e["rid"] == rid),
                      key=lambda e: e["t"])

    def timelines(self) -> dict[int, list[dict]]:
        return {rid: self.timeline(rid) for rid in self.rids()}

    # -- Perfetto export -----------------------------------------------
    def _us(self, t: float) -> float:
        return max(0.0, (t - self.t0) * 1e6)

    def to_perfetto(self, path: str | None = None) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON (load the file at
        https://ui.perfetto.dev or chrome://tracing).

        Track layout (one process, pid 1):

        * tid 0 ``scheduler`` — per-round spans (``ph:"X"``: chaos /
          join / decode-segment / collect, strictly sequential) plus
          chaos fault instants;
        * tid 1 ``queue`` — async spans (``ph:"b"``/``"e"``, id = rid)
          from SUBMIT (or PREEMPT) to ADMIT — queue residency overlaps
          across requests, which is what the async phase exists for;
        * tid 10+s ``slot s`` — an ``X`` span per occupancy (derived
          ADMIT -> RETIRE/PREEMPT; a preempted slot's span *ends at* the
          PREEMPT instant, the rid's next ADMIT opens a span on whatever
          slot re-admits it) with the lifecycle instants (``ph:"i"``)
          on top — one request per slot at a time, so slot spans never
          overlap;
        * counter track ``kv_pool_pages`` (``ph:"C"``) — the pool's
          free/mapped/cached/preempted/held partition sizes over time.
        """
        ev: list[dict] = []
        ev.append({"ph": "M", "pid": _PID, "name": "process_name",
                   "args": {"name": "repro.serve"}})

        def thread_meta(tid: int, name: str) -> None:
            ev.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name", "args": {"name": name}})

        thread_meta(_TID_SCHED, "scheduler")
        thread_meta(_TID_QUEUE, "queue")
        for sp in self.spans:
            ev.append({"name": sp["name"], "cat": "scheduler", "ph": "X",
                       "pid": _PID, "tid": _TID_SCHED,
                       "ts": self._us(sp["t0"]),
                       "dur": self._us(sp["t1"]) - self._us(sp["t0"]),
                       "args": {"round": sp["round"]}})

        events = sorted(self.events, key=lambda e: e["t"])
        slots_seen: set[int] = set()
        open_queue: set[int] = set()        # rids with an open queue span
        open_slot: dict[int, dict] = {}     # slot -> {"rid", "t0"}
        t_end = self._us(events[-1]["t"]) if events else 0.0

        def close_slot(slot: int, ts: float, end_kind: str) -> None:
            sp = open_slot.pop(slot, None)
            if sp is None:
                return
            ev.append({"name": f"rid {sp['rid']}", "cat": "slot",
                       "ph": "X", "pid": _PID, "tid": _TID_SLOT0 + slot,
                       "ts": sp["t0"], "dur": max(0.0, ts - sp["t0"]),
                       "args": {"rid": sp["rid"], "end": end_kind}})

        for e in events:
            kind, rid, slot = e["kind"], e["rid"], e["slot"]
            ts = self._us(e["t"])
            args = {k: v for k, v in e.items()
                    if k not in ("t", "kind") and v is not None}
            if slot is not None:
                tid = _TID_SLOT0 + slot
                slots_seen.add(slot)
            elif rid is None:
                tid = _TID_SCHED
            else:
                tid = _TID_QUEUE
            ev.append({"name": kind, "cat": "lifecycle", "ph": "i",
                       "s": "t", "pid": _PID, "tid": tid, "ts": ts,
                       "args": args})
            if rid is not None:
                if kind in ("SUBMIT", "PREEMPT") and rid not in open_queue:
                    open_queue.add(rid)
                    ev.append({"name": f"queued rid {rid}", "cat": "queue",
                               "ph": "b", "id": rid, "pid": _PID,
                               "tid": _TID_QUEUE, "ts": ts, "args": args})
                elif (kind in ("ADMIT", "CANCEL") and rid in open_queue):
                    # ADMIT moves the request onto a slot; a queued
                    # CANCEL (deadline/timeout/shed before admission)
                    # ends its residency without one
                    open_queue.discard(rid)
                    ev.append({"name": f"queued rid {rid}", "cat": "queue",
                               "ph": "e", "id": rid, "pid": _PID,
                               "tid": _TID_QUEUE, "ts": ts, "args": {}})
            if slot is not None:
                if kind == "ADMIT":
                    close_slot(slot, ts, "lost")     # defensive: no-op
                    open_slot[slot] = {"rid": rid, "t0": ts}
                elif kind in ("PREEMPT", "RETIRE", "CANCEL"):
                    close_slot(slot, ts, kind)
        for slot in list(open_slot):
            close_slot(slot, t_end, "open")          # still live at export
        for slot in sorted(slots_seen):
            thread_meta(_TID_SLOT0 + slot, f"slot {slot}")

        for t, counts in self.pool_samples:
            ev.append({"name": "kv_pool_pages", "cat": "pool", "ph": "C",
                       "pid": _PID, "ts": self._us(t),
                       "args": {k: int(v) for k, v in counts.items()}})

        data = {"traceEvents": ev, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(data, f)
                f.write("\n")
        return data
