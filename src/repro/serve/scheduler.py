"""Slot-based continuous batching over the device-resident decode loop.

Serving architecture
--------------------
The scheduler owns a fixed-width **slot table**: ``cfg.batch`` decode slots
that share one KV-cache allocation ([layers, B, max_len, ...]), one jitted
prefill/join step and one jitted multi-token decode scan.  Host state per
slot is just (request id, token budget, live length); device state is
(next-token [B,1], per-slot cache_len [B], done flag [B], remaining budget
[B], PRNG key, caches).

Refill policy: requests queue in a ``deque``.  Between decode *segments*
(``cfg.sync_every`` fused steps — the only host sync points), every retired
slot is refilled from the queue head: the joining prompts are padded to one
width, batch-prefilled in a single jitted call, and selected into the live
state with a batch-axis ``where`` — occupied slots keep their caches
bit-for-bit.  Mixed-length requests therefore share one jitted decode step
at all times instead of padding to a fresh batch each round, and the same
two compiled executables are reused across the whole drain (no retracing).

Retirement: a slot retires when it emits EOS (the EOS token is kept) or
exhausts its ``max_new`` budget.  Both conditions are evaluated *on device*
inside the scan (done-flag latch), so a retired slot stops sampling,
stops growing its cache and emits a PAD sentinel until the segment ends;
the host mirrors the same rules when it drains the emitted block.

Dead-block skipping (paper §5.1.2): commercial PIM kernels win by skipping
commands for banks whose data is dead; the serving analogue is KV blocks
past a slot's live length.  Two levels: (1) per-slot lengths reach the
decode-attention kernel, which skips every KV block past *that slot's*
depth before any compute; (2) between segments the host knows the deepest
live slot, so the engine re-jits the scan with a power-of-two ``kv_cap``
and the attention op slices the cache to that bound — blocks past *every*
slot's length are never launched at all.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from .engine import PAD_TOKEN, ServeConfig, jit_decode_loop, jit_join
from ..models.model_zoo import Model


def _pow2_bucket(n: int, lo: int = 16, hi: int | None = None) -> int:
    b = max(lo, 1 << max(0, n - 1).bit_length())
    return min(b, hi) if hi is not None else b


class ContinuousBatcher:
    """Greedy continuous batcher over a fixed slot table (see module doc).

    Drop-in upgrade of the seed per-token ``Batcher``: same
    ``submit``/``run`` surface, but the hot path is a jitted ``lax.scan``
    with donated caches, device-side sampling and per-slot lengths instead
    of a per-token Python loop with host argmax.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 eos_id: int | None = None, seed: int = 0):
        self.model, self.params, self.cfg = model, params, cfg
        self.eos = eos_id
        self.queue: collections.deque[tuple[int, list[int]]] = \
            collections.deque()
        self.results: dict[int, list[int]] = {}
        b = cfg.batch
        self.caches = model.init_caches(b, cfg.max_len, cfg.dtype)
        self.tok = jnp.zeros((b, 1), jnp.int32)
        self.lengths = jnp.zeros((b,), jnp.int32)
        self.done = jnp.ones((b,), bool)
        self.remaining = jnp.zeros((b,), jnp.int32)
        self.key = jax.random.key(seed)
        # host mirror of the slot table
        self.slot_rid: list[int | None] = [None] * b
        self.slot_len = [0] * b
        self.slot_budget = [0] * b
        self.outputs: dict[int, list[int]] = {}
        self._join = jit_join(model, cfg, eos_id=eos_id)
        self._loops: dict[tuple[int, int | None], object] = {}

    # ------------------------------------------------------------------
    def submit(self, rid: int, prompt: list[int]) -> None:
        if not prompt:
            raise ValueError("empty prompt")
        self.queue.append((rid, list(prompt)))

    # ------------------------------------------------------------------
    def _loop(self, steps: int, kv_cap: int | None):
        keyid = (steps, kv_cap)
        if keyid not in self._loops:
            self._loops[keyid] = jit_decode_loop(
                self.model, self.cfg, steps=steps, eos_id=self.eos,
                kv_cap=kv_cap)
        return self._loops[keyid]

    def _kv_cap(self, steps: int) -> int | None:
        live = [self.slot_len[i] for i, r in enumerate(self.slot_rid)
                if r is not None]
        if not live:
            return None
        cap = _pow2_bucket(max(live) + steps, hi=self.cfg.max_len)
        return None if cap >= self.cfg.max_len else cap

    # ------------------------------------------------------------------
    def _refill(self, max_new: int) -> None:
        free = [i for i, r in enumerate(self.slot_rid) if r is None]
        if not free or not self.queue:
            return
        take: list[tuple[int, int, list[int]]] = []   # (slot, rid, prompt)
        for slot in free:
            if not self.queue:
                break
            take.append((slot, *self.queue.popleft()))
        if not take:
            return
        b = self.cfg.batch
        width = _pow2_bucket(max(len(p) for _, _, p in take), lo=8,
                             hi=self.cfg.max_len)
        join_mask = np.zeros((b,), bool)
        prompts = np.zeros((b, width), np.int32)
        plens = np.ones((b,), np.int32)
        for slot, _, p in take:
            join_mask[slot] = True
            prompts[slot, :len(p)] = p
            plens[slot] = len(p)
        (self.caches, self.tok, self.lengths, self.done, self.remaining,
         self.key, first) = self._join(
            self.params, self.caches, self.tok, self.lengths, self.done,
            self.remaining, jnp.asarray(join_mask), jnp.asarray(prompts),
            jnp.asarray(plens),
            jnp.full((b,), max_new, jnp.int32), self.key)
        first = np.asarray(first)
        for slot, rid, p in take:
            out = [int(first[slot])]
            self.outputs[rid] = out
            self.slot_len[slot] = len(p)
            if (self.eos is not None and out[0] == self.eos) or max_new <= 1:
                self.results[rid] = out           # retired at birth
                self.slot_rid[slot] = None
            else:
                self.slot_rid[slot] = rid
                self.slot_budget[slot] = max_new

    # ------------------------------------------------------------------
    def _collect(self, emitted: np.ndarray) -> None:
        steps = emitted.shape[0]
        for i, rid in enumerate(self.slot_rid):
            if rid is None:
                continue
            out = self.outputs[rid]
            appended = 0
            for t in range(steps):
                v = int(emitted[t, i])
                if v == PAD_TOKEN:
                    break
                out.append(v)
                appended += 1
                self.slot_len[i] += 1
                if ((self.eos is not None and v == self.eos)
                        or len(out) >= self.slot_budget[i]):
                    self.results[rid] = out
                    self.slot_rid[i] = None
                    break
            if appended == 0 and self.slot_rid[i] is not None:
                raise RuntimeError(
                    f"slot {i} (request {rid}) stalled: device reports done "
                    "but host bookkeeping thinks it is live")

    # ------------------------------------------------------------------
    def run(self, max_new: int = 16) -> dict[int, list[int]]:
        """Drain the queue: refill slots, run fused decode segments, sync
        emitted tokens every ``cfg.sync_every`` steps."""
        if max_new <= 0:
            while self.queue:
                rid, _ = self.queue.popleft()
                self.results[rid] = []
            return self.results
        steps = max(1, self.cfg.sync_every)
        # reject oversized requests up front, before anything is dequeued,
        # so a bad request never drops its queue-mates
        for rid, prompt in self.queue:
            if len(prompt) + max_new > self.cfg.max_len:
                raise ValueError(
                    f"request {rid}: prompt {len(prompt)} + max_new "
                    f"{max_new} exceeds max_len {self.cfg.max_len}")
        while self.queue or any(r is not None for r in self.slot_rid):
            self._refill(max_new)
            if all(r is None for r in self.slot_rid):
                if self.queue:
                    continue
                break
            loop = self._loop(steps, self._kv_cap(steps))
            ((self.tok, self.caches, self.lengths, self.done,
              self.remaining, self.key), emitted) = loop(
                self.params, self.tok, self.caches, self.lengths,
                self.done, self.remaining, self.key)
            self._collect(np.asarray(emitted))
        return self.results


# the public serving entry point: the slot scheduler *is* the batcher
Batcher = ContinuousBatcher
