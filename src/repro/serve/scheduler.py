"""Slot-based continuous batching over the device-resident decode loop.

Serving architecture
--------------------
The scheduler owns a fixed-width **slot table**: ``cfg.batch`` decode slots
that share one KV-cache allocation ([layers, B, max_len, ...]), one jitted
prefill/join step and one jitted multi-token decode scan.  Host state per
slot is just (request id, token budget, live length); device state is
(next-token [B,1], per-slot cache_len [B], done flag [B], remaining budget
[B], PRNG key, caches).

Refill policy: requests queue in a ``deque``.  Between decode *segments*
(``cfg.sync_every`` fused steps — the only host sync points), every retired
slot is refilled from the queue head: the joining prompts are padded to one
width, batch-prefilled in a single jitted call, and selected into the live
state with a batch-axis ``where`` — occupied slots keep their caches
bit-for-bit.  Mixed-length requests therefore share one jitted decode step
at all times instead of padding to a fresh batch each round, and the same
two compiled executables are reused across the whole drain (no retracing).

Retirement: a slot retires when it emits EOS (the EOS token is kept) or
exhausts its ``max_new`` budget.  Both conditions are evaluated *on device*
inside the scan (done-flag latch), so a retired slot stops sampling,
stops growing its cache and emits a PAD sentinel until the segment ends;
the host mirrors the same rules when it drains the emitted block.

Dead-block skipping (paper §5.1.2): commercial PIM kernels win by skipping
commands for banks whose data is dead; the serving analogue is KV blocks
past a slot's live length.  Two levels: (1) per-slot lengths reach the
decode-attention kernel, which skips every KV block past *that slot's*
depth before any compute; (2) between segments the host knows the deepest
live slot, so the engine re-jits the scan with a power-of-two ``kv_cap``
and the attention op slices the cache to that bound — blocks past *every*
slot's length are never launched at all.

Paged mode (``cfg.paged``, repro.serve.kvpool): the per-slot ``max_len``
stripes are replaced by fixed-size pages in one pooled allocation.
Admission is now on **free-page capacity** — a request joins when the pool
can hold its prompt + budget (``ceil((plen + max_new) / page_size)``
pages), not merely when a slot index is free — and a retiring slot returns
every page to the free list at the segment boundary, so short/early-EOS
requests stop stranding ``max_len``-sized stripes.  The dense ``kv_cap``
bucketing becomes **page-count bucketing**: the device page table is
sliced to a power-of-two bound on the deepest live slot's page count
(same ``_pow2_bucket`` policy, so segments don't retrace), which prunes
the paged-attention grid to live pages only.

Prefix cache (``cfg.prefix_cache``, repro.serve.prefixcache, needs paged):
admission first matches the prompt against a radix tree of page-aligned
cached chunks; the matched pages are mapped into the joining slot via
``KVPool.share`` (refcounts go above 1) and only the **uncached suffix**
is prefetched into fresh pages and prefilled — hit-aware admission needs
free pages for suffix + budget only.  Full prompt pages are registered
after reservation (so queue-mates in the same refill round already hit),
and retirement parks registered pages in the evictable cached state
instead of freeing them — reclaimed LRU/leaf-first on pool pressure, so
the cache reserves zero capacity.  Attention-only: hybrid SSM models are
rejected (a recurrent state cannot resume from a cached page).

Admission policy (``cfg.admission``): ``"fifo"`` (default) keeps strict
head-of-line order — if the head's pages don't fit, nothing joins until a
retirement frees them.  ``"skip-ahead"`` scans up to
``cfg.admission_lookahead`` queued requests for the first admissible one
when the head blocks: higher slot occupancy under mixed prompt sizes, at
the cost of a bounded reorder window (per-slot lengths keep every
request's tokens schedule-independent either way).  **Aging** bounds the
reordering: every time a blocked request is bypassed its skip count
grows, and once it reaches ``cfg.admission_max_skips`` it becomes a
barrier — the lookahead scan stops at it, so sustained small-request
load cannot starve a big prompt indefinitely (``max_skips=0``
degenerates skip-ahead to FIFO).

Chunked prefill (``cfg.prefill_chunk``, needs paged): a long prompt's
uncached suffix no longer monopolizes one join — it is prefilled in
page-aligned chunks of at most ``prefill_chunk`` tokens, one chunk per
refill round, the slot sitting in the **PREFILLING** state in between:

    queued --admit--> PREFILLING --last chunk--> decoding --EOS/budget-->
    retired            (chunks interleave with other slots' decode
                        segments; device done-latch keeps the slot
                        frozen — no sampling, no cache growth, PAD
                        emissions — while its table row keeps accepting
                        chunk scatters at ``cache_len`` = filled depth)

Pages for the whole worst case are still reserved at admission (no
mid-prefill preemption); each continuation round re-enters the same
``jit_paged_join`` with ``prefix_lens`` = the filled depth, exactly the
suffix-resume path the prefix cache introduced, and only the final chunk
samples a first token (``commit_mask``).  Chunk boundaries are
page-aligned, so a frozen slot's placeholder decode writes (overwritten
by the next chunk) can never land in a shared prefix page, and prompt
pages are registered in the radix tree *as chunks cover them* — a
queue-mate can match and gather a page in the same join that writes it
(scatters precede gathers per layer), but never one the writer has not
reached.

Decode-priority chunk budget (``cfg.prefill_round_tokens``): by default a
refill round takes one chunk from *every* PREFILLING slot plus the first
chunk of every new admission, so many concurrent long prompts can still
make the round's join wide.  A round-token budget caps the total prefill
tokens a single round may take: once the running total reaches the cap,
further continuations are deferred to the next round (counted in
``join_stats()['budget_deferrals']``) and admission stops.  The first
piece of a round is always taken, so prefill always progresses — the
budget trades prefill throughput for decode latency explicitly.

Self-speculative decoding (``cfg.speculate_k``, needs paged; greedy and
attention-only): decode segments run the draft-k verify loop from
:func:`repro.serve.engine.make_decode_loop` — per step, k candidate
tokens are drafted from the slot's own prompt+output ``history`` (the
on-device n-gram/period lookup in ``engine.ngram_propose``) and verified
in one Lq = k+1 paged attention call; the per-slot accepted length
commits 1..k+1 tokens per step at bit-identical greedy output.  The
scheduler's part of the contract:

* **admission reserves the speculation window** — every verify writes
  K/V up to position ``lengths + k``, so the worst-case page reservation
  (and ``can_admit``, and the up-front ``max_len`` validation) grows
  from ``prompt + max_new`` to ``prompt + max_new + k`` tokens;
* **host history**: the prompt is written into the slot's history row at
  admission and the first sampled token at commit; during decode the
  device updates history inside the scan and the host mirror is synced
  back at each segment boundary (joins are host-sync points already);
* **variable advance**: ``emitted`` is [steps, B, k+1] — ``_collect``
  walks each step's committed burst (PAD-terminated) with the same
  EOS/budget retirement rules, and the per-step committed counts feed
  ``spec_stats()`` (acceptance rate = accepted drafts / proposed).

Optimistic admission + page-level preemption
(``cfg.admission_mode="optimistic"``, needs paged; attention-only):
reservation admission maps the full worst case (prompt + max_new + k) at
join time, so the pool runs far under its true capacity whenever outputs
finish early — ``kv_util_mean`` is the gap.  Optimistic admission maps
only the *prompt's* pages at join time and grows each decoding slot's
table on demand between segments (``_ensure_decode_pages``: cover the
segment's worst-case advance, ``steps * (k+1)`` tokens, capped by the
slot's total budget).  When growth outruns the pool, the scheduler picks
a **victim** under a deterministic policy — lowest priority class
(``submit(..., priority=)``), then most pages mapped, then least decode
progress, then lowest slot id — releases the victim's pages (dead
private pages park in the pool's *preempted* partition, registered
prefix pages stay evictable-cached) and re-queues it at the queue head:

    ... -> DECODING --pool pressure--> PREEMPTED (off device, pages
    released, host history keeps prompt + committed tokens) --re-admit-->
    PREFILLING/DECODING (recompute KV from history via the ordinary
    chunked-prefill join at absolute depth) --> ... -> retired

Resume is recompute-on-resume: the re-queued "prompt" is the original
prompt plus every committed token, so the ordinary suffix-prefill path
rebuilds the KV bit-exactly and the join's first sampled token is the
next token the uninterrupted run would have produced (greedy parity).
Pages the victim had covered are registered in the radix tree at
preemption (generated-token pages are immutable full pages too), so with
the prefix cache on the resume usually *matches* most of its history and
recomputes only a page-aligned tail.  No-livelock: every preemption
charges the request's preempt count, and at ``admission_max_skips`` the
request becomes an admission **barrier** (the PR 4 aging mechanism) —
nothing joins past it, the pool drains toward it, and since the victim
policy always evicts the least-progressed slot last, some slot always
runs to retirement, so every preempted request eventually completes.

Chaos injection (``chaos=``, repro.serve.chaos): a deterministic
round-keyed injector can force pool exhaustion (``KVPool.hold`` on the
free list), override victim selection, simulate slot failure
mid-decode (handled as a preemption — recompute-on-resume *is* the
recovery path), suppress whole scheduling rounds (``stall_at``, the
watchdog drill) and inject synthetic queue bursts (``burst_at``), with
optional per-round ``KVPool.check()`` / ``PrefixCache.check()``
invariant sweeps.

Overload protection (repro.serve.overload): deadlines and cancellation
are always on — ``submit(deadline_s=..., timeout_s=...)`` stamps
per-request absolute deadlines, and a per-round sweep cancels requests
whose deadline/timeout passed or whose remaining-budget projection
(observed TTFT/TPOT means) can no longer meet the deadline.  CANCELLED
is a terminal lifecycle state (QUEUED→CANCELLED releases nothing;
PREFILLING/DECODING→CANCELLED releases pages through ``_release_slot``
and done-latches the device row exactly like a preemption, minus the
re-queue), traced as a ``CANCEL`` event with a reason code
(deadline / timeout / shed / client).  ``cfg.overload`` arms the
degradation controller (HEALTHY→DEGRADED→SHEDDING on SLO burn rate +
pool pressure): DEGRADED sheds speculation and shrinks the prefill
chunk, SHEDDING freezes optimistic growth (admission reverts to
worst-case reservation) and sheds lowest-priority queued work with a
retryable RETRY_AFTER rejection.  Degradation only changes when and
whether work runs — every request that completes stays bit-exact.  A
progress watchdog (``cfg.watchdog_rounds`` rounds with no join, commit,
retirement, preemption or cancellation) replaces the old idle-spin
guard: it dumps the flight-recorder bundle and force-sheds the blocking
head instead of raising, so a livelocked drain finishes (minus the shed
requests) and ships its own postmortem.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .engine import (PAD_TOKEN, ServeConfig, jit_decode_loop, jit_join,
                     jit_paged_decode_loop, jit_paged_join,
                     jit_spec_decode_loop)
from .kvpool import KVPool, PageError
from .overload import (CANCEL_REASONS, HEALTHY, RETRY_AFTER, STATES,
                       DegradationController, Watchdog, WatchdogStall,
                       project_finish_s)
from .prefixcache import PrefixCache
# _pct moved to telemetry (the registry owns percentile math) but stays
# importable from here — it has always been this module's public helper
from .telemetry import MetricsRegistry, Tracer, _pct  # noqa: F401
from ..models.model_zoo import Model


def _pow2_bucket(n: int, lo: int = 16, hi: int | None = None) -> int:
    b = max(lo, 1 << max(0, n - 1).bit_length())
    return min(b, hi) if hi is not None else b


class ContinuousBatcher:
    """Greedy continuous batcher over a fixed slot table (see module doc).

    Drop-in upgrade of the seed per-token ``Batcher``: same
    ``submit``/``run`` surface, but the hot path is a jitted ``lax.scan``
    with donated caches, device-side sampling and per-slot lengths instead
    of a per-token Python loop with host argmax.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 eos_id: int | None = None, seed: int = 0, chaos=None,
                 telemetry: Tracer | None = None):
        self.model, self.params, self.cfg = model, params, cfg
        self.eos = eos_id
        # every accumulated stat lives in the registry (the *_stats()
        # methods and the legacy counter attributes are views over it);
        # the tracer is optional — None (the default, unless
        # cfg.telemetry asks for one) keeps every event call site a
        # skipped ``if`` at scheduling-round boundaries
        self.metrics = MetricsRegistry()
        self.telemetry = (telemetry if telemetry is not None
                          else Tracer() if cfg.telemetry else None)
        # flight recorder: an always-on bounded ring of the same
        # lifecycle events (host dict appends only — no spans, no pool
        # gauge callback, no device syncs, so the traced==untraced
        # parity contract holds).  Dumped as a debug bundle when a
        # PageError escapes the run loop (see ``_dump_flight``).
        self.flight = (Tracer(ring=cfg.flight_events)
                       if cfg.flight_recorder else None)
        self.last_flight_bundle: dict | None = None
        # SLO accounting: priority classes that have scored at least one
        # sample (the met/total counters themselves live in the
        # registry, so ``reset_stats`` clears them with everything else)
        self._slo_classes: set[int] = set()
        self.queue: collections.deque[tuple[int, list[int]]] = \
            collections.deque()
        self.results: dict[int, list[int]] = {}
        if cfg.admission not in ("fifo", "skip-ahead"):
            raise ValueError(f"unknown admission policy {cfg.admission!r}")
        if cfg.admission_mode not in ("reserve", "optimistic"):
            raise ValueError(
                f"unknown admission mode {cfg.admission_mode!r} "
                "(expected 'reserve' or 'optimistic')")
        if cfg.admission_mode == "optimistic":
            from ..configs.base import BlockKind
            if not cfg.paged:
                raise ValueError(
                    "admission_mode='optimistic' requires paged=True "
                    "(on-demand growth and preemption move pages through "
                    "the pool)")
            if any(s.kind is BlockKind.SSM
                   for s in model.cfg.resolved_segments()):
                raise ValueError(
                    "optimistic admission is attention-only: preempting a "
                    "hybrid SSM slot would discard a recurrent state that "
                    "recompute-on-resume cannot rebuild from paged KV")
        self.chaos = chaos
        if chaos is not None and not cfg.paged:
            raise ValueError("chaos injection requires paged=True (its "
                             "faults move pages through the pool)")
        if cfg.prefill_chunk is not None:
            from ..configs.base import BlockKind
            if not cfg.paged:
                raise ValueError("prefill_chunk requires paged=True "
                                 "(chunks resume through the page table)")
            if cfg.prefill_chunk <= 0:
                raise ValueError("prefill_chunk must be positive")
            if cfg.prefill_chunk % cfg.page_size:
                raise ValueError(
                    f"prefill_chunk {cfg.prefill_chunk} must be a multiple "
                    f"of page_size {cfg.page_size} (chunk boundaries must "
                    "never land inside a shared prefix page)")
            if any(s.kind is BlockKind.SSM
                   for s in model.cfg.resolved_segments()):
                raise ValueError(
                    "prefill_chunk is attention-only: a hybrid SSM "
                    "model's recurrent state cannot resume mid-prompt "
                    "across join calls")
        if cfg.prefill_round_tokens is not None \
                and cfg.prefill_round_tokens <= 0:
            raise ValueError("prefill_round_tokens must be positive")
        self.spec_k = cfg.speculate_k or 0
        if cfg.speculate_k is not None:
            from ..configs.base import BlockKind
            if not cfg.paged:
                raise ValueError(
                    "speculate_k requires paged=True (the verify step "
                    "writes and rolls back through the page table)")
            if cfg.speculate_k < 1:
                raise ValueError("speculate_k must be >= 1")
            if cfg.speculate_ngram < 1:
                raise ValueError("speculate_ngram must be >= 1")
            if cfg.temperature != 0.0:
                raise ValueError(
                    "speculate_k is greedy-only for now: acceptance is "
                    "defined by exact argmax agreement (temperature 0)")
            if any(s.kind is BlockKind.SSM
                   for s in model.cfg.resolved_segments()):
                raise ValueError(
                    "speculate_k is attention-only: a hybrid SSM model's "
                    "recurrent state advances k+1 tokens per verify and "
                    "cannot roll back past the acceptance point")
        b = cfg.batch
        if cfg.paged:
            self.pool = KVPool(cfg.pool_pages, cfg.page_size, b,
                               max_pages=cfg.max_pages)
            self.caches = model.init_paged_caches(
                b, cfg.pool_pages, cfg.page_size, cfg.dtype)
            self._join = jit_paged_join(model, cfg, eos_id=eos_id)
        else:
            self.pool = None
            self.caches = model.init_caches(b, cfg.max_len, cfg.dtype)
            self._join = jit_join(model, cfg, eos_id=eos_id)
        self.prefix: PrefixCache | None = None
        if cfg.prefix_cache:
            from ..configs.base import BlockKind
            if not cfg.paged:
                raise ValueError("prefix_cache requires paged=True "
                                 "(shared pages live in the block pool)")
            if any(s.kind is BlockKind.SSM
                   for s in model.cfg.resolved_segments()):
                raise ValueError(
                    "prefix_cache is attention-only: hybrid SSM models "
                    "cannot resume a recurrent state from cached pages")
            self.prefix = PrefixCache(self.pool)
        if self.telemetry is not None and self.pool is not None:
            # pool-partition gauge: every allocator mutation lands one
            # counter sample in the trace (and the current-state gauges)
            self.pool.gauge_cb = self._on_pool_gauge
        self.tok = jnp.zeros((b, 1), jnp.int32)
        self.lengths = jnp.zeros((b,), jnp.int32)
        self.done = jnp.ones((b,), bool)
        self.remaining = jnp.zeros((b,), jnp.int32)
        self.key = jax.random.key(seed)
        # host mirror of the slot table
        self.slot_rid: list[int | None] = [None] * b
        self.slot_len = [0] * b
        self.slot_budget = [0] * b
        # chunked-prefill state: a slot with pending suffix tokens is
        # PREFILLING (device done-latch frozen); ``slot_filled`` mirrors
        # the device ``lengths`` row = prompt tokens resident so far
        self.slot_pending: list[list[int]] = [[] for _ in range(b)]
        self.slot_prompt: list[list[int] | None] = [None] * b
        self.slot_filled = [0] * b
        self.outputs: dict[int, list[int]] = {}
        self._loops: dict[tuple[int, int | None], object] = {}
        # KV memory accounting, sampled once per decode segment:
        # (live tokens, allocated token capacity, live slots)
        self.kv_samples: list[tuple[int, int, int]] = []
        # skip-ahead aging: times each queued rid has been bypassed
        self._skips: dict[int, int] = {}
        self.admit_order: list[int] = []
        # self-speculation: host mirror of the per-slot token history the
        # device drafter reads (prompt at admission, first token at
        # commit, then synced back from the scan carry each segment)
        self.history = np.zeros((b, cfg.max_len), np.int32)
        # request latency trajectory: wall-clock TTFT (run start -> first
        # sampled token) and time-per-output-token per retired request —
        # the samples themselves live in the registry ("lat.*" hists)
        self._clock0: float | None = None
        self._first_tok_t: dict[int, float] = {}
        # queue-wait trajectory: submit (or preemption) -> admission
        self._submit_t: dict[int, float] = {}
        # optimistic admission / preemption state: per-request priority
        # class (victim policy evicts lowest first), the slot's total
        # token ceiling (prompt + remaining budget + spec window — what
        # on-demand growth may cover), how many committed tokens predate
        # the slot's current admission (a re-preempted slot's resume
        # prompt is slot_prompt + outputs[slot_prior:]), and the rids
        # currently living between preemption and retirement
        self.req_priority: dict[int, int] = {}
        self.slot_max_tokens = [0] * b
        self.slot_prior = [0] * b
        self._resumed: set[int] = set()
        self._preempt_counts: dict[int, int] = {}
        self.preempted_rids: set[int] = set()
        self.preempt_events: list[tuple[int, int, int, str]] = []
        # overload protection: per-request absolute deadline/timeout
        # stamps, terminal cancellations (rid -> reason code), the
        # RETRY_AFTER rejections shed queued work was answered with, the
        # opt-in degradation controller and the always-on progress
        # watchdog (which replaces the old 100k-idle-round guard)
        self._deadline_t: dict[int, float] = {}
        self._timeout_t: dict[int, float] = {}
        self.cancelled: dict[int, str] = {}
        self.rejections: list[dict] = []
        if cfg.watchdog_rounds < 1:
            raise ValueError("watchdog_rounds must be >= 1")
        self.overload = (DegradationController(
            degrade_burn=cfg.overload_degrade_burn,
            shed_burn=cfg.overload_shed_burn,
            degrade_pressure=cfg.overload_degrade_pressure,
            shed_pressure=cfg.overload_shed_pressure,
            up_rounds=cfg.overload_up_rounds,
            down_rounds=cfg.overload_down_rounds)
            if cfg.overload else None)
        self.watchdog = Watchdog(cfg.watchdog_rounds)
        # chaos ``stall_at``: rounds below this bound skip the whole
        # round body (the deterministic livelock the watchdog drills on)
        self._stall_until = 0
        self._max_new = 0
        # keep the host history mirror warm whenever speculation is
        # *configured*, even while the controller has shed it — a
        # re-enabled drafter must read a corpus that covers the tokens
        # plain decode committed in between (wrong drafts only cost
        # acceptance, but a warm mirror keeps them right)
        self._hist_on = cfg.speculate_k is not None
        # scheduling-round counter: the chaos injector keys on it
        self.round = 0

    # ------------------------------------------------------------------
    # legacy counter surface: every accumulated stat is stored in the
    # metrics registry; these read-only views keep the attribute names
    # tests, benches and older callers read (no churn, one store)
    # ------------------------------------------------------------------
    @property
    def prefill_computed(self) -> int:
        return int(self.metrics.value("prefill.computed_tokens"))

    @property
    def prefill_skipped(self) -> int:
        return int(self.metrics.value("prefill.skipped_tokens"))

    @property
    def prefix_admits(self) -> int:
        return int(self.metrics.value("prefix.admits"))

    @property
    def prefix_hits(self) -> int:
        return int(self.metrics.value("prefix.hits"))

    @property
    def chunk_joins(self) -> int:
        return int(self.metrics.value("join.chunk_continuations"))

    @property
    def budget_deferrals(self) -> int:
        return int(self.metrics.value("join.budget_deferrals"))

    @property
    def spec_steps(self) -> int:
        return int(self.metrics.value("spec.steps"))

    @property
    def spec_proposed(self) -> int:
        return int(self.metrics.value("spec.proposed"))

    @property
    def spec_accepted(self) -> int:
        return int(self.metrics.value("spec.accepted"))

    @property
    def spec_emitted(self) -> int:
        return int(self.metrics.value("spec.emitted"))

    @property
    def preemptions(self) -> int:
        return int(self.metrics.value("preempt.count"))

    @property
    def preempted_token_recompute(self) -> int:
        return int(self.metrics.value("preempt.recompute_tokens"))

    @property
    def join_times(self) -> list[float]:
        return self.metrics.samples("join.seconds")

    @property
    def ttfts(self) -> list[float]:
        return self.metrics.samples("lat.ttft_s")

    @property
    def tpots(self) -> list[float]:
        return self.metrics.samples("lat.tpot_s")

    @property
    def queue_waits(self) -> list[float]:
        return self.metrics.samples("lat.queue_wait_s")

    # ------------------------------------------------------------------
    # telemetry plumbing (every call site guards on ``telemetry is None``
    # — tracing off is the default and costs one attribute test per
    # scheduling-round boundary, nothing on jitted paths)
    # ------------------------------------------------------------------
    def _on_pool_gauge(self, **counts) -> None:
        tr = self.telemetry
        if tr is not None:
            tr.pool_gauge(counts)
        for k, v in counts.items():
            self.metrics.set_gauge(f"pool.{k}_pages", v)

    def _trace(self, kind: str, rid: int | None,
               slot: int | None = None, **attrs) -> None:
        tr, fl = self.telemetry, self.flight
        if tr is None and fl is None:
            return
        pages = (len(self.pool.slot_pages(slot))
                 if self.pool is not None and slot is not None else 0)
        free = self.pool.free_pages if self.pool is not None else 0
        pages_held = attrs.pop("pages_held", pages)
        pool_free = attrs.pop("pool_free", free)
        if tr is not None:
            tr.event(kind, rid, round=self.round, slot=slot,
                     pages_held=pages_held, pool_free=pool_free, **attrs)
        if fl is not None:
            fl.event(kind, rid, round=self.round, slot=slot,
                     pages_held=pages_held, pool_free=pool_free, **attrs)

    def _slo_observe(self, metric: str, rid: int, v: float) -> None:
        """Score one observed latency against its configured SLO, per
        priority class.  No-op (beyond the attribute test) when the SLO
        for that metric is unset."""
        slo = (self.cfg.ttft_slo_s if metric == "ttft"
               else self.cfg.tpot_slo_s)
        if slo is None:
            return
        cls = self.req_priority.get(rid, 0)
        self._slo_classes.add(cls)
        self.metrics.inc(f"slo.{metric}_total.c{cls}")
        if v <= slo:
            self.metrics.inc(f"slo.{metric}_met.c{cls}")

    # ------------------------------------------------------------------
    def submit(self, rid: int, prompt: list[int], priority: int = 0,
               deadline_s: float | None = None,
               timeout_s: float | None = None) -> None:
        """Queue a request.  ``priority`` is its SLO class for the
        preemption victim policy — higher values are evicted later
        (ties fall back to most-pages / least-progress).

        ``deadline_s`` is the client's completion deadline, seconds from
        now: the per-round sweep cancels the request (reason
        ``"deadline"``) once the deadline passes *or* once the
        remaining-budget TTFT/TPOT projection says it can no longer be
        met — pages come back immediately instead of at the doomed
        completion.  ``timeout_s`` is a hard lifetime cap (reason
        ``"timeout"``): no projection, only actual expiry.  Deadline
        attainment (``latency_stats()['deadline_attainment']``) scores
        deadline-carrying requests that completed or expired; shed /
        client cancels are excluded (a RETRY_AFTER rejection is a fast
        failure, not a latency violation)."""
        if not prompt:
            raise ValueError("empty prompt")
        now = time.perf_counter()
        if deadline_s is not None:
            if deadline_s < 0:
                raise ValueError("deadline_s must be >= 0")
            self._deadline_t[rid] = now + deadline_s
        if timeout_s is not None:
            if timeout_s < 0:
                raise ValueError("timeout_s must be >= 0")
            self._timeout_t[rid] = now + timeout_s
        self.queue.append((rid, list(prompt)))
        self.req_priority[rid] = priority
        self._submit_t[rid] = now
        self._trace("SUBMIT", rid, prompt_tokens=len(prompt),
                    priority=priority, deadline_s=deadline_s,
                    timeout_s=timeout_s)

    # ------------------------------------------------------------------
    def _spec_live(self) -> int:
        """The speculation window actually in force this round: the
        configured ``spec_k`` unless the degradation controller has shed
        speculation (DEGRADED+).  Shedding is loss-free for tokens —
        speculative and plain greedy decode are bit-identical — it only
        trades the steps-per-token win for smaller verify writes and
        smaller on-demand page growth."""
        if self.overload is not None and self.overload.shed_speculation:
            return 0
        return self.spec_k

    def _effective_chunk(self) -> int | None:
        """The prefill chunk in force this round: halved (page-aligned,
        floored at one page) while the controller is DEGRADED+ — shorter
        joins stall live slots' decode for less at the cost of more
        continuation rounds.  Unchunked configs stay unchunked (the
        controller never *introduces* a feature)."""
        chunk = self.cfg.prefill_chunk
        if (chunk is not None and self.overload is not None
                and self.overload.shrink_chunk):
            ps = self.cfg.page_size
            return max(ps, (chunk // 2) // ps * ps)
        return chunk

    def _loop(self, steps: int, cap: int | None):
        # the spec flag keys the cache too: the controller can shed
        # speculation mid-run, and the spec/plain loops take different
        # carries — a (steps, cap) collision across modes would replay
        # the wrong executable
        keyid = (steps, cap, bool(self._spec_live()))
        if keyid not in self._loops:
            if self._spec_live():
                self._loops[keyid] = jit_spec_decode_loop(
                    self.model, self.cfg, steps=steps, eos_id=self.eos)
            elif self.cfg.paged:
                # cap shapes the page-table slice; the jit keys on it
                self._loops[keyid] = jit_paged_decode_loop(
                    self.model, self.cfg, steps=steps, eos_id=self.eos)
            else:
                self._loops[keyid] = jit_decode_loop(
                    self.model, self.cfg, steps=steps, eos_id=self.eos,
                    kv_cap=cap)
        return self._loops[keyid]

    def _kv_cap(self, steps: int) -> int | None:
        live = [self.slot_len[i] for i, r in enumerate(self.slot_rid)
                if r is not None]
        if not live:
            return None
        cap = _pow2_bucket(max(live) + steps, hi=self.cfg.max_len)
        return None if cap >= self.cfg.max_len else cap

    def _page_cap(self) -> int:
        """Power-of-two bound on the deepest live slot's *allocated* page
        count (allocation covers prompt + budget, so a segment can never
        outgrow it) — the paged analogue of ``_kv_cap``."""
        live = [len(self.pool.slot_pages(i))
                for i, r in enumerate(self.slot_rid) if r is not None]
        if not live:
            return self.cfg.max_pages
        return _pow2_bucket(max(live), lo=2, hi=self.cfg.max_pages)

    def _note_admitted(self, rid: int) -> None:
        """Close the request's queue-wait interval (opened at submit and
        re-opened at each preemption)."""
        t0 = self._submit_t.pop(rid, None)
        if t0 is not None:
            self.metrics.observe("lat.queue_wait_s",
                                 time.perf_counter() - t0)

    # ------------------------------------------------------------------
    def _admit_next(self, slot: int, max_new: int):
        """Pop and reserve the next admissible queued request for ``slot``.

        Paged admission matches the prompt against the prefix cache first:
        matched pages are mapped via ``KVPool.share`` and only suffix +
        budget pages must be free (hit-aware admission).  FIFO blocks on
        the queue head; ``skip-ahead`` scans a bounded lookahead window
        for the first request whose pages fit — charging every bypassed
        request one skip, and never scanning past a request whose skip
        count has aged to ``cfg.admission_max_skips`` (the starvation
        bound).  Returns ``(rid, prompt, matched_tokens)`` or None.
        """
        if not self.queue:
            return None
        if self.pool is None:
            rid, p = self.queue.popleft()
            self.admit_order.append(rid)
            self._note_admitted(rid)
            self._trace("ADMIT", rid, slot=slot, prompt_tokens=len(p))
            return rid, p, 0
        # SHEDDING freezes optimistic slot growth at the source: new
        # admissions revert to worst-case reservation, so they can never
        # demand on-demand pages (and thus preemptions) later — already
        # live optimistic slots still grow as needed (they must, or
        # their verify writes would land outside their tables)
        optimistic = (self.cfg.admission_mode == "optimistic"
                      and not (self.overload is not None
                               and self.overload.freeze_growth))
        window = 1
        if self.cfg.admission == "skip-ahead":
            window = min(len(self.queue), self.cfg.admission_lookahead)
        for qi in range(window):
            rid, p = self.queue[qi]
            # a resume's "prompt" already contains ``prior`` committed
            # tokens, so only the *remaining* budget counts toward its
            # worst case — the total never exceeds the original admission
            prior = (len(self.outputs.get(rid, ()))
                     if rid in self._resumed else 0)
            ceiling = len(p) + (max_new - prior) + self.spec_k
            matched: list[int] = []
            mtoks = 0
            if self.prefix is not None:
                matched, mtoks = self.prefix.match(p)
            # reserve mode admits the worst case up front (the spec
            # window counts: a verify at the budget edge writes K/V up
            # to lengths + spec_k); optimistic mode admits on the
            # prompt's pages only and grows on demand between segments
            admit_tokens = len(p) if optimistic else ceiling
            if not self.pool.can_admit(admit_tokens,
                                       shared_pages=matched):
                if self._skips.get(rid, 0) >= self.cfg.admission_max_skips:
                    # aged out: this blocked request is now a barrier —
                    # nothing may be admitted past it until it fits
                    break
                continue
            del self.queue[qi]
            for prev in range(qi):
                # everything scanned past was blocked: charge one skip
                self._skips[self.queue[prev][0]] = \
                    self._skips.get(self.queue[prev][0], 0) + 1
            self._skips.pop(rid, None)
            self.admit_order.append(rid)
            self._note_admitted(rid)
            self.slot_max_tokens[slot] = ceiling
            total = self.pool.pages_for(admit_tokens)
            if matched:
                # refcounts go above 1 here: the prefix chain is mapped
                # into this slot's table on top of its other references
                self.pool.share(slot, matched)
                if total > len(matched):
                    self.pool.extend(slot, total - len(matched))
            else:
                self.pool.reserve(slot, admit_tokens)
            if self.prefix is not None:
                # register the pages the *first chunk* will have written
                # by the end of this refill round's join, so queue-mates
                # in the same round already match them; later chunks
                # extend the registration as they cover more pages
                # (unchunked: the first chunk is the whole prompt)
                chunk = self._effective_chunk()
                covered = (len(p) if chunk is None
                           else min(len(p), mtoks + chunk))
                self._register_covered(slot, p, covered)
                self.metrics.inc("prefix.admits")
                self.metrics.inc("prefix.hits", int(bool(mtoks)))
            self._trace("ADMIT", rid, slot=slot, prompt_tokens=len(p),
                        matched_tokens=mtoks)
            if rid in self._resumed:
                # recompute-on-resume re-enters through ordinary
                # admission — the RESUME mark pairs with its PREEMPT
                self._trace("RESUME", rid, slot=slot,
                            prior_tokens=len(self.outputs.get(rid, ())))
            return rid, p, mtoks
        return None

    def _register_covered(self, slot: int, prompt: list[int],
                          covered: int) -> None:
        """Insert ``prompt``'s full pages up to ``covered`` resident
        tokens into the radix tree (idempotent for already-registered
        chunks — continuation rounds extend the chain)."""
        ps = self.pool.page_size
        n_full = min(covered, len(prompt)) // ps
        if n_full:
            self.prefix.insert(prompt[:n_full * ps],
                               self.pool.slot_pages(slot)[:n_full])

    def _release_slot(self, slot: int) -> None:
        """Return ``slot``'s pages; registered prefix pages whose refcount
        hits zero park in the evictable cached state, everything else goes
        straight back to the free list."""
        if self.pool is None:
            return
        cacheable = frozenset()
        if self.prefix is not None:
            cacheable = self.prefix.registered_pages(
                self.pool.slot_pages(slot))
        self.pool.release(slot, cacheable=cacheable)
        self.slot_pending[slot] = []
        self.slot_prompt[slot] = None
        self.slot_filled[slot] = 0
        self.slot_prior[slot] = 0
        self.slot_max_tokens[slot] = 0

    # ------------------------------------------------------------------
    # page-level preemption (optimistic admission / chaos slot failure)
    # ------------------------------------------------------------------
    def _preempt_slot(self, slot: int, reason: str = "pressure") -> None:
        """Evict a live slot at a segment boundary: register its covered
        pages in the radix tree (so the resume can shortcut recompute),
        release its pages (unregistered ones park in the pool's preempted
        partition), latch its device row done, and re-queue the request
        at the queue head with prompt = everything committed so far —
        the ordinary chunked-prefill path then recomputes the KV
        bit-exactly (recompute-on-resume)."""
        rid = self.slot_rid[slot]
        if rid is None:
            raise RuntimeError(f"preempt of empty slot {slot}")
        prompt = self.slot_prompt[slot]
        if self.slot_pending[slot]:
            # PREFILLING: no tokens committed under *this* admission yet;
            # the resume replays the same (resume-)prompt from the top
            resident = self.slot_filled[slot]
            resume = list(prompt)
            known = prompt[:resident]
        else:
            # DECODING: resume prompt = this admission's prompt plus the
            # tokens committed since (``slot_prior`` marks the split, so
            # a second preemption never duplicates older outputs).  The
            # last committed token has no KV yet (it is the *input* of
            # the next step), hence ``known`` stops one short.
            out = self.outputs[rid]
            resident = self.slot_len[slot]
            resume = list(prompt) + out[self.slot_prior[slot]:]
            known = resume[:-1]
        if self.prefix is not None and resident:
            # generated-token pages are immutable full pages of real KV:
            # registering them lets the resume *match* its own history
            # and recompute only the page-aligned tail
            self._register_covered(slot, known, resident)
        cacheable = frozenset()
        if self.prefix is not None:
            cacheable = self.prefix.registered_pages(
                self.pool.slot_pages(slot))
        pages_released = len(self.pool.slot_pages(slot))
        self.pool.release(slot, cacheable=cacheable, preempt=True)
        self.slot_rid[slot] = None
        self.slot_pending[slot] = []
        self.slot_prompt[slot] = None
        self.slot_filled[slot] = 0
        self.slot_len[slot] = 0
        self.slot_prior[slot] = 0
        self.slot_max_tokens[slot] = 0
        # freeze the abandoned device row: done-latched rows stop
        # sampling and growing their cache, and their table row is the
        # OOB sentinel after release, so any residual write drops
        self.done = self.done.at[slot].set(True)
        self.remaining = self.remaining.at[slot].set(0)
        self.queue.appendleft((rid, resume))
        self._resumed.add(rid)
        self.preempted_rids.add(rid)
        self.metrics.inc("preempt.count")
        self._trace("PREEMPT", rid, slot=slot, reason=reason,
                    pages_held=pages_released, resident_tokens=resident)
        self._submit_t[rid] = time.perf_counter()   # re-open queue wait
        n = self._preempt_counts[rid] = self._preempt_counts.get(rid, 0) + 1
        if n >= max(1, self.cfg.admission_max_skips):
            # thrash bound: an often-preempted request becomes an
            # admission barrier (the skip-ahead aging mechanism) and the
            # victim policy marks it protected — the pool drains toward
            # it, so it cannot be starved by re-admissions
            self._skips[rid] = self.cfg.admission_max_skips
        self.preempt_events.append((self.round, rid, slot, reason))

    def _pick_victim(self, requester: int | None = None) -> int | None:
        """Deterministic victim policy over live slots: barrier-protected
        last, then lowest priority class, most pages mapped, least decode
        progress, lowest slot id.  A chaos override (if armed) replaces
        the policy for this one decision."""
        cands = [i for i, r in enumerate(self.slot_rid) if r is not None]
        if not cands:
            return None
        if self.chaos is not None:
            v = self.chaos.pick_victim(self, list(cands))
            if v is not None:
                return v
        max_skips = max(1, self.cfg.admission_max_skips)

        def key(i: int):
            rid = self.slot_rid[i]
            protected = self._preempt_counts.get(rid, 0) >= max_skips
            progress = (0 if self.slot_pending[i]
                        else len(self.outputs.get(rid, ())))
            return (protected, self.req_priority.get(rid, 0),
                    -len(self.pool.slot_pages(i)), progress, i)
        return min(cands, key=key)

    def _ensure_decode_pages(self, steps: int) -> None:
        """Optimistic mode: before a decode segment, grow every decoding
        slot's page table to cover the segment's worst-case advance
        (``steps * (spec_k + 1)`` tokens, capped by the slot's total
        budget), preempting victims when the pool cannot cover it.
        Highest-priority slots grow first, so pressure evicts in policy
        order; a slot picked as its own victim simply stops (its demand
        left with it)."""
        if self.pool is None or self.cfg.admission_mode != "optimistic":
            return
        adv = steps * (self._spec_live() + 1)
        order = sorted(
            (i for i, r in enumerate(self.slot_rid)
             if r is not None and not self.slot_pending[i]),
            key=lambda i: (-self.req_priority.get(self.slot_rid[i], 0), i))
        for slot in order:
            if self.slot_rid[slot] is None:
                continue                  # evicted by an earlier grow
            cover = min(self.slot_len[slot] + adv,
                        self.slot_max_tokens[slot])
            need = (self.pool.pages_for(cover)
                    - len(self.pool.slot_pages(slot)))
            if need <= 0:
                continue
            while need > (self.pool.free_pages + self.pool.preempted_pages
                          + self.pool.cached_pages):
                victim = self._pick_victim(requester=slot)
                if victim is None:
                    raise PageError(
                        f"cannot grow slot {slot} by {need} pages: no "
                        "live victim left and the pool cannot cover it")
                self._preempt_slot(victim, reason="pressure")
                if victim == slot:
                    break
            if self.slot_rid[slot] is not None:
                self.pool.extend(slot, need)

    # ------------------------------------------------------------------
    # cancellation: the terminal CANCELLED lifecycle state
    # (QUEUED→CANCELLED and PREFILLING/DECODING→CANCELLED)
    # ------------------------------------------------------------------
    def cancel(self, rid: int, reason: str = "client") -> bool:
        """Cancel a queued or in-flight request.  Mid-flight
        cancellation releases the slot's pages through the ordinary
        ``_release_slot`` path (registered prefix pages park
        evictable-cached — the KV is real and immutable, a later match
        may still use it) and done-latches the device row like a
        preemption, minus the re-queue.  Returns False when the rid is
        not queued or live (already retired or cancelled)."""
        if reason not in CANCEL_REASONS:
            raise ValueError(f"unknown cancel reason {reason!r} "
                             f"(expected one of {CANCEL_REASONS})")
        for qi, (qrid, _) in enumerate(self.queue):
            if qrid == rid:
                del self.queue[qi]
                self._finish_cancel(rid, None, reason)
                return True
        for slot, srid in enumerate(self.slot_rid):
            if srid == rid:
                self._cancel_slot(slot, reason)
                return True
        return False

    def _cancel_slot(self, slot: int, reason: str) -> None:
        rid = self.slot_rid[slot]
        if rid is None:
            raise RuntimeError(f"cancel of empty slot {slot}")
        pages = (len(self.pool.slot_pages(slot))
                 if self.pool is not None else 0)
        if self.prefix is not None and not self.slot_pending[slot]:
            # like a preemption: full pages of committed KV are real and
            # immutable — register them so the cache keeps the benefit
            # of the work the cancelled request already paid for
            out = self.outputs.get(rid, [])
            resume = (list(self.slot_prompt[slot])
                      + out[self.slot_prior[slot]:])
            self._register_covered(slot, resume[:-1] if out else resume,
                                   self.slot_len[slot])
        self._release_slot(slot)
        self.slot_rid[slot] = None
        self.slot_len[slot] = 0
        self.slot_budget[slot] = 0
        # freeze the abandoned device row (same contract as preemption):
        # done-latched rows stop sampling and growing their cache, and
        # the released table row is the OOB sentinel, so residual
        # writes drop
        self.done = self.done.at[slot].set(True)
        self.remaining = self.remaining.at[slot].set(0)
        self._finish_cancel(rid, slot, reason, pages_released=pages)

    def _finish_cancel(self, rid: int, slot: int | None, reason: str,
                       pages_released: int = 0) -> None:
        """Terminal bookkeeping shared by queued and mid-flight
        cancellation: reason ledger, counters, deadline-attainment
        accounting, RETRY_AFTER rejection for sheds, CANCEL trace."""
        self.cancelled[rid] = reason
        self._resumed.discard(rid)
        self._preempt_counts.pop(rid, None)
        self._skips.pop(rid, None)
        self._submit_t.pop(rid, None)
        dl = self._deadline_t.pop(rid, None)
        self._timeout_t.pop(rid, None)
        self.metrics.inc("cancel.count")
        self.metrics.inc(f"cancel.{reason}")
        if dl is not None and reason in ("deadline", "timeout"):
            # an expiry/projection cancel is a scored deadline miss;
            # shed/client cancels leave attainment untouched (the
            # request was answered, not served late)
            self.metrics.inc("deadline.total")
        attrs: dict = {}
        if reason == "shed":
            ra = self.cfg.overload_retry_after_s
            self.rejections.append({"rid": rid, "status": RETRY_AFTER,
                                    "retry_after_s": ra,
                                    "round": self.round})
            attrs["retry_after_s"] = ra
        self._trace("CANCEL", rid, slot=slot, reason=reason,
                    emitted_tokens=len(self.outputs.get(rid, ())),
                    pages_held=pages_released, **attrs)

    def _note_deadline_done(self, rid: int, now: float) -> None:
        """Score a retiring deadline-carrying request: met iff it
        completed at or before its absolute deadline."""
        dl = self._deadline_t.pop(rid, None)
        self._timeout_t.pop(rid, None)
        if dl is None:
            return
        self.metrics.inc("deadline.total")
        if now <= dl:
            self.metrics.inc("deadline.met")

    def _expired(self, rid: int, now: float) -> str | None:
        t = self._timeout_t.get(rid)
        if t is not None and now > t:
            return "timeout"
        d = self._deadline_t.get(rid)
        if d is not None and now > d:
            return "deadline"
        return None

    def _cancel_sweep(self, max_new: int) -> None:
        """Per-round deadline/timeout enforcement: cancel queued and
        live requests whose stamp expired, and deadline-carrying ones
        whose remaining-budget projection (observed TTFT/TPOT means —
        deliberately optimistic, see ``project_finish_s``) can no longer
        meet the deadline.  Runs with or without the degradation
        controller — deadlines are a request property, not a load
        policy."""
        if not self._deadline_t and not self._timeout_t:
            return
        now = time.perf_counter()
        for rid, _ in list(self.queue):
            reason = self._expired(rid, now)
            if reason is None and rid in self._deadline_t:
                prior = (len(self.outputs.get(rid, ()))
                         if rid in self._resumed else 0)
                proj = project_finish_s(self.metrics,
                                        max_new - prior, queued=True)
                if (proj is not None
                        and now + proj > self._deadline_t[rid]):
                    reason = "deadline"
            if reason is not None:
                self.cancel(rid, reason)
        for slot, rid in enumerate(self.slot_rid):
            if rid is None:
                continue
            reason = self._expired(rid, now)
            if (reason is None and rid in self._deadline_t
                    and not self.slot_pending[slot]):
                remaining = max(0, self.slot_budget[slot]
                                - len(self.outputs.get(rid, ())))
                proj = project_finish_s(self.metrics, remaining,
                                        queued=False)
                if (proj is not None
                        and now + proj > self._deadline_t[rid]):
                    reason = "deadline"
            if reason is not None:
                self._cancel_slot(slot, reason)

    # ------------------------------------------------------------------
    # degradation controller + progress watchdog (the observe→act loop)
    # ------------------------------------------------------------------
    def _overload_round(self) -> None:
        """Feed the controller this round's burn/pressure signals, trace
        any ladder transition, and apply the SHEDDING rung (queued-work
        shedding; the other rungs are consulted where the scheduler
        reads ``spec_k`` / ``prefill_chunk`` / admission sizing)."""
        ctl = self.overload
        slo = self.slo_stats()
        burn = max(slo["burn_rate_ttft"], slo["burn_rate_tpot"])
        pressure = self.pool.pressure() if self.pool is not None else 0.0
        prev = ctl.state
        state = ctl.observe(burn=burn, pressure=pressure,
                            queue_depth=len(self.queue),
                            round=self.round)
        if state != prev:
            self.metrics.inc("overload.transitions")
            self._trace("DEGRADE", None, state=state, prev=prev,
                        burn=round(burn, 4),
                        pressure=round(pressure, 4))
        if ctl.shedding:
            self._shed_queued()

    def _shed_queued(self) -> None:
        """SHEDDING's last rung: drain the queue down to
        ``overload_queue_keep`` (default: one slot-table's worth),
        lowest priority class first, latest-submitted first within a
        class, never a preempted resume (its work is already paid for —
        shedding it would waste the recompute and break the preemption
        liveness contract).  Every shed answers with a retryable
        RETRY_AFTER rejection."""
        keep = self.cfg.overload_queue_keep
        keep = self.cfg.batch if keep is None else keep
        while len(self.queue) > keep:
            cands = [(qi, rid) for qi, (rid, _) in enumerate(self.queue)
                     if rid not in self._resumed]
            if not cands:
                break
            qi, rid = min(cands, key=lambda c: (
                self.req_priority.get(c[1], 0), -c[0]))
            del self.queue[qi]
            self._finish_cancel(rid, None, "shed")

    def _progress_fingerprint(self) -> tuple:
        """Monotone progress counters the watchdog compares round over
        round: any join, committed token, retirement, preemption or
        cancellation moves at least one of them."""
        return (self.metrics.count("join.seconds"),
                int(self.metrics.value("preempt.count")),
                int(self.metrics.value("cancel.count")),
                len(self.results),
                sum(len(o) for o in self.outputs.values()))

    def _watchdog_tick(self) -> None:
        """Per-round progress check (replaces the idle-spin guard).  On
        a trip: dump the flight-recorder bundle (the postmortem the old
        RuntimeError never shipped), trace a WATCHDOG instant, and
        force-shed the blocking head — the run finishes minus the shed
        request instead of raising."""
        if not self.watchdog.tick(self._progress_fingerprint()):
            return
        live = sum(1 for r in self.slot_rid if r is not None)
        err = WatchdogStall(
            f"no scheduler progress for {self.cfg.watchdog_rounds} "
            f"rounds at round {self.round}: queue={len(self.queue)} "
            f"live_slots={live} (livelock/stall — shedding the "
            "blocking head instead of raising)")
        self._dump_flight(err)
        self.metrics.inc("watchdog.trips")
        self._trace("WATCHDOG", None,
                    stalled_rounds=self.cfg.watchdog_rounds,
                    queued=len(self.queue), live_slots=live)
        self._force_shed()

    def _force_shed(self) -> None:
        """Shed whatever is blocking the stalled drain: the queue head
        when work is queued (the request admission cannot place), else
        the lowest-priority live slot.  Barrier/priority protections do
        not apply — the alternative is the run never finishing."""
        if self.queue:
            rid, _ = self.queue.popleft()
            self._finish_cancel(rid, None, "shed")
            return
        live = [i for i, r in enumerate(self.slot_rid) if r is not None]
        if live:
            slot = min(live, key=lambda i: (
                self.req_priority.get(self.slot_rid[i], 0), i))
            self._cancel_slot(slot, "shed")

    # ------------------------------------------------------------------
    def _refill(self, max_new: int) -> None:
        chunk = self._effective_chunk()
        round_cap = self.cfg.prefill_round_tokens
        round_used = 0
        # (slot, rid, piece tokens, depth before this piece, commits?)
        take: list[tuple[int, int, list[int], int, bool]] = []
        # 1. PREFILLING slots first: their next chunk rides this join, and
        #    its about-to-be-covered pages are registered *before* the
        #    admission scan so queue-mates can match them (their KV is
        #    written by this very join; scatters precede gathers)
        for slot, rid in enumerate(self.slot_rid):
            if rid is None or not self.slot_pending[slot]:
                continue
            if round_cap is not None and round_used >= round_cap:
                # decode-priority budget: this round already took its
                # prefill tokens — the continuation rides the next round
                self.metrics.inc("join.budget_deferrals")
                continue
            pend = self.slot_pending[slot]
            piece = pend[:chunk] if chunk else list(pend)
            depth = self.slot_filled[slot]
            if self.prefix is not None:
                self._register_covered(slot, self.slot_prompt[slot],
                                       depth + len(piece))
            take.append((slot, rid, piece, depth, len(piece) == len(pend)))
            self.metrics.inc("join.chunk_continuations")
            round_used += len(piece)
        # 2. new admissions into free slots (first chunk of each)
        free = [i for i, r in enumerate(self.slot_rid) if r is None]
        for fi, slot in enumerate(free):
            if not self.queue:
                break
            if round_cap is not None and round_used >= round_cap:
                # every remaining (free slot, queued request) pair is an
                # admission this budget pushed to a later round — count
                # them all so the metric matches the per-slot counting
                # of deferred continuations above
                self.metrics.inc("join.budget_deferrals",
                                 min(len(free) - fi, len(self.queue)))
                break
            cand = self._admit_next(slot, max_new)
            if cand is None:
                break
            rid, p, mtoks = cand
            suffix = p[mtoks:]
            piece = suffix[:chunk] if chunk else suffix
            self.slot_prompt[slot] = p
            self.slot_pending[slot] = suffix     # trimmed after the join
            take.append((slot, rid, piece, mtoks,
                         len(piece) == len(suffix)))
            round_used += len(piece)
            if self._hist_on:
                # the drafter's lookup corpus: the whole prompt is known
                # at admission (chunk continuations re-use this row) —
                # kept warm even while the controller sheds speculation,
                # so a re-enabled drafter reads a correct corpus
                self.history[slot, :len(p)] = p
        if not take:
            return
        t0 = time.perf_counter()
        b = self.cfg.batch
        # the join prefills only each row's uncached suffix piece, so the
        # padded width (and the jit bucket) shrinks with hit depth and is
        # bounded by the chunk size
        width = _pow2_bucket(max(len(piece) for _, _, piece, _, _ in take),
                             lo=8, hi=self.cfg.max_len)
        join_mask = np.zeros((b,), bool)
        commit_mask = np.zeros((b,), bool)
        prompts = np.zeros((b, width), np.int32)
        plens = np.ones((b,), np.int32)
        prefix_lens = np.zeros((b,), np.int32)
        budgets = np.full((b,), max_new, np.int32)
        for slot, rid, piece, depth, commit in take:
            join_mask[slot] = True
            commit_mask[slot] = commit
            prompts[slot, :len(piece)] = piece
            plens[slot] = len(piece)
            prefix_lens[slot] = depth
            self.metrics.inc("prefill.computed_tokens", len(piece))
            self._trace("PREFILL_CHUNK", rid, slot=slot,
                        tokens=len(piece), depth=depth, commit=commit,
                        recompute=rid in self._resumed)
            if rid in self._resumed:
                # prefill spent re-admitting a preempted request — the
                # direct cost of recompute-on-resume
                self.metrics.inc("preempt.recompute_tokens", len(piece))
                # the resume's device budget is only the *remaining*
                # tokens: its prompt already carries the committed ones,
                # so the done-latch must fire at the original total
                prior = len(self.outputs.get(rid, ()))
                if prior:
                    budgets[slot] = max(1, max_new - prior)
        join_args = (self.params, self.caches, self.tok, self.lengths,
                     self.done, self.remaining, jnp.asarray(join_mask),
                     jnp.asarray(prompts), jnp.asarray(plens),
                     jnp.asarray(budgets), self.key)
        if self.pool is not None:
            join_args += (jnp.asarray(self.pool.table),
                          jnp.asarray(prefix_lens),
                          jnp.asarray(commit_mask))
        (self.caches, self.tok, self.lengths, self.done, self.remaining,
         self.key, first) = self._join(*join_args)
        first = np.asarray(first)
        now = time.perf_counter()
        for slot, rid, piece, depth, commit in take:
            new_admission = self.slot_rid[slot] is None
            if new_admission:
                # cached-prefix tokens the join never had to compute
                self.metrics.inc("prefill.skipped_tokens", depth)
            self.slot_filled[slot] = depth + len(piece)
            self.slot_pending[slot] = self.slot_pending[slot][len(piece):]
            self.slot_len[slot] = self.slot_filled[slot]
            if not commit:
                self.slot_rid[slot] = rid         # PREFILLING: occupied,
                self.slot_budget[slot] = max_new  # frozen on device
                continue
            tokv = int(first[slot])
            prev = self.outputs.get(rid) if rid in self._resumed else None
            # ``slot_prior``: committed tokens that predate this
            # admission — a later preemption resumes from slot_prompt +
            # outputs[prior:], never duplicating older tokens
            self.slot_prior[slot] = len(prev) if prev is not None else 0
            if prev is not None:
                prev.append(tokv)                 # resume: keep history
                out = prev
            else:
                out = [tokv]
                self.outputs[rid] = out
            if self._clock0 is not None and rid not in self._first_tok_t:
                # a resumed request keeps its original first-token stamp
                self._first_tok_t[rid] = now
                self.metrics.observe("lat.ttft_s", now - self._clock0)
                self._slo_observe("ttft", rid, now - self._clock0)
                self._trace("FIRST_TOKEN", rid, slot=slot, token=tokv,
                            ttft_s=now - self._clock0)
            if self._hist_on:
                # newest token at position filled: the current token the
                # next verify step's tail n-gram ends on
                self.history[slot, self.slot_filled[slot]] = tokv
            if ((self.eos is not None and tokv == self.eos)
                    or len(out) >= max_new):
                self.results[rid] = out           # retired at commit
                self.slot_rid[slot] = None
                self._resumed.discard(rid)
                self._preempt_counts.pop(rid, None)
                self._note_deadline_done(rid, now)
                tpot = 0.0
                if (self._clock0 is not None and len(out) > 1
                        and rid in self._first_tok_t):
                    tpot = ((now - self._first_tok_t[rid])
                            / (len(out) - 1))
                self._trace("RETIRE", rid, slot=slot, tokens=len(out),
                            tpot_s=tpot)
                self._release_slot(slot)
                if tpot > 0.0:
                    self.metrics.observe("lat.tpot_s", tpot)
                    self._slo_observe("tpot", rid, tpot)
            else:
                self.slot_rid[slot] = rid
                self.slot_budget[slot] = max_new
        t1 = time.perf_counter()
        self.metrics.observe("join.seconds", t1 - t0)
        if self.telemetry is not None:
            self.telemetry.add_span("join", self.round, t0, t1)

    # ------------------------------------------------------------------
    def _collect(self, emitted: np.ndarray) -> None:
        """Drain one segment's emitted block into per-request outputs.

        Plain decode emits [steps, B] (one token per live step);
        speculative decode emits [steps, B, k+1] — each step is a
        PAD-terminated burst of 1..k+1 committed tokens whose length is
        that step's accepted advance.  A PAD ends the *step's* burst, not
        the slot: a live slot keeps committing in later steps, so only
        retirement (EOS/budget) stops the walk early.
        """
        if emitted.ndim == 2:
            emitted = emitted[:, :, None]
        steps, _, width = emitted.shape
        now = time.perf_counter()
        for i, rid in enumerate(self.slot_rid):
            if rid is None:
                continue
            if self.slot_pending[i]:
                # PREFILLING: the device row is done-latched and emits
                # only PADs until its last chunk commits — not a stall
                continue
            out = self.outputs[rid]
            appended = 0
            for t in range(steps):
                burst = 0
                for j in range(width):
                    v = int(emitted[t, i, j])
                    if v == PAD_TOKEN:
                        break
                    out.append(v)
                    burst += 1
                    appended += 1
                    self.slot_len[i] += 1
                    if (self._hist_on and width == 1
                            and self.slot_len[i] < self.cfg.max_len):
                        # plain-loop segment (speculation shed by the
                        # controller, or spec never carried): the device
                        # did not advance the history carry, so mirror
                        # the committed token here — same position
                        # convention as the spec loop (token at the
                        # post-advance length)
                        self.history[i, self.slot_len[i]] = v
                    if ((self.eos is not None and v == self.eos)
                            or len(out) >= self.slot_budget[i]):
                        self.results[rid] = out
                        self.slot_rid[i] = None
                        self._resumed.discard(rid)
                        self._preempt_counts.pop(rid, None)
                        self._note_deadline_done(rid, now)
                        tpot = 0.0
                        if (self._clock0 is not None and len(out) > 1
                                and rid in self._first_tok_t):
                            tpot = ((now - self._first_tok_t[rid])
                                    / (len(out) - 1))
                        self._trace("RETIRE", rid, slot=i,
                                    tokens=len(out), tpot_s=tpot)
                        # exact reclamation at this segment edge: private
                        # pages go back to the free list, registered
                        # prefix pages park evictable-cached for matches
                        self._release_slot(i)
                        if tpot > 0.0:
                            self.metrics.observe("lat.tpot_s", tpot)
                            self._slo_observe("tpot", rid, tpot)
                        break
                if self.spec_k and width > 1 and burst:
                    # one verify step committed ``burst`` tokens: burst-1
                    # drafts were accepted plus the model's bonus token
                    self.metrics.inc("spec.steps")
                    self.metrics.inc("spec.proposed", self.spec_k)
                    self.metrics.inc("spec.accepted", burst - 1)
                    self.metrics.inc("spec.emitted", burst)
                    self._trace("SPEC_COMMIT", rid, slot=i, step=t,
                                committed=burst,
                                accepted_drafts=burst - 1,
                                proposed=self.spec_k)
                if self.slot_rid[i] is None:
                    break
                if burst == 0:
                    # a live slot only emits an empty step once its
                    # device done-latch fired — every later step of this
                    # segment is PAD too (the stall check below still
                    # sees appended == 0 if the latch disagrees with
                    # host bookkeeping)
                    break
            if appended == 0 and self.slot_rid[i] is not None:
                raise RuntimeError(
                    f"slot {i} (request {rid}) stalled: device reports done "
                    "but host bookkeeping thinks it is live")

    # ------------------------------------------------------------------
    def run(self, max_new: int = 16) -> dict[int, list[int]]:
        """Drain the queue: refill slots, run fused decode segments, sync
        emitted tokens every ``cfg.sync_every`` steps."""
        if max_new <= 0:
            while self.queue:
                rid, _ = self.queue.popleft()
                self.results[rid] = []
            return self.results
        steps = max(1, self.cfg.sync_every)
        if self._clock0 is None:
            self._clock0 = time.perf_counter()
        # reject oversized requests up front, before anything is dequeued,
        # so a bad request never drops its queue-mates.  The speculation
        # window counts toward the worst case: a verify step writes K/V
        # (and needs table width) up to position lengths + spec_k.
        window = self.spec_k
        for rid, prompt in self.queue:
            if rid in self._resumed:
                # a resume's prompt carries committed tokens, so the
                # naive formula over-counts; it was validated (and its
                # total never grows) at its original admission
                continue
            if len(prompt) + max_new + window > self.cfg.max_len:
                raise ValueError(
                    f"request {rid}: prompt {len(prompt)} + max_new "
                    f"{max_new}"
                    + (f" + speculation window {window}" if window else "")
                    + f" exceeds max_len {self.cfg.max_len}")
            if (self.pool is not None
                    and self.pool.pages_for(len(prompt) + max_new + window)
                    > min(self.pool.n_pages, self.pool.max_pages)):
                raise ValueError(
                    f"request {rid}: needs "
                    f"{self.pool.pages_for(len(prompt) + max_new + window)}"
                    f" pages, pool holds {self.pool.n_pages} "
                    f"(max {self.pool.max_pages}/slot)")
        self._max_new = max_new
        tr = self.telemetry
        try:
            while self.queue or any(r is not None for r in self.slot_rid):
                self.round += 1
                if self.chaos is not None:
                    if tr is not None:
                        with tr.span("chaos", self.round):
                            self.chaos.on_round(self)
                    else:
                        self.chaos.on_round(self)
                if self.overload is not None:
                    self._overload_round()
                self._cancel_sweep(max_new)
                # progress watchdog (replaces the old idle-spin counter +
                # RuntimeError): *any* kind of stall — admission spin,
                # livelock, chaos stall — trips it after watchdog_rounds
                # rounds with unchanged progress counters, dumps the
                # flight bundle, and sheds the blocking head so the run
                # finishes instead of raising
                self._watchdog_tick()
                if self.round < self._stall_until:
                    continue                      # chaos stall: dead round
                self._refill(max_new)
                if not any(r is not None and not self.slot_pending[i]
                           for i, r in enumerate(self.slot_rid)):
                    # nothing is decoding: if slots are still PREFILLING
                    # (or the queue is waiting on pages) the next refill
                    # round advances their chunks — a decode segment
                    # would only burn a scan on all-done rows
                    if self.queue or any(r is not None
                                         for r in self.slot_rid):
                        continue
                    break
                # optimistic admission: make every decoding slot's page
                # table cover this segment's worst-case advance,
                # preempting on pressure — may evict every decoding slot
                # (chaos holds), in which case the next refill round
                # re-admits from the queue
                self._ensure_decode_pages(steps)
                if not any(r is not None and not self.slot_pending[i]
                           for i, r in enumerate(self.slot_rid)):
                    continue
                self._sample_kv()
                seg_t0 = time.perf_counter() if tr is not None else 0.0
                if self._spec_live():
                    cap = self._page_cap()
                    loop = self._loop(steps, cap)
                    pages = jnp.asarray(self.pool.table[:, :cap])
                    hist = jnp.asarray(self.history)
                    ((self.tok, self.caches, self.lengths, self.done,
                      self.remaining, self.key, hist), emitted) = loop(
                        self.params, self.tok, self.caches, self.lengths,
                        self.done, self.remaining, self.key, hist, pages)
                    # np.array (not asarray): the device export is
                    # read-only and the next join writes prompts into
                    # this mirror
                    self.history = np.array(hist)
                elif self.pool is not None:
                    cap = self._page_cap()
                    loop = self._loop(steps, cap)
                    pages = jnp.asarray(self.pool.table[:, :cap])
                    ((self.tok, self.caches, self.lengths, self.done,
                      self.remaining, self.key), emitted) = loop(
                        self.params, self.tok, self.caches, self.lengths,
                        self.done, self.remaining, self.key, pages)
                else:
                    loop = self._loop(steps, self._kv_cap(steps))
                    ((self.tok, self.caches, self.lengths, self.done,
                      self.remaining, self.key), emitted) = loop(
                        self.params, self.tok, self.caches, self.lengths,
                        self.done, self.remaining, self.key)
                if tr is not None:
                    # block so the segment span measures device wall
                    # time, not dispatch — a tracing-on-only sync (the
                    # off path's sync stays where it always was:
                    # np.asarray below)
                    jax.block_until_ready(emitted)
                    tr.add_span("decode-segment", self.round, seg_t0,
                                time.perf_counter())
                    with tr.span("collect", self.round):
                        self._collect(np.asarray(emitted))
                else:
                    self._collect(np.asarray(emitted))
        except PageError as err:
            # postmortem before the crash propagates: the flight
            # recorder's ring holds the last N lifecycle events leading
            # up to the invariant trip — dump them with the allocator
            # and slot-table state so every CI failure ships its own
            # debugging bundle
            self._dump_flight(err)
            raise
        return self.results

    # ------------------------------------------------------------------
    # flight recorder
    # ------------------------------------------------------------------
    def _dump_flight(self, err: BaseException) -> dict | None:
        """Assemble (and optionally write) the flight-recorder debug
        bundle: the ring buffer's last events, the allocator snapshot,
        the host slot table, the config and the metrics at the moment a
        PageError escaped the run loop.  Stored on
        ``self.last_flight_bundle``; written as JSON when
        ``cfg.flight_path`` (or $REPRO_FLIGHT_PATH) names a file."""
        if self.flight is None:
            return None
        cfg = {k: (v if isinstance(v, (bool, int, float, str, type(None)))
                   else str(v))
               for k, v in dataclasses.asdict(self.cfg).items()}
        bundle = {
            "schema": 1,
            "error": f"{type(err).__name__}: {err}",
            "round": self.round,
            "config": cfg,
            "events": self.flight.tail(),
            "slot_table": {
                "slot_rid": list(self.slot_rid),
                "slot_len": list(self.slot_len),
                "slot_filled": list(self.slot_filled),
                "slot_budget": list(self.slot_budget),
                "slot_prior": list(self.slot_prior),
                "slot_max_tokens": list(self.slot_max_tokens),
                "pending_tokens": [len(p) for p in self.slot_pending]},
            "pool": self.pool.snapshot() if self.pool is not None else None,
            "queue": [[rid, len(p)] for rid, p in self.queue],
            "preempt_events": list(self.preempt_events),
            "metrics": self.metrics.snapshot(),
        }
        self.last_flight_bundle = bundle
        path = self.cfg.flight_path or os.environ.get("REPRO_FLIGHT_PATH")
        if path:
            with open(path, "w") as f:
                json.dump(bundle, f, indent=1)
                f.write("\n")
        return bundle

    # ------------------------------------------------------------------
    # KV memory accounting
    # ------------------------------------------------------------------
    def _sample_kv(self) -> None:
        """Record (live tokens, allocated token capacity, live slots) at a
        segment boundary.  Dense allocates ``batch * max_len`` whether or
        not slots are live; paged allocates only the mapped pages."""
        live = [i for i, r in enumerate(self.slot_rid) if r is not None]
        live_tokens = sum(self.slot_len[i] for i in live)
        if self.pool is not None:
            alloc = self.pool.used_pages * self.pool.page_size
        else:
            alloc = self.cfg.batch * self.cfg.max_len
        self.kv_samples.append((live_tokens, alloc, len(live)))

    def kv_utilization(self) -> dict:
        """Aggregate the per-segment samples: mean/peak KV utilization
        (live tokens / allocated token capacity) and peak concurrency."""
        if not self.kv_samples:
            return {"mean_util": 0.0, "peak_util": 0.0,
                    "peak_live_slots": 0, "samples": 0}
        utils = [lt / cap for lt, cap, _ in self.kv_samples if cap]
        return {"mean_util": sum(utils) / max(1, len(utils)),
                "peak_util": max(utils, default=0.0),
                "peak_live_slots": max(s for _, _, s in self.kv_samples),
                "samples": len(self.kv_samples)}

    def join_stats(self) -> dict:
        """Join-segment latency trajectory: every refill that ran a join
        stalls all live slots' decode for its duration — the number
        chunked prefill exists to bound.  ``chunk_joins`` counts the
        continuation pieces (0 when unchunked); ``budget_deferrals``
        counts prefill pieces pushed to a later round by the
        decode-priority ``prefill_round_tokens`` cap (0 when uncapped)."""
        m = self.metrics
        n = m.count("join.seconds")
        return {"joins": n,
                "chunk_joins": int(m.value("join.chunk_continuations")),
                "budget_deferrals": int(m.value("join.budget_deferrals")),
                "max_join_s": max(m.samples("join.seconds"), default=0.0),
                "mean_join_s": m.sum("join.seconds") / n if n else 0.0}

    def reset_stats(self) -> None:
        """Zero *all* per-wave measurement state.  Benchmarks re-submit
        requests into a *warm* batcher to measure the steady serving
        state (a fresh instance would re-jit its closures and time
        compilation); without this reset the second wave's stats would
        blend with the first's.

        The accumulated stats all live in the metrics registry, so one
        ``metrics.reset()`` clears every counter and histogram — latency
        and queue-wait samples, join times, speculative acceptance,
        preemption/recompute tallies, budget deferrals, prefill/prefix
        accounting (the pre-registry version hand-picked a subset and
        silently missed the rest).  What it deliberately does *not*
        touch is operational state the next wave still depends on:
        ``_resumed`` / ``_preempt_counts`` / ``_submit_t`` (in-flight
        request bookkeeping), ``_skips`` / ``admit_order`` (admission
        history), the slot table, and the round counter (the chaos
        injector keys on it)."""
        self._clock0 = None
        self._first_tok_t.clear()
        self.metrics.reset()
        # pool-partition gauges describe *current* allocator state, but
        # this batcher owns them — clear and immediately re-seed from the
        # live pool, so a gauge from a previous pool geometry can never
        # survive into the next wave's snapshot()
        self.metrics.clear_gauges("pool.")
        if self.pool is not None and self.pool.gauge_cb is not None:
            self.pool._notify()
        self._slo_classes.clear()
        self.kv_samples = []
        self.preempt_events.clear()
        self.preempted_rids.clear()
        # overload measurement state resets with the wave; the deadline/
        # timeout *stamps* are in-flight request bookkeeping and survive
        # (like _resumed / _preempt_counts above)
        self.cancelled.clear()
        self.rejections.clear()
        if self.overload is not None:
            self.overload.reset()

    def spec_stats(self) -> dict:
        """Self-speculation effectiveness: ``acceptance_rate`` = accepted
        drafts / proposed drafts, and ``tokens_per_step`` = committed
        tokens per verify step (1.0 = speculation never helped, k+1 =
        every draft always accepted).  All zeros with speculation off, so
        the dict is reportable either way."""
        m = self.metrics
        steps = int(m.value("spec.steps"))
        proposed = int(m.value("spec.proposed"))
        accepted = int(m.value("spec.accepted"))
        emitted = int(m.value("spec.emitted"))
        return {"enabled": bool(self.spec_k),
                "k": self.spec_k,
                "steps": steps,
                "proposed": proposed,
                "accepted": accepted,
                "acceptance_rate": (accepted / proposed
                                    if proposed else 0.0),
                "tokens_per_step": (emitted / steps
                                    if steps else 0.0)}

    def latency_stats(self) -> dict:
        """Per-request latency trajectory observed at host sync points:
        TTFT (run start -> the join that sampled the request's first
        token), time-per-output-token ((retirement - first token) /
        (tokens - 1), requests with > 1 token), and queue wait (submit —
        or preemption — to admission; a preempted request contributes one
        wait per admission).  Segment syncs quantize all of these —
        serving-level numbers, not kernel timings.  Preemption counters
        ride along so one dict describes what the request latencies paid
        for (percentiles come from the registry's histograms — the one
        ``_pct`` implementation, no per-method sample plumbing)."""
        m = self.metrics
        return {"requests": m.count("lat.ttft_s"),
                "ttft_p50_s": m.percentile("lat.ttft_s", 50),
                "ttft_p95_s": m.percentile("lat.ttft_s", 95),
                "tpot_p50_s": m.percentile("lat.tpot_s", 50),
                "tpot_p95_s": m.percentile("lat.tpot_s", 95),
                "queue_wait_p50_s": m.percentile("lat.queue_wait_s", 50),
                "queue_wait_p95_s": m.percentile("lat.queue_wait_s", 95),
                "preemptions": int(m.value("preempt.count")),
                "preempted_token_recompute":
                    int(m.value("preempt.recompute_tokens")),
                "cancellations": int(m.value("cancel.count")),
                "shed_requests": int(m.value("cancel.shed")),
                "deadline_met": int(m.value("deadline.met")),
                "deadline_total": int(m.value("deadline.total")),
                "deadline_attainment": self._deadline_attainment(),
                "watchdog_trips": int(m.value("watchdog.trips"))}

    def _deadline_attainment(self) -> float:
        """Met/total over deadline-carrying requests that were *scored*:
        retired (met iff on time) or cancelled for deadline/timeout
        (always a miss).  Shed and client cancels are excluded — a
        RETRY_AFTER rejection is a fast answer, not a latency violation.
        Vacuously 1.0 with no deadlines in play."""
        total = int(self.metrics.value("deadline.total"))
        met = int(self.metrics.value("deadline.met"))
        return met / total if total else 1.0

    def overload_stats(self) -> dict:
        """One dict for the overload-protection story: cancellation and
        shed tallies, deadline attainment, watchdog trips, the RETRY_AFTER
        rejection ledger, and the degradation controller's state machine
        (state, time-in-state, transition history, whether it recovered
        to HEALTHY).  Controller-off runs report HEALTHY with zero
        time-in-state, so the dict is reportable either way."""
        m = self.metrics
        if self.overload is not None:
            ctl = self.overload.stats()
        else:
            ctl = {"state": HEALTHY, "recovered_to_healthy": False,
                   "transitions": [],
                   "time_in_state": {s: 0.0 for s in STATES}}
        return {"enabled": self.overload is not None,
                "cancellations": int(m.value("cancel.count")),
                "cancelled_by_reason": {
                    r: int(m.value(f"cancel.{r}")) for r in CANCEL_REASONS},
                "shed_requests": int(m.value("cancel.shed")),
                "deadline_met": int(m.value("deadline.met")),
                "deadline_total": int(m.value("deadline.total")),
                "deadline_attainment": self._deadline_attainment(),
                "watchdog_trips": int(m.value("watchdog.trips")),
                "rejections": list(self.rejections),
                "controller": ctl}

    def slo_stats(self, window: int = 64) -> dict:
        """SLO attainment and burn rate against ``cfg.ttft_slo_s`` /
        ``cfg.tpot_slo_s``.

        * ``slo_attainment`` — overall met/total fraction across both
          metrics and every priority class, always in [0, 1] (vacuously
          1.0 with no SLO configured or no samples yet — "no target" is
          never a violation);
        * ``classes`` — per-priority-class met/total/attainment, so a
          mixed-priority wave shows *which* class is paying for the
          preemptions (victims are picked lowest-priority-first, so
          attainment should be monotone in class under pressure);
        * ``burn_rate_*`` — violating fraction of the last ``window``
          raw samples, normalized by the error budget ``1 - slo_target``
          (1.0 = burning exactly the budget, > 1.0 = on track to miss
          the target) — the windowed view reacts to a regression long
          before the cumulative attainment moves.
        """
        cfg, m = self.cfg, self.metrics
        enabled = (cfg.ttft_slo_s is not None
                   or cfg.tpot_slo_s is not None)
        classes: dict[int, dict] = {}
        met_all = total_all = 0
        for cls in sorted(self._slo_classes):
            row: dict = {}
            for metric in ("ttft", "tpot"):
                tot = int(m.value(f"slo.{metric}_total.c{cls}"))
                met = int(m.value(f"slo.{metric}_met.c{cls}"))
                row[f"{metric}_met"] = met
                row[f"{metric}_total"] = tot
                row[f"{metric}_attainment"] = met / tot if tot else 1.0
                met_all += met
                total_all += tot
            classes[cls] = row
        budget = max(1e-9, 1.0 - cfg.slo_target)
        burn = {}
        for metric, slo in (("ttft", cfg.ttft_slo_s),
                            ("tpot", cfg.tpot_slo_s)):
            if slo is None:
                burn[metric] = 0.0
                continue
            recent = m.samples(f"lat.{metric}_s")[-window:]
            viol = (sum(1 for v in recent if v > slo) / len(recent)
                    if recent else 0.0)
            burn[metric] = viol / budget
        return {"enabled": enabled,
                "ttft_slo_s": cfg.ttft_slo_s,
                "tpot_slo_s": cfg.tpot_slo_s,
                "slo_target": cfg.slo_target,
                "slo_attainment": (met_all / total_all
                                   if total_all else 1.0),
                "classes": classes,
                "window": window,
                "burn_rate_ttft": burn["ttft"],
                "burn_rate_tpot": burn["tpot"]}

    def preempt_stats(self) -> dict:
        """Preemption effectiveness and liveness: how many evictions
        happened, how much prefill was re-spent resuming them, and
        ``recomputed_ok`` — True iff every request that was ever
        preempted has retired with a result (vacuously True with no
        preemptions; the liveness gate pairs it with
        ``preemptions > 0``)."""
        # a preempted-then-cancelled request is accounted for (its pages
        # were released and it reached a terminal state) even though it
        # never produced a result
        ok = all((rid in self.results or rid in self.cancelled)
                 and rid not in self._resumed
                 for rid in self.preempted_rids)
        return {"enabled": self.cfg.admission_mode == "optimistic",
                "preemptions": self.preemptions,
                "preempted_requests": len(self.preempted_rids),
                "recompute_tokens": self.preempted_token_recompute,
                "slot_failures": (self.chaos.slot_failures
                                  if self.chaos is not None else 0),
                "recomputed_ok": ok,
                "events": list(self.preempt_events)}

    def prefix_stats(self) -> dict:
        """Prefix-cache effectiveness: prefill tokens computed vs skipped
        (token hit rate), request-level hits, and cache residency.  With
        the cache off everything lands in ``prefill_computed`` and the
        rates are zero, so the dict is reportable either way."""
        total = self.prefill_computed + self.prefill_skipped
        return {"enabled": self.prefix is not None,
                "prefill_computed": self.prefill_computed,
                "prefill_skipped": self.prefill_skipped,
                "hit_rate": self.prefill_skipped / total if total else 0.0,
                "admits": self.prefix_admits,
                "hits": self.prefix_hits,
                "cached_pages": (self.pool.cached_pages
                                 if self.pool is not None else 0),
                "radix_entries": (self.prefix.n_entries
                                  if self.prefix is not None else 0),
                "evicted_pages": (self.prefix.evicted_pages
                                  if self.prefix is not None else 0)}


# the public serving entry point: the slot scheduler *is* the batcher
Batcher = ContinuousBatcher
