"""Serving: jitted prefill/decode-loop engine + slot-based continuous
batching scheduler."""
from .engine import ServeConfig, jit_decode_loop, jit_decode_step  # noqa: F401
from .scheduler import Batcher, ContinuousBatcher  # noqa: F401
