"""Serving: jitted prefill/decode-loop engine + slot-based continuous
batching scheduler, with dense (per-slot stripe) and paged (block-pool)
KV-cache layouts."""
from .attribution import (RequestAttribution, attribution_report,  # noqa: F401
                          explain)
from .chaos import ChaosInjector  # noqa: F401
from .engine import (ServeConfig, jit_decode_loop,  # noqa: F401
                     jit_decode_step, jit_paged_decode_loop, jit_paged_join)
from .kvpool import KVPool, PageError  # noqa: F401
from .scheduler import Batcher, ContinuousBatcher  # noqa: F401
from .telemetry import MetricsRegistry, Tracer  # noqa: F401
