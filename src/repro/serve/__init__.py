"""Serving: prefill/decode step factories + request batcher."""
