"""Fault-injection chaos harness for the serving stack.

PrIM-style benchmarking (and the paper's own co-design argument) says a
system is characterized by its behavior under resource pressure, not its
happy path.  :mod:`repro.ft.elastic` already applies that to training
(``FailureInjector`` raising at configured steps); this module is the
serving analogue, but the injected faults are ones the scheduler is
expected to *survive*, not crash on:

* **forced pool exhaustion** — :meth:`KVPool.hold` takes free pages out
  of circulation at a configured scheduling round, so optimistic
  admission hits pool pressure (and must preempt) exactly when the test
  wants it to, with the pressure arriving through the real allocator
  path rather than a mock;
* **victim-selection override** — replaces the scheduler's
  (priority, most-pages, least-progress) policy for one decision, so
  tests can force a specific eviction order;
* **simulated slot failure mid-decode** — a live slot's device state is
  declared lost at a configured round; the scheduler treats it exactly
  like a preemption (release pages, re-queue, recompute-on-resume), so
  recovery is the same code path the chaos run is already exercising;
* **forced stall** — suppress *all* scheduler work (no refill, no
  decode, no commits) for K rounds from a configured round, so the
  progress watchdog's trip path (flight-bundle dump + force-shed of the
  blocking head) is exercised deterministically instead of waiting for
  a real livelock;
* **synthetic queue burst** — inject N low-priority requests into the
  queue at a configured round (optionally deadline-stamped), so the
  overload controller's pressure signal and shedding ladder see a
  reproducible 3x-capacity spike mid-drain;
* **per-round invariant checks** — ``KVPool.check()`` (and
  ``PrefixCache.check()`` when the cache is on) at every scheduling
  round, so an invariant violation surfaces at the round it happens
  instead of at drain time.

The injector is deterministic: every action is keyed on the scheduler's
round counter, and everything it did is recorded in ``events`` for
assertions.  It is pure host code — the device never sees it.

Typical test wiring::

    chaos = ChaosInjector(exhaust_at={3: 0}, release_at=(6,),
                          check_invariants=True)
    b = Batcher(model, params, cfg, chaos=chaos)
    ... run ...
    assert chaos.events  # and b.preempt_stats()["preemptions"] > 0
"""
from __future__ import annotations

from typing import Callable, Iterable, Mapping


class ChaosInjector:
    """Deterministic, round-keyed fault injection for the scheduler.

    Parameters
    ----------
    exhaust_at:
        ``{round: keep_free}`` — at the given scheduling round, hold all
        but ``keep_free`` of the pool's free pages (``0`` = drain the
        free list completely).  Holds accumulate until released.
    release_at:
        rounds at which every held page is returned to the free list.
    fail_slot_at:
        ``{round: slot}`` — at the given round, declare the slot's
        device state lost.  ``slot`` may be an int index or ``"deepest"``
        (the live slot with the most resident tokens).  A round whose
        slot is not live records a no-op event instead of failing.
    victim_override:
        ``callable(batcher, candidates) -> slot | None`` consulted before
        the scheduler's victim policy; returning ``None`` falls through
        to the policy.
    stall_at:
        ``{round: k_rounds}`` — from the given round, the scheduler
        skips its entire round body (no refill, no decode segment, no
        commits, no retirements) for ``k_rounds`` consecutive rounds.
        A watchdog whose ``watchdog_rounds`` bound is below ``k_rounds``
        must trip during the stall (the drill the watchdog tests and
        ``scripts/ci.sh`` rely on).
    burst_at:
        ``{round: n_requests}`` — inject ``n_requests`` synthetic
        low-priority (``burst_priority``) requests at the given round,
        each a deterministic short prompt sized to pass the scheduler's
        admission validation, stamped with ``burst_deadline_s`` when
        set.  Synthetic rids start at ``BURST_RID0`` so they can never
        collide with test workloads.
    check_invariants:
        run ``pool.check()`` / ``prefix.check()`` every round.
    """

    BURST_RID0 = 10_000

    def __init__(self, *,
                 exhaust_at: Mapping[int, int] | None = None,
                 release_at: Iterable[int] = (),
                 fail_slot_at: Mapping[int, int | str] | None = None,
                 victim_override: Callable | None = None,
                 stall_at: Mapping[int, int] | None = None,
                 burst_at: Mapping[int, int] | None = None,
                 burst_deadline_s: float | None = None,
                 burst_priority: int = -1,
                 check_invariants: bool = False):
        self.exhaust_at = dict(exhaust_at or {})
        self.release_at = set(release_at)
        self.fail_slot_at = dict(fail_slot_at or {})
        self.victim_override = victim_override
        self.stall_at = dict(stall_at or {})
        self.burst_at = dict(burst_at or {})
        self.burst_deadline_s = burst_deadline_s
        self.burst_priority = burst_priority
        self.check_invariants = check_invariants
        self.events: list[tuple[int, str, int]] = []   # (round, kind, arg)
        self.slot_failures = 0
        self._burst_seq = 0

    # ------------------------------------------------------------------
    def on_round(self, batcher) -> None:
        """Called by the scheduler at the top of every scheduling round
        (``batcher.round`` has already been advanced).  Every fault also
        lands in the batcher's trace (``CHAOS_*`` instants on the
        scheduler track) when telemetry is on, so a trace of a chaos run
        shows the injected cause next to the preemptions it forced."""
        r = batcher.round
        pool = batcher.pool
        tr = getattr(batcher, "telemetry", None)

        def trace(kind, **attrs):
            if tr is not None:
                tr.event(kind, None, round=r,
                         pool_free=pool.free_pages if pool else 0, **attrs)

        if pool is not None and r in self.release_at:
            released = pool.release_held()
            self.events.append((r, "release_held", released))
            trace("CHAOS_RELEASE_HELD", pages=released)
        if pool is not None and r in self.exhaust_at:
            keep = self.exhaust_at[r]
            taken = pool.hold(max(0, pool.free_pages - keep))
            self.events.append((r, "hold", len(taken)))
            trace("CHAOS_HOLD", pages=len(taken), keep_free=keep)
        if r in self.fail_slot_at:
            slot = self._resolve_slot(batcher, self.fail_slot_at[r])
            if slot is None:
                self.events.append((r, "fail_slot_noop", -1))
                trace("CHAOS_SLOT_FAILURE_NOOP")
            else:
                trace("CHAOS_SLOT_FAILURE", slot=slot)
                batcher._preempt_slot(slot, reason="slot-failure")
                self.slot_failures += 1
                self.events.append((r, "fail_slot", slot))
        if r in self.stall_at:
            k = max(1, int(self.stall_at[r]))
            # the scheduler checks ``round < _stall_until`` at the top of
            # each round and skips the whole round body — K dead rounds
            # with zero progress, exactly what the watchdog must catch
            batcher._stall_until = max(batcher._stall_until, r + k)
            self.events.append((r, "stall", k))
            trace("CHAOS_STALL", rounds=k)
        if r in self.burst_at:
            n = int(self.burst_at[r])
            for _ in range(n):
                rid = self.BURST_RID0 + self._burst_seq
                self._burst_seq += 1
                batcher.submit(rid, self._burst_prompt(batcher, rid),
                               priority=self.burst_priority,
                               deadline_s=self.burst_deadline_s)
            self.events.append((r, "burst", n))
            trace("CHAOS_BURST", requests=n)
        if self.check_invariants:
            if pool is not None:
                pool.check()
            if batcher.prefix is not None:
                batcher.prefix.check()

    def pick_victim(self, batcher, candidates: list[int]) -> int | None:
        """Victim-selection override hook: a non-None return replaces the
        scheduler's policy for this one decision."""
        if self.victim_override is None:
            return None
        v = self.victim_override(batcher, candidates)
        if v is not None:
            if v not in candidates:
                raise ValueError(f"chaos victim_override chose slot {v} "
                                 f"not in candidates {candidates}")
            self.events.append((batcher.round, "victim_override", v))
            tr = getattr(batcher, "telemetry", None)
            if tr is not None:
                tr.event("CHAOS_VICTIM_OVERRIDE", None,
                         round=batcher.round, slot=v)
        return v

    def _burst_prompt(self, batcher, rid: int) -> list[int]:
        """Deterministic synthetic prompt sized so the mid-run submit can
        never trip the scheduler's oversize validation (which only runs
        at ``run()`` entry): token ids stay tiny (< any real vocab) and
        the length fits ``max_len`` and the pool's per-slot page bound
        alongside the run's ``max_new`` budget + speculation window."""
        cfg = batcher.cfg
        budget = getattr(batcher, "_max_new", 16) + batcher.spec_k
        cap = cfg.max_len - budget
        if batcher.pool is not None:
            pool = batcher.pool
            cap = min(cap, min(pool.n_pages, pool.max_pages)
                      * pool.page_size - budget)
        plen = max(1, min(cfg.page_size if cfg.paged else 8, cap))
        return [1 + (rid * 7 + j) % 13 for j in range(plen)]

    @staticmethod
    def _resolve_slot(batcher, spec: int | str) -> int | None:
        live = [i for i, rid in enumerate(batcher.slot_rid)
                if rid is not None]
        if not live:
            return None
        if spec == "deepest":
            return max(live, key=lambda i: (batcher.slot_len[i], i))
        return spec if spec in live else None
