"""Per-request latency attribution over a :class:`Tracer` timeline.

PR 7 gave the serving stack raw telemetry — lifecycle events, scheduler
spans, pool gauges — but no layer that turns them into *answers*.  The
paper's whole method is bottleneck attribution (the amenability test
explains *why* a primitive under-delivers on PIM); this module is the
serving-side analogue: it decomposes each request's measured TTFT and
TPOT into named components that sum **exactly** to the measured number,
so "request 17 missed its TTFT target because of 2 preemption
recomputes" is a query, not a guess.

Everything here is pure host-side arithmetic over event/span deltas the
tracer already recorded; no scheduler state is consulted, so a saved
trace attributes the same as a live one.

Component taxonomy
------------------

TTFT window ``[first_token.t - ttft_s, first_token.t]`` — anchored on
the FIRST_TOKEN event's own ``ttft_s`` attribute so the parts sum to
the *measured* TTFT, not a re-derived one:

* ``queue_wait_s``     — time not covered by any admitted interval
  (queued behind admission, or re-queued by a preemption);
* ``prefill_compute_s`` — overlap with join spans of rounds where this
  request took a PREFILL_CHUNK (its own prompt being computed);
* ``preempt_recompute_s`` — the same, for chunks flagged ``recompute``
  (KV being rebuilt after a preemption — pure waste, the paper's
  recompute tax);
* ``chunk_stall_s``    — admitted time spent in *neither* of the above:
  waiting between chunks while other slots decode, other slots' joins,
  collect/host bookkeeping.

TPOT window ``[first_token.t, first_token.t + tpot_s * (tokens - 1)]``
(the RETIRE event carries ``tpot_s``):

* ``decode_segment_s`` — overlap with decode-segment spans while
  admitted (the device actually advancing this slot);
* ``verify_overhead_s`` — the slice of decode time spent computing
  speculative drafts that were *not* committed (from the request's
  SPEC_COMMIT events: ``1 - committed / (proposed + 1)`` of its verify
  work), split out of ``decode_segment_s``;
* ``preempt_recompute_s`` — join-span overlap for recompute chunks
  (a mid-decode preemption re-prefills inside the TPOT window);
* ``requeue_s``        — queued time inside the window (only preempted
  requests have any);
* ``host_sync_s``      — the admitted remainder: joins for *other*
  slots, collect, scheduling bookkeeping between segments.

Both decompositions are exact partitions of their windows — the
``check()`` method (and ``tests/test_attribution.py``) asserts the
components sum to the measured TTFT/TPOT within float tolerance.
"""
from __future__ import annotations

import dataclasses

from .telemetry import Tracer

TTFT_COMPONENTS = ("queue_wait_s", "prefill_compute_s",
                   "preempt_recompute_s", "chunk_stall_s")
TPOT_COMPONENTS = ("decode_segment_s", "verify_overhead_s",
                   "preempt_recompute_s", "requeue_s", "host_sync_s")


def _overlap(a0: float, a1: float, b0: float, b1: float) -> float:
    return max(0.0, min(a1, b1) - max(a0, b0))


def _admitted_intervals(tl: list[dict], t_end: float) -> list[tuple]:
    """[(t0, t1)] intervals during which the request held a slot, from
    its event state machine: ADMIT opens, PREEMPT/RETIRE/CANCEL closes
    (an interval still open at ``t_end`` is clipped there).  CANCEL is
    terminal like RETIRE — a mid-flight cancellation ends the admitted
    interval at the instant its pages were released, so a cancelled
    request's TTFT decomposition partitions exactly like a retired
    one's."""
    out: list[tuple[float, float]] = []
    open_t: float | None = None
    for e in tl:
        if e["kind"] == "ADMIT" and open_t is None:
            open_t = e["t"]
        elif (e["kind"] in ("PREEMPT", "RETIRE", "CANCEL")
                and open_t is not None):
            out.append((open_t, e["t"]))
            open_t = None
    if open_t is not None:
        out.append((open_t, t_end))
    return out


def _spans_by_round(tracer: Tracer, name: str) -> dict[int, list[tuple]]:
    by: dict[int, list[tuple[float, float]]] = {}
    for sp in tracer.spans:
        if sp["name"] == name:
            by.setdefault(sp["round"], []).append((sp["t0"], sp["t1"]))
    return by


def _clipped_overlap(spans: list[tuple], admitted: list[tuple],
                     w0: float, w1: float) -> float:
    """Seconds covered by ``spans`` while admitted, inside the window —
    the triple intersection keeps every component <= the admitted total,
    so the residual terms can never go negative."""
    total = 0.0
    for s0, s1 in spans:
        for a0, a1 in admitted:
            total += _overlap(max(s0, a0), min(s1, a1), w0, w1)
    return total


@dataclasses.dataclass
class RequestAttribution:
    """One request's measured latencies and their exact decompositions.

    ``ttft[c]`` for c in :data:`TTFT_COMPONENTS` sums to ``ttft_s``;
    ``tpot[c]`` for c in :data:`TPOT_COMPONENTS` sums to
    ``tpot_s * (tokens - 1)`` (the request's total decode wall time).
    """

    rid: int
    ttft_s: float
    tpot_s: float
    tokens: int
    preemptions: int
    ttft: dict
    tpot: dict
    # terminal cancellation (None = retired normally): the reason code
    # from the CANCEL event, so a report can split "slow" from "shed"
    cancelled: str | None = None

    @property
    def decode_s(self) -> float:
        return self.tpot_s * max(0, self.tokens - 1)

    def dominant_ttft(self) -> str:
        return max(self.ttft, key=lambda k: self.ttft[k])

    def check(self, tol: float = 1e-6) -> None:
        """Assert the exact-partition contract (used by the tests)."""
        s = sum(self.ttft.values())
        if abs(s - self.ttft_s) > tol * max(1.0, self.ttft_s):
            raise AssertionError(
                f"rid {self.rid}: TTFT components sum {s} != {self.ttft_s}")
        s = sum(self.tpot.values())
        if abs(s - self.decode_s) > tol * max(1.0, self.decode_s):
            raise AssertionError(
                f"rid {self.rid}: TPOT components sum {s} != "
                f"{self.decode_s}")


def explain(tracer: Tracer, rid: int) -> RequestAttribution | None:
    """Decompose one request's TTFT/TPOT from its trace timeline.

    Returns None when the request never produced a first token (still
    in flight, or the trace predates it).  Requires a full tracer (the
    flight recorder's ring has no spans to attribute against).
    """
    tl = tracer.timeline(rid)
    first = next((e for e in tl if e["kind"] == "FIRST_TOKEN"), None)
    if first is None:
        return None
    retire = next((e for e in reversed(tl) if e["kind"] == "RETIRE"), None)
    t_end = tl[-1]["t"]
    admitted = _admitted_intervals(tl, t_end)
    joins = _spans_by_round(tracer, "join")
    segs = _spans_by_round(tracer, "decode-segment")
    chunks = [(e["round"], bool(e.get("recompute", False)))
              for e in tl if e["kind"] == "PREFILL_CHUNK"]

    # ---- TTFT: [t_ft - ttft_s, t_ft], anchored on the measured value
    ttft_s = float(first.get("ttft_s", 0.0))
    w1 = first["t"]
    w0 = w1 - ttft_s
    admitted_s = sum(_overlap(a0, a1, w0, w1) for a0, a1 in admitted)
    prefill_s = recompute_s = 0.0
    for rnd, rec in chunks:
        o = _clipped_overlap(joins.get(rnd, []), admitted, w0, w1)
        if rec:
            recompute_s += o
        else:
            prefill_s += o
    ttft = {"queue_wait_s": ttft_s - admitted_s,
            "prefill_compute_s": prefill_s,
            "preempt_recompute_s": recompute_s,
            "chunk_stall_s": admitted_s - prefill_s - recompute_s}

    # ---- TPOT: [t_ft, t_ft + tpot_s * (tokens - 1)]
    tokens = int(retire["tokens"]) if retire is not None else 0
    tpot_s = float(retire.get("tpot_s", 0.0)) if retire is not None else 0.0
    tpot = {c: 0.0 for c in TPOT_COMPONENTS}
    if tpot_s > 0.0 and tokens > 1:
        d0 = first["t"]
        d1 = d0 + tpot_s * (tokens - 1)
        adm_s = sum(_overlap(a0, a1, d0, d1) for a0, a1 in admitted)
        seg_s = _clipped_overlap(
            [iv for ivs in segs.values() for iv in ivs], admitted, d0, d1)
        rec_s = 0.0
        for rnd, rec in chunks:
            if rec:
                rec_s += _clipped_overlap(joins.get(rnd, []), admitted,
                                          d0, d1)
        # speculative waste: the fraction of verify rows (k drafts + 1
        # bonus per step) that did not commit — carved out of the
        # decode-segment overlap, since the verify *is* the decode step
        commits = [e for e in tl if e["kind"] == "SPEC_COMMIT"]
        waste = 0.0
        rows = sum(int(e.get("proposed", 0)) + 1 for e in commits)
        if rows:
            waste = 1.0 - (sum(int(e["committed"]) for e in commits)
                           / rows)
        verify_s = seg_s * waste
        tpot = {"decode_segment_s": seg_s - verify_s,
                "verify_overhead_s": verify_s,
                "preempt_recompute_s": rec_s,
                "requeue_s": (d1 - d0) - adm_s,
                "host_sync_s": adm_s - seg_s - rec_s}

    cancel = next((e for e in reversed(tl) if e["kind"] == "CANCEL"), None)
    return RequestAttribution(
        rid=rid, ttft_s=ttft_s, tpot_s=tpot_s, tokens=tokens,
        preemptions=sum(1 for e in tl if e["kind"] == "PREEMPT"),
        ttft=ttft, tpot=tpot,
        cancelled=(cancel.get("reason", "client")
                   if cancel is not None else None))


def attribution_report(tracer: Tracer) -> dict:
    """Wave-level roll-up: per-component totals/means/shares across every
    attributable request, ranked so the dominant bottleneck is the first
    thing a reader (or the bench row writer) sees."""
    reqs = [a for a in (explain(tracer, rid) for rid in tracer.rids()
            ) if a is not None]
    report: dict = {"requests": len(reqs),
                    "ttft": {}, "tpot": {},
                    "dominant_ttft_component": None,
                    "dominant_tpot_component": None,
                    "per_request": []}
    if not reqs:
        return report
    for section, comps, total_of in (
            ("ttft", TTFT_COMPONENTS, lambda a: a.ttft_s),
            ("tpot", TPOT_COMPONENTS, lambda a: a.decode_s)):
        grand = sum(total_of(a) for a in reqs)
        for c in comps:
            tot = sum(getattr(a, section)[c] for a in reqs)
            report[section][c] = {
                "total_s": tot,
                "mean_s": tot / len(reqs),
                "share": tot / grand if grand else 0.0}
        ranked = sorted(report[section],
                        key=lambda c: -report[section][c]["total_s"])
        report[f"dominant_{section}_component"] = ranked[0]
    for a in sorted(reqs, key=lambda a: -a.ttft_s):
        report["per_request"].append({
            "rid": a.rid, "ttft_s": a.ttft_s, "tpot_s": a.tpot_s,
            "tokens": a.tokens, "preemptions": a.preemptions,
            "cancelled": a.cancelled,
            "dominant_ttft": a.dominant_ttft(),
            "ttft": dict(a.ttft), "tpot": dict(a.tpot)})
    return report
