"""Shared-prefix radix cache over the paged KV pool.

The paper's co-design lesson is to never spend commands, capacity, or data
movement on work whose result is already resident (§4.2 blocked placement,
§5.1.2 command skipping).  In serving terms the biggest remaining
redundancy after paging (PR 2) is *prompt recomputation*: every request
carrying the same system / few-shot prefix re-prefills and re-stores KV
that already sits, bit-identical, in the page pool.  This module is the
reuse manager that closes that gap.

Structure: a radix tree keyed on **page-aligned token chunks** — each edge
is a full page (``page_size`` tokens) of prompt, each node names the pooled
page holding that chunk's KV.  Sharing granularity is therefore exactly the
pool's allocation granularity:

* only **full, immutable prefix pages** are ever shared.  The first
  partially-filled page of a prompt stays private to its slot, so a shared
  page is never written again and no copy-on-write is needed;
* matching is capped so at least one prompt token is always left as
  suffix — the prefill needs a real token to produce next-token logits.

Lifecycle of a page (see also :mod:`repro.serve.kvpool`):

    free -> mapped (refcount 1) -> registered here at admission
         -> shared (refcount > 1) as later requests match it
         -> evictable cached (refcount 0, radix entry live) at retirement
         -> revived by a new match, or reclaimed (LRU, leaf-first) on
            pool pressure -> free

Eviction is leaf-first in LRU order: a node can only be dropped once it
has no children, so a cached chain is peeled from its deep end and a match
can never dangle mid-chain.  Because a slot always maps its matched chain
contiguously from the root, a mapped (refcount > 0) node never sits below
a cached one, and every cached page is eventually reachable by the
leaf-first walk.

All of this is pure host bookkeeping, O(pages touched) per call — the
device only ever sees the pool's page table.
"""
from __future__ import annotations

import heapq

from .kvpool import KVPool, PageError


class _Node:
    """One full-page chunk of some cached prompt prefix."""
    __slots__ = ("chunk", "page", "children", "parent", "last_use")

    def __init__(self, chunk: tuple[int, ...] | None, page: int | None,
                 parent: "_Node | None", last_use: int):
        self.chunk = chunk
        self.page = page
        self.children: dict[tuple[int, ...], _Node] = {}
        self.parent = parent
        self.last_use = last_use


class PrefixCache:
    """Radix tree of page-aligned prompt chunks -> pooled page ids.

    Registers itself as ``pool.evictor``: when the pool's free list runs
    short, :meth:`evict` reclaims cached pages (LRU, leaf-first) so the
    cache costs zero reserved capacity — it only keeps pages that nothing
    else wants yet.
    """

    def __init__(self, pool: KVPool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root = _Node(None, None, None, 0)
        self._by_page: dict[int, _Node] = {}
        self._clock = 0
        self.evicted_pages = 0
        pool.evictor = self

    # ------------------------------------------------------------------
    # lookup / registration
    # ------------------------------------------------------------------
    def match(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(pages, matched_tokens)``; the match is capped at
        ``(len(tokens) - 1) // page_size`` pages so at least one token is
        always left to prefill.  Touches the matched chain for LRU.
        """
        ps = self.page_size
        cap = max(0, (len(tokens) - 1) // ps)
        node, pages = self.root, []
        for i in range(cap):
            child = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            node = child
            pages.append(child.page)
        if pages:
            self._clock += 1
            t = node
            while t is not self.root:
                t.last_use = self._clock
                t = t.parent
        return pages, len(pages) * ps

    def insert(self, tokens: list[int], pages: list[int]) -> int:
        """Register a prompt's full pages: ``pages[i]`` holds the KV of
        ``tokens[i*ps:(i+1)*ps]``.  Chunks already present keep their
        existing page (the caller's duplicate stays private and is freed
        normally at retirement); returns the number of new entries."""
        ps = self.page_size
        if len(tokens) < len(pages) * ps:
            raise PageError("insert: pages extend past the token prefix")
        self._clock += 1
        node, new = self.root, 0
        for i, page in enumerate(pages):
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                if page in self._by_page:
                    raise PageError(f"page {page} already registered")
                child = _Node(chunk, page, node, self._clock)
                node.children[chunk] = child
                self._by_page[page] = child
                new += 1
            child.last_use = self._clock
            node = child
        return new

    def registered_pages(self, pages: list[int]) -> frozenset[int]:
        """Subset of ``pages`` with a live radix entry — the ones a
        release should park in the evictable cached state."""
        return frozenset(p for p in pages if p in self._by_page)

    @property
    def n_entries(self) -> int:
        return len(self._by_page)

    # ------------------------------------------------------------------
    # eviction (pool pressure)
    # ------------------------------------------------------------------
    def evict(self, n: int) -> int:
        """Reclaim up to ``n`` cached pages to the pool's free list, LRU
        first and leaves only (a freed node may expose its parent as the
        next leaf).  Returns the number actually reclaimed.

        One pass collects the evictable leaves into a min-heap on
        ``last_use``; the cascade pushes a freed node's parent when it
        becomes an evictable leaf — O((c + n) log c) per call instead of
        a full rescan per page.  Nothing touches the LRU clock mid-call,
        so the heap order stays exact."""
        heap = []
        for page in self.pool.cached_page_ids():
            node = self._by_page.get(page)
            if node is not None and not node.children:
                heapq.heappush(heap, (node.last_use, page))
        freed = 0
        while freed < n and heap:
            _, page = heapq.heappop(heap)
            node = self._by_page[page]
            parent = node.parent
            del parent.children[node.chunk]
            del self._by_page[page]
            self.pool.reclaim(page)
            self.evicted_pages += 1
            freed += 1
            if (parent is not self.root and not parent.children
                    and self.pool.is_cached(parent.page)):
                heapq.heappush(heap, (parent.last_use, parent.page))
        return freed

    # ------------------------------------------------------------------
    # invariants
    # ------------------------------------------------------------------
    def check(self) -> None:
        """Radix/pool consistency: the tree and the page index agree, a
        registered page is mapped or cached (never free), and a mapped
        node never sits below a cached one (the leaf-first eviction
        invariant)."""
        pool = self.pool
        seen: set[int] = set()
        stack = [(self.root, False)]
        while stack:
            node, under_cached = stack.pop()
            for chunk, child in node.children.items():
                if child.parent is not node or child.chunk != chunk:
                    raise PageError("radix parent/chunk link broken")
                if self._by_page.get(child.page) is not child:
                    raise PageError(f"page index out of sync for "
                                    f"{child.page}")
                seen.add(child.page)
                cached = pool.is_cached(child.page)
                mapped = int(pool.refcount[child.page]) > 0
                if child.page in pool._preempted or child.page in pool._held:
                    # a preemption must park registered pages as evictable
                    # cached (their KV stays matchable); the preempted /
                    # held partitions are for dead private pages only
                    raise PageError(f"registered page {child.page} is in "
                                    "the preempted/held partition")
                if not (cached or mapped):
                    raise PageError(f"registered page {child.page} is "
                                    "neither mapped nor cached")
                if under_cached and mapped:
                    raise PageError(f"mapped page {child.page} below a "
                                    "cached ancestor")
                stack.append((child, under_cached or cached))
        if seen != set(self._by_page):
            raise PageError("page index holds entries not in the tree")
        for page in pool.cached_page_ids():
            if page not in self._by_page:
                raise PageError(f"cached page {page} has no radix entry")
        pool.check()
