"""Block-pool KV-cache memory manager (host side of the paged subsystem).

The dense slot table (PR 1) gives every slot a ``[max_len]`` KV stripe, so
memory is capped by ``slots x max_len`` whether or not those tokens exist —
retired and short requests strand capacity.  The paper's co-design lesson
(§4.2 blocked placement, §5.1.2 command skipping) is to never spend
commands or capacity on dead data, and PrIM-style studies put placement
management, not compute, at the center of near-memory wins.  The paged
analogue: KV lives in fixed-size **pages** inside one pooled allocation
(``[layers, n_pages, page_size, kv_heads, head_dim]`` per segment, see
:func:`repro.models.transformer.init_paged_caches`); each slot holds an
ordered list of page ids (its **page table**), pages come from a free list,
and retirement returns every page exactly once.

This class is pure host bookkeeping — no jax.  The device sees only the
``table`` array ([slots, max_pages] int32, unallocated entries =
``sentinel`` = ``n_pages``, i.e. one past the pool so scatters through them
drop); the scheduler uploads (a column-slice of) it around each decode
segment.

Prefix-cache lifecycle (PR 3, :mod:`repro.serve.prefixcache`): a page is
born on the free list, mapped into one slot by :meth:`reserve` /
:meth:`extend` (refcount 1), and — if it holds a full, immutable page of
prompt tokens — registered in the radix cache.  Later requests with the
same prompt prefix map the *same* page via :meth:`share`, taking its
refcount above 1; only full page-aligned prefix chunks are ever shared, so
a shared page is never written again (the first partially-filled page of
every prompt stays private — no copy-on-write).  When the last slot
mapping a registered page retires, :meth:`release` parks it in the
**evictable cached** state (refcount 0, not free, ``cacheable`` argument)
instead of freeing it: the KV stays resident for future matches at zero
reserved cost.  A new match revives it straight back to refcount 1
(:meth:`share`), and pool pressure reclaims it (:meth:`reclaim`, driven
LRU/leaf-first by the registered ``evictor``) — so

    free -> mapped (1) -> shared (>1) -> cached (0, evictable) -> free
                                     \\-> revived (1) -> ...

and ``free + mapped + cached`` always partitions the pool exactly.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np


class PageError(RuntimeError):
    """Allocator invariant violation (double free, over-allocation)."""


class KVPool:
    """Free-list page allocator + per-slot page tables.

    ``n_pages`` fixed-size pages of ``page_size`` tokens are shared by
    ``slots`` decode slots, each of which may map at most ``max_pages``
    pages.  All methods are O(pages touched); nothing allocates device
    memory — the pooled KV arrays themselves live in the model caches.
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 max_pages: int | None = None):
        if n_pages <= 0 or page_size <= 0 or slots <= 0:
            raise ValueError("n_pages, page_size and slots must be positive")
        self.n_pages = n_pages
        self.page_size = page_size
        self.slots = slots
        self.max_pages = max_pages if max_pages is not None else n_pages
        self.sentinel = n_pages            # OOB page id: scatters drop
        # LIFO free list: recently freed pages are re-used first (their
        # HBM is warm and the table stays dense at the low ids).
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        # evictable cached pages: refcount 0 but their KV is still live
        # prefix-cache content — reclaimed on pressure via ``evictor``
        self._cached: set[int] = set()
        self.evictor = None                # set by prefixcache.PrefixCache
        self.refcount = np.zeros((n_pages,), np.int32)
        self.table = np.full((slots, self.max_pages), self.sentinel,
                             np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(slots)]

    # ------------------------------------------------------------------
    # capacity queries (the scheduler's admission rule)
    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV rows."""
        return -(-max(0, tokens) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Pages in the evictable cached state (refcount 0, KV resident)."""
        return len(self._cached)

    @property
    def used_pages(self) -> int:
        """Pages mapped by live slots (cached pages are *not* used — they
        cost nothing and are reclaimed on pressure)."""
        return self.n_pages - len(self._free) - len(self._cached)

    def cached_page_ids(self) -> list[int]:
        return sorted(self._cached)

    def is_cached(self, page: int) -> bool:
        return page in self._cached

    def can_admit(self, tokens: int,
                  shared_pages: Iterable[int] = ()) -> bool:
        """Would admitting a ``tokens``-token request succeed, given that
        ``shared_pages`` of its prefix are already resident (mapped or
        cached) and need no fresh allocation?  Cached pages count as
        available — the evictor reclaims them on demand."""
        shared = set(shared_pages)
        total = self.pages_for(tokens)
        if total > self.max_pages:
            return False
        avail = len(self._free) + len(self._cached - shared)
        return total - len(shared) <= avail

    def slot_pages(self, slot: int) -> list[int]:
        return list(self._slot_pages[slot])

    # ------------------------------------------------------------------
    # allocate / share / release
    # ------------------------------------------------------------------
    def _alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages off the free list, evicting cached pages first
        when the list runs short (the prefix cache costs zero capacity)."""
        if n > len(self._free) and self.evictor is not None:
            self.evictor.evict(n - len(self._free))
        if n > len(self._free):
            raise PageError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def reserve(self, slot: int, tokens: int) -> list[int]:
        """Map pages for a ``tokens``-token request onto ``slot``.

        The whole worst case (prompt + budget) is reserved up front, so a
        request can never run out of pages mid-segment; the win over dense
        is that the reservation is ``ceil(tokens / page_size)`` pages, not
        ``max_len``, and it is returned the moment the slot retires.
        """
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} already holds pages")
        if tokens <= 0:
            # a zero-page reservation would leave the slot indistinguishable
            # from unreserved (a second reserve would "succeed") — reject it
            raise PageError(
                f"slot {slot}: zero-token reservation (tokens={tokens})")
        n = self.pages_for(tokens)
        if n > self.max_pages:
            raise PageError(
                f"request needs {n} pages > max_pages {self.max_pages}")
        pages = self._alloc(n)
        for i, p in enumerate(pages):
            self.refcount[p] += 1
            self.table[slot, i] = p
        self._slot_pages[slot] = pages
        return pages

    def share(self, slot: int, pages: list[int]) -> None:
        """Map already-resident ``pages`` (a matched prefix chain, in
        order) into empty ``slot``.  Mapped pages gain a reference
        (refcount goes above 1 — several tables now name the same page);
        cached pages are revived back to refcount 1.  Free pages cannot be
        shared — their KV is gone."""
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} already holds pages")
        if not pages:
            raise PageError(f"slot {slot}: share of zero pages")
        if len(pages) > self.max_pages:
            raise PageError(
                f"shared prefix {len(pages)} pages > max_pages "
                f"{self.max_pages}")
        if len(set(pages)) != len(pages):
            raise PageError("shared prefix repeats a page")
        for p in pages:
            if self.refcount[p] == 0 and p not in self._cached:
                raise PageError(f"page {p} is free, cannot share")
        for i, p in enumerate(pages):
            self._cached.discard(p)
            self.refcount[p] += 1
            self.table[slot, i] = p
        self._slot_pages[slot] = list(pages)

    def extend(self, slot: int, n: int) -> list[int]:
        """Append ``n`` fresh pages after ``slot``'s current mapping — the
        private suffix + budget pages of a request whose prefix came from
        :meth:`share`."""
        if n <= 0:
            raise PageError(f"slot {slot}: zero-page extend (n={n})")
        held = self._slot_pages[slot]
        if len(held) + n > self.max_pages:
            raise PageError(
                f"slot {slot}: {len(held)} + {n} pages > max_pages "
                f"{self.max_pages}")
        pages = self._alloc(n)
        for i, p in enumerate(pages):
            self.refcount[p] += 1
            self.table[slot, len(held) + i] = p
        held.extend(pages)
        return pages

    def release(self, slot: int,
                cacheable: frozenset[int] | set[int] = frozenset()) -> int:
        """Drop ``slot``'s reference on every page it maps; returns the
        count returned to the free list.

        A page re-enters circulation only at refcount zero (prefix sharing
        keeps shared pages alive under their other tables).  Zero-refcount
        pages in ``cacheable`` (i.e. with a live radix entry) park in the
        evictable cached state instead of the free list — resident for
        future matches, reclaimed on pressure.  Releasing an empty slot is
        a no-op, but a page leaving the table twice is a hard error.
        """
        pages = self._slot_pages[slot]
        if not pages:
            return 0
        freed = 0
        for p in pages:
            if self.refcount[p] <= 0:
                raise PageError(f"double free of page {p} (slot {slot})")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                if p in cacheable:
                    self._cached.add(p)
                else:
                    self._free.append(p)
                    freed += 1
        self._slot_pages[slot] = []
        self.table[slot, :] = self.sentinel
        return freed

    def reclaim(self, page: int) -> None:
        """Move an evictable cached page back to the free list (called by
        the prefix cache's evictor once the radix entry is dropped)."""
        if page not in self._cached:
            raise PageError(f"reclaim of non-cached page {page}")
        self._cached.discard(page)
        self._free.append(page)

    # ------------------------------------------------------------------
    # invariants / metrics
    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert global allocator consistency (used by the tests):
        free, mapped and cached pages partition the pool exactly, shared
        pages' refcounts equal the number of tables naming them, and
        cached pages carry no references."""
        counts: dict[int, int] = {}
        for pages in self._slot_pages:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        for p, c in counts.items():
            if self.refcount[p] != c:
                raise PageError(
                    f"page {p} mapped {c}x but refcount {self.refcount[p]}")
        free = set(self._free)
        if len(free) != len(self._free):
            raise PageError("free list contains duplicates")
        if free & counts.keys():
            raise PageError("a page is both free and mapped")
        if self._cached & free:
            raise PageError("a page is both cached and free")
        if self._cached & counts.keys():
            raise PageError("a page is both cached and mapped")
        for p in self._cached:
            if self.refcount[p] != 0:
                raise PageError(
                    f"cached page {p} has refcount {self.refcount[p]}")
        if len(free) + len(counts) + len(self._cached) != self.n_pages:
            raise PageError("free + mapped + cached pages != pool")
        for slot, pages in enumerate(self._slot_pages):
            if list(self.table[slot, :len(pages)]) != pages:
                raise PageError(f"table row {slot} out of sync")
            if not (self.table[slot, len(pages):] == self.sentinel).all():
                raise PageError(f"table row {slot} has stale tail entries")

    def utilization(self, live_tokens: int) -> float:
        """live tokens / token capacity mapped by live slots (1.0 = no
        page waste; prefix sharing can push this *above* 1.0 — several
        slots' live tokens counting one physical page)."""
        cap = self.used_pages * self.page_size
        return live_tokens / cap if cap else 0.0
