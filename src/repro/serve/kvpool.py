"""Block-pool KV-cache memory manager (host side of the paged subsystem).

The dense slot table (PR 1) gives every slot a ``[max_len]`` KV stripe, so
memory is capped by ``slots x max_len`` whether or not those tokens exist —
retired and short requests strand capacity.  The paper's co-design lesson
(§4.2 blocked placement, §5.1.2 command skipping) is to never spend
commands or capacity on dead data, and PrIM-style studies put placement
management, not compute, at the center of near-memory wins.  The paged
analogue: KV lives in fixed-size **pages** inside one pooled allocation
(``[layers, n_pages, page_size, kv_heads, head_dim]`` per segment, see
:func:`repro.models.transformer.init_paged_caches`); each slot holds an
ordered list of page ids (its **page table**), pages come from a free list,
and retirement returns every page exactly once.

This class is pure host bookkeeping — no jax.  The device sees only the
``table`` array ([slots, max_pages] int32, unallocated entries =
``sentinel`` = ``n_pages``, i.e. one past the pool so scatters through them
drop); the scheduler uploads (a column-slice of) it around each decode
segment.

Prefix-cache lifecycle (PR 3, :mod:`repro.serve.prefixcache`): a page is
born on the free list, mapped into one slot by :meth:`reserve` /
:meth:`extend` (refcount 1), and — if it holds a full, immutable page of
prompt tokens — registered in the radix cache.  Later requests with the
same prompt prefix map the *same* page via :meth:`share`, taking its
refcount above 1; only full page-aligned prefix chunks are ever shared, so
a shared page is never written again (the first partially-filled page of
every prompt stays private — no copy-on-write).  When the last slot
mapping a registered page retires, :meth:`release` parks it in the
**evictable cached** state (refcount 0, not free, ``cacheable`` argument)
instead of freeing it: the KV stays resident for future matches at zero
reserved cost.  A new match revives it straight back to refcount 1
(:meth:`share`), and pool pressure reclaims it (:meth:`reclaim`, driven
LRU/leaf-first by the registered ``evictor``) — so

    free -> mapped (1) -> shared (>1) -> cached (0, evictable) -> free
                                     \\-> revived (1) -> ...

and ``free + mapped + cached`` always partitions the pool exactly.

Preemption lifecycle (PR 6, :mod:`repro.serve.scheduler` optimistic
admission): when the scheduler evicts a victim slot under pool pressure,
:meth:`release` with ``preempt=True`` parks the victim's dead private
pages (refcount 0, no radix entry) in the **preempted** partition instead
of the free list.  Their KV is garbage the moment the slot's history is
the only way back (resume recomputes through the chunked-prefill path),
so :meth:`_alloc` reclaims them *before* evicting cached prefix pages —
preempted pages have zero future value, cached ones may still match.  The
partition exists for accounting: ``check()`` proves preemption conserves
pages and refcounts instead of leaking them into the free list untracked.
A fifth **held** partition backs the chaos harness
(:mod:`repro.serve.chaos`): :meth:`hold` takes free pages out of
circulation to force pool pressure at a configured round, and
:meth:`release_held` returns them — so

    free + mapped + cached + preempted + held == n_pages

always, and every non-mapped page carries refcount 0.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np


class PageError(RuntimeError):
    """Allocator invariant violation (double free, over-allocation)."""


class KVPool:
    """Free-list page allocator + per-slot page tables.

    ``n_pages`` fixed-size pages of ``page_size`` tokens are shared by
    ``slots`` decode slots, each of which may map at most ``max_pages``
    pages.  All methods are O(pages touched); nothing allocates device
    memory — the pooled KV arrays themselves live in the model caches.
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 max_pages: int | None = None):
        if n_pages <= 0 or page_size <= 0 or slots <= 0:
            raise ValueError("n_pages, page_size and slots must be positive")
        self.n_pages = n_pages
        self.page_size = page_size
        self.slots = slots
        self.max_pages = max_pages if max_pages is not None else n_pages
        self.sentinel = n_pages            # OOB page id: scatters drop
        # LIFO free list: recently freed pages are re-used first (their
        # HBM is warm and the table stays dense at the low ids).
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        # evictable cached pages: refcount 0 but their KV is still live
        # prefix-cache content — reclaimed on pressure via ``evictor``
        self._cached: set[int] = set()
        # preempted pages: refcount 0, KV dead (the victim resumes by
        # recompute) — first in line for reclamation on pressure
        self._preempted: set[int] = set()
        # held pages: taken out of circulation by the chaos harness to
        # force pool pressure; never allocatable until release_held()
        self._held: set[int] = set()
        self.evictor = None                # set by prefixcache.PrefixCache
        # telemetry gauge hook (set by the scheduler when tracing): called
        # with the partition sizes after every mutating operation.  None
        # (default) costs one attribute test per mutation.
        self.gauge_cb = None
        self.refcount = np.zeros((n_pages,), np.int32)
        self.table = np.full((slots, self.max_pages), self.sentinel,
                             np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(slots)]

    # ------------------------------------------------------------------
    # capacity queries (the scheduler's admission rule)
    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV rows."""
        return -(-max(0, tokens) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Pages in the evictable cached state (refcount 0, KV resident)."""
        return len(self._cached)

    @property
    def preempted_pages(self) -> int:
        """Pages parked by slot preemption (refcount 0, KV dead) —
        reclaimed before anything else on pressure."""
        return len(self._preempted)

    @property
    def held_pages(self) -> int:
        """Pages taken out of circulation by the chaos harness."""
        return len(self._held)

    @property
    def used_pages(self) -> int:
        """Pages mapped by live slots (cached/preempted/held pages are
        *not* used — they hold no live slot's KV)."""
        return (self.n_pages - len(self._free) - len(self._cached)
                - len(self._preempted) - len(self._held))

    def cached_page_ids(self) -> list[int]:
        return sorted(self._cached)

    def pressure(self) -> float:
        """Fraction of the pool no admission could be granted from:
        mapped (live slots' KV) plus chaos-held pages over the total.
        Free, cached and preempted pages all count as *available* — the
        evictor reclaims the latter two on demand — so 1.0 means every
        grantable page is pinned under live work.  This is the pool
        signal the overload DegradationController climbs its ladder on
        (burn rate is the other)."""
        return (self.used_pages + self.held_pages) / self.n_pages

    def is_cached(self, page: int) -> bool:
        return page in self._cached

    def can_admit(self, tokens: int,
                  shared_pages: Iterable[int] = ()) -> bool:
        """Would admitting a ``tokens``-token request succeed, given that
        ``shared_pages`` of its prefix are already resident (mapped or
        cached) and need no fresh allocation?  Cached pages count as
        available — the evictor reclaims them on demand."""
        shared = set(shared_pages)
        total = self.pages_for(tokens)
        if total > self.max_pages:
            return False
        avail = (len(self._free) + len(self._preempted)
                 + len(self._cached - shared))
        return total - len(shared) <= avail

    def slot_pages(self, slot: int) -> list[int]:
        return list(self._slot_pages[slot])

    def _notify(self) -> None:
        """Telemetry gauge: report the partition sizes after a mutation
        (free + mapped + cached + preempted + held == n_pages always —
        the counter track in the trace shows the partition flow)."""
        cb = self.gauge_cb
        if cb is not None:
            cb(free=len(self._free), mapped=self.used_pages,
               cached=len(self._cached), preempted=len(self._preempted),
               held=len(self._held))

    # ------------------------------------------------------------------
    # allocate / share / release
    # ------------------------------------------------------------------
    def _slot_snapshot(self, slot: int) -> str:
        """Debuggability suffix for allocator errors: the slot's page
        table plus the pool's partition totals at the failure point."""
        return (f" [slot {slot} pages={self._slot_pages[slot]}; pool: "
                f"{len(self._free)} free, {self.used_pages} mapped, "
                f"{len(self._cached)} cached, "
                f"{len(self._preempted)} preempted, "
                f"{len(self._held)} held / {self.n_pages}]")

    def _alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages off the free list.  When the list runs short,
        reclaim preempted pages first (their KV is dead — zero future
        value), then evict cached prefix pages (theirs may still match)."""
        while n > len(self._free) and self._preempted:
            self._free.append(min(self._preempted))
            self._preempted.discard(self._free[-1])
        if n > len(self._free) and self.evictor is not None:
            self.evictor.evict(n - len(self._free))
        if n > len(self._free):
            raise PageError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def reserve(self, slot: int, tokens: int) -> list[int]:
        """Map pages for a ``tokens``-token request onto ``slot``.

        The whole worst case (prompt + budget) is reserved up front, so a
        request can never run out of pages mid-segment; the win over dense
        is that the reservation is ``ceil(tokens / page_size)`` pages, not
        ``max_len``, and it is returned the moment the slot retires.
        """
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} already holds pages"
                            + self._slot_snapshot(slot))
        if tokens <= 0:
            # a zero-page reservation would leave the slot indistinguishable
            # from unreserved (a second reserve would "succeed") — reject it
            raise PageError(
                f"slot {slot}: zero-token reservation (tokens={tokens})")
        n = self.pages_for(tokens)
        if n > self.max_pages:
            raise PageError(
                f"request needs {n} pages > max_pages {self.max_pages}"
                + self._slot_snapshot(slot))
        pages = self._alloc(n)
        for i, p in enumerate(pages):
            self.refcount[p] += 1
            self.table[slot, i] = p
        self._slot_pages[slot] = pages
        self._notify()
        return pages

    def share(self, slot: int, pages: list[int]) -> None:
        """Map already-resident ``pages`` (a matched prefix chain, in
        order) into empty ``slot``.  Mapped pages gain a reference
        (refcount goes above 1 — several tables now name the same page);
        cached pages are revived back to refcount 1.  Free pages cannot be
        shared — their KV is gone."""
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} already holds pages"
                            + self._slot_snapshot(slot))
        if not pages:
            raise PageError(f"slot {slot}: share of zero pages")
        if len(pages) > self.max_pages:
            raise PageError(
                f"shared prefix {len(pages)} pages > max_pages "
                f"{self.max_pages}")
        if len(set(pages)) != len(pages):
            raise PageError("shared prefix repeats a page")
        for p in pages:
            if self.refcount[p] == 0 and p not in self._cached:
                raise PageError(f"page {p} is not mapped or cached, "
                                "cannot share" + self._slot_snapshot(slot))
        for i, p in enumerate(pages):
            self._cached.discard(p)
            self.refcount[p] += 1
            self.table[slot, i] = p
        self._slot_pages[slot] = list(pages)
        self._notify()

    def extend(self, slot: int, n: int) -> list[int]:
        """Append ``n`` fresh pages after ``slot``'s current mapping — the
        private suffix + budget pages of a request whose prefix came from
        :meth:`share`."""
        if n <= 0:
            raise PageError(f"slot {slot}: zero-page extend (n={n})")
        held = self._slot_pages[slot]
        if len(held) + n > self.max_pages:
            raise PageError(
                f"slot {slot}: {len(held)} + {n} pages > max_pages "
                f"{self.max_pages}" + self._slot_snapshot(slot))
        pages = self._alloc(n)
        for i, p in enumerate(pages):
            self.refcount[p] += 1
            self.table[slot, len(held) + i] = p
        held.extend(pages)
        self._notify()
        return pages

    def release(self, slot: int,
                cacheable: frozenset[int] | set[int] = frozenset(),
                preempt: bool = False) -> int:
        """Drop ``slot``'s reference on every page it maps; returns the
        count leaving the mapped state under this slot's last reference.

        A page re-enters circulation only at refcount zero (prefix sharing
        keeps shared pages alive under their other tables).  Zero-refcount
        pages in ``cacheable`` (i.e. with a live radix entry) park in the
        evictable cached state instead of the free list — resident for
        future matches, reclaimed on pressure.  With ``preempt`` the
        remaining zero-refcount pages park in the **preempted** partition
        instead of the free list: same allocatability (``_alloc`` reclaims
        them first), but the accounting distinguishes preemption's page
        flow so ``check()`` can prove nothing leaked.  Releasing an empty
        slot is a no-op, but a page leaving the table twice is a hard
        error.
        """
        pages = self._slot_pages[slot]
        if not pages:
            return 0
        freed = 0
        for p in pages:
            if self.refcount[p] <= 0:
                raise PageError(f"double free of page {p} (slot {slot})"
                                + self._slot_snapshot(slot))
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                if p in cacheable:
                    self._cached.add(p)
                elif preempt:
                    self._preempted.add(p)
                    freed += 1
                else:
                    self._free.append(p)
                    freed += 1
        self._slot_pages[slot] = []
        self.table[slot, :] = self.sentinel
        self._notify()
        return freed

    def reclaim(self, page: int) -> None:
        """Move an evictable cached page back to the free list (called by
        the prefix cache's evictor once the radix entry is dropped)."""
        if page not in self._cached:
            raise PageError(f"reclaim of non-cached page {page}")
        self._cached.discard(page)
        self._free.append(page)
        self._notify()

    # ------------------------------------------------------------------
    # chaos / fault-injection hooks (repro.serve.chaos)
    # ------------------------------------------------------------------
    def hold(self, n: int) -> list[int]:
        """Take up to ``n`` *free* pages out of circulation (chaos-forced
        pool pressure).  Only the free list is raided — live slots, the
        prefix cache and the preempted partition are untouched, so the
        pressure arrives exactly as a smaller effective pool would."""
        taken = [self._free.pop() for _ in range(min(n, len(self._free)))]
        self._held.update(taken)
        self._notify()
        return taken

    def release_held(self) -> int:
        """Return every held page to the free list; returns the count."""
        n = len(self._held)
        self._free.extend(sorted(self._held))
        self._held.clear()
        self._notify()
        return n

    # ------------------------------------------------------------------
    # invariants / metrics
    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert global allocator consistency (used by the tests):
        free, mapped, cached, preempted and held pages partition the pool
        exactly, shared pages' refcounts equal the number of tables naming
        them, refcounts are conserved (their total equals the total table
        mappings, and every non-mapped page carries zero), and no page
        sits in two partitions at once."""
        counts: dict[int, int] = {}
        for pages in self._slot_pages:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        for p, c in counts.items():
            if self.refcount[p] != c:
                raise PageError(
                    f"page {p} mapped {c}x but refcount {self.refcount[p]}")
        free = set(self._free)
        if len(free) != len(self._free):
            raise PageError("free list contains duplicates")
        parts = {"free": free, "cached": self._cached,
                 "preempted": self._preempted, "held": self._held}
        names = list(parts)
        for i, a in enumerate(names):
            if parts[a] & counts.keys():
                raise PageError(f"a page is both {a} and mapped")
            for b in names[i + 1:]:
                if parts[a] & parts[b]:
                    raise PageError(f"a page is both {a} and {b}")
            for p in parts[a]:
                if self.refcount[p] != 0:
                    raise PageError(f"{a} page {p} has refcount "
                                    f"{self.refcount[p]}")
        if (len(free) + len(counts) + len(self._cached)
                + len(self._preempted) + len(self._held) != self.n_pages):
            raise PageError(
                "free + mapped + cached + preempted + held pages != pool")
        # refcount conservation: the refcount total is exactly the total
        # number of table mappings (negatives cancelling positives, or a
        # stray count on an unmapped page, would slip the per-page checks
        # above only via a bookkeeping structure they don't look at)
        if (self.refcount < 0).any():
            raise PageError("negative refcount")
        total_refs = int(self.refcount.sum())
        total_maps = sum(len(ps) for ps in self._slot_pages)
        if total_refs != total_maps:
            raise PageError(f"refcount total {total_refs} != "
                            f"{total_maps} table mappings")
        for slot, pages in enumerate(self._slot_pages):
            if list(self.table[slot, :len(pages)]) != pages:
                raise PageError(f"table row {slot} out of sync"
                                + self._slot_snapshot(slot))
            if not (self.table[slot, len(pages):] == self.sentinel).all():
                raise PageError(f"table row {slot} has stale tail entries"
                                + self._slot_snapshot(slot))

    def snapshot(self) -> dict:
        """JSON-serializable allocator state — the pool section of the
        scheduler's flight-recorder bundle (and a debugging aid on its
        own: every partition, every slot's table, every refcount)."""
        return {"n_pages": self.n_pages,
                "page_size": self.page_size,
                "max_pages": self.max_pages,
                "free": sorted(self._free),
                "cached": sorted(self._cached),
                "preempted": sorted(self._preempted),
                "held": sorted(self._held),
                "slot_pages": [list(p) for p in self._slot_pages],
                "refcount": [int(c) for c in self.refcount]}

    def utilization(self, live_tokens: int) -> float:
        """live tokens / token capacity mapped by live slots (1.0 = no
        page waste; prefix sharing can push this *above* 1.0 — several
        slots' live tokens counting one physical page)."""
        cap = self.used_pages * self.page_size
        return live_tokens / cap if cap else 0.0
