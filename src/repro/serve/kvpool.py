"""Block-pool KV-cache memory manager (host side of the paged subsystem).

The dense slot table (PR 1) gives every slot a ``[max_len]`` KV stripe, so
memory is capped by ``slots x max_len`` whether or not those tokens exist —
retired and short requests strand capacity.  The paper's co-design lesson
(§4.2 blocked placement, §5.1.2 command skipping) is to never spend
commands or capacity on dead data, and PrIM-style studies put placement
management, not compute, at the center of near-memory wins.  The paged
analogue: KV lives in fixed-size **pages** inside one pooled allocation
(``[layers, n_pages, page_size, kv_heads, head_dim]`` per segment, see
:func:`repro.models.transformer.init_paged_caches`); each slot holds an
ordered list of page ids (its **page table**), pages come from a free list,
and retirement returns every page exactly once.

This class is pure host bookkeeping — no jax.  The device sees only the
``table`` array ([slots, max_pages] int32, unallocated entries =
``sentinel`` = ``n_pages``, i.e. one past the pool so scatters through them
drop); the scheduler uploads (a column-slice of) it around each decode
segment.  ``refcount`` is carried per page and today is only ever 0/1 —
it is the hook for prefix sharing (ROADMAP), where a shared prompt page
would be mapped into several tables and freed on the last release.
"""
from __future__ import annotations

import numpy as np


class PageError(RuntimeError):
    """Allocator invariant violation (double free, over-allocation)."""


class KVPool:
    """Free-list page allocator + per-slot page tables.

    ``n_pages`` fixed-size pages of ``page_size`` tokens are shared by
    ``slots`` decode slots, each of which may map at most ``max_pages``
    pages.  All methods are O(pages touched); nothing allocates device
    memory — the pooled KV arrays themselves live in the model caches.
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 max_pages: int | None = None):
        if n_pages <= 0 or page_size <= 0 or slots <= 0:
            raise ValueError("n_pages, page_size and slots must be positive")
        self.n_pages = n_pages
        self.page_size = page_size
        self.slots = slots
        self.max_pages = max_pages if max_pages is not None else n_pages
        self.sentinel = n_pages            # OOB page id: scatters drop
        # LIFO free list: recently freed pages are re-used first (their
        # HBM is warm and the table stays dense at the low ids).
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self.refcount = np.zeros((n_pages,), np.int32)
        self.table = np.full((slots, self.max_pages), self.sentinel,
                             np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(slots)]

    # ------------------------------------------------------------------
    # capacity queries (the scheduler's admission rule)
    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV rows."""
        return -(-max(0, tokens) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def can_admit(self, tokens: int) -> bool:
        """Would ``reserve`` for a ``tokens``-token request succeed?"""
        n = self.pages_for(tokens)
        return n <= min(len(self._free), self.max_pages)

    def slot_pages(self, slot: int) -> list[int]:
        return list(self._slot_pages[slot])

    # ------------------------------------------------------------------
    # allocate / release
    # ------------------------------------------------------------------
    def reserve(self, slot: int, tokens: int) -> list[int]:
        """Map pages for a ``tokens``-token request onto ``slot``.

        The whole worst case (prompt + budget) is reserved up front, so a
        request can never run out of pages mid-segment; the win over dense
        is that the reservation is ``ceil(tokens / page_size)`` pages, not
        ``max_len``, and it is returned the moment the slot retires.
        """
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} already holds pages")
        n = self.pages_for(tokens)
        if n > self.max_pages:
            raise PageError(
                f"request needs {n} pages > max_pages {self.max_pages}")
        if n > len(self._free):
            raise PageError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for i, p in enumerate(pages):
            self.refcount[p] += 1
            self.table[slot, i] = p
        self._slot_pages[slot] = pages
        return pages

    def release(self, slot: int) -> int:
        """Return every page mapped by ``slot``; returns the count freed.

        Each page's refcount drops by one and the page re-enters the free
        list only at zero (prefix sharing keeps shared pages alive).
        Releasing an empty slot is a no-op — but a page leaving the table
        twice is a hard error.
        """
        pages = self._slot_pages[slot]
        if not pages:
            return 0
        freed = 0
        for p in pages:
            if self.refcount[p] <= 0:
                raise PageError(f"double free of page {p} (slot {slot})")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed += 1
        self._slot_pages[slot] = []
        self.table[slot, :] = self.sentinel
        return freed

    # ------------------------------------------------------------------
    # invariants / metrics
    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert global allocator consistency (used by the tests)."""
        counts: dict[int, int] = {}
        for pages in self._slot_pages:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        for p, c in counts.items():
            if self.refcount[p] != c:
                raise PageError(
                    f"page {p} mapped {c}x but refcount {self.refcount[p]}")
        free = set(self._free)
        if len(free) != len(self._free):
            raise PageError("free list contains duplicates")
        if free & counts.keys():
            raise PageError("a page is both free and mapped")
        if len(free) + len(counts) != self.n_pages:
            raise PageError("free list + mapped pages != pool")
        for slot, pages in enumerate(self._slot_pages):
            if list(self.table[slot, :len(pages)]) != pages:
                raise PageError(f"table row {slot} out of sync")
            if not (self.table[slot, len(pages):] == self.sentinel).all():
                raise PageError(f"table row {slot} has stale tail entries")

    def utilization(self, live_tokens: int) -> float:
        """live tokens / allocated token capacity (1.0 = no page waste)."""
        cap = self.used_pages * self.page_size
        return live_tokens / cap if cap else 0.0
