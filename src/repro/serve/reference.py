"""Step-by-step reference decode loop: the oracle for the fused scan.

Runs every request in one padded batch, one ``model.decode_step`` per
token, host-side sampling — the semantics the device-resident scan in
:mod:`repro.serve.engine` must reproduce token-for-token (greedy).  Kept
deliberately simple and schedule-free: per-slot lengths make each row's
output independent of the other rows, so the continuous batcher's refills
must not change any request's tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .engine import ServeConfig, sample_tokens
from ..models.model_zoo import Model


def reference_decode(model: Model, params, cfg: ServeConfig,
                     requests: list[tuple[int, list[int]]], max_new: int,
                     eos_id: int | None = None,
                     seed: int = 0) -> dict[int, list[int]]:
    """Decode ``requests`` [(rid, prompt)] as one batch, step by step.

    Same per-slot semantics as the engine: padded batch prefill with
    per-row last-prompt-position logits, per-slot cache lengths during
    decode, EOS kept then the slot frozen.  Sampling matches
    ``engine.sample_tokens`` with a per-step split of one key (greedy when
    ``cfg.temperature == 0``, where the key is unused).
    """
    b = len(requests)
    width = max(len(p) for _, p in requests)
    toks = np.zeros((b, width), np.int32)
    plens = np.zeros((b,), np.int32)
    for i, (_, p) in enumerate(requests):
        toks[i, :len(p)] = p
        plens[i] = len(p)
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, cfg.max_len, dtype=cfg.dtype,
        last_pos=jnp.asarray(plens - 1))
    key = jax.random.key(seed)
    key, sub = jax.random.split(key)
    tok = sample_tokens(logits[:, -1], sub, cfg.temperature)[:, None]
    lengths = jnp.asarray(plens)
    outs = [[int(tok[i, 0])] for i in range(b)]
    done = [eos_id is not None and outs[i][0] == eos_id or max_new <= 1
            for i in range(b)]
    for _ in range(max_new - 1):
        logits, caches = model.decode_step(params, tok, caches, lengths,
                                           dtype=cfg.dtype)
        key, sub = jax.random.split(key)
        nxt = sample_tokens(logits[:, -1], sub, cfg.temperature)
        nxt_np = np.asarray(nxt)
        new_tok = np.asarray(tok).copy()
        adv = np.zeros((b,), np.int32)
        for i in range(b):
            if done[i]:
                continue
            v = int(nxt_np[i])
            outs[i].append(v)
            new_tok[i, 0] = v
            adv[i] = 1
            if ((eos_id is not None and v == eos_id)
                    or len(outs[i]) >= max_new):
                done[i] = True
        tok = jnp.asarray(new_tok)
        lengths = lengths + jnp.asarray(adv)
        if all(done):
            break
    return {rid: outs[i] for i, (rid, _) in enumerate(requests)}
