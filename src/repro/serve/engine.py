"""Serving engine: jitted prefill + decode steps and a batched scheduler.

``decode_step`` is the paper's regime: one token against a deep KV cache is
a skinny, memory-bandwidth-bound op (op/byte ~= 1-2) — exactly what the
PIM-amenability test flags, and what the decode_attn Pallas kernel and the
roofline's memory term are about.  Caches are donated so decode runs
in-place.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..distributed import sharding as shd
from ..models.model_zoo import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    dtype: Any = jnp.bfloat16
    temperature: float = 0.0     # 0 = greedy


def make_decode_step(model: Model, cfg: ServeConfig):
    def step(params, tokens, caches, cache_len, extra):
        logits, caches = model.decode_step(params, tokens, caches, cache_len,
                                           dtype=cfg.dtype,
                                           extra=extra or None)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches
    return step


def jit_decode_step(model: Model, cfg: ServeConfig, mesh: Mesh,
                    input_specs: dict):
    step = make_decode_step(model, cfg)
    pshard = shd.param_shardings(model.abstract_ptree(), mesh)
    tok_shard = shd.data_shardings(input_specs["tokens"], mesh)
    cache_shard = shd.cache_shardings(input_specs["caches"], mesh)
    extra_shard = shd.data_shardings(input_specs.get("extra", {}), mesh)
    return jax.jit(
        step,
        in_shardings=(pshard, tok_shard, cache_shard,
                      shd.replicated(mesh), extra_shard),
        out_shardings=(tok_shard, cache_shard),
        donate_argnums=(2,))


def make_prefill(model: Model, cfg: ServeConfig):
    def prefill(params, batch):
        return model.prefill(params, batch, cfg.max_len, dtype=cfg.dtype)
    return prefill


class Batcher:
    """Greedy continuous batcher over a fixed decode batch (host-side).

    Requests are (id, prompt tokens); finished slots (EOS or length) are
    refilled from the queue.  This is the host-side loop a serving pod
    runs; the device work is the jitted prefill/decode steps above.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 eos_id: int = 0):
        self.model, self.params, self.cfg = model, params, cfg
        self.eos = eos_id
        self.queue: list[tuple[int, list[int]]] = []
        self.results: dict[int, list[int]] = {}

    def submit(self, rid: int, prompt: list[int]) -> None:
        self.queue.append((rid, prompt))

    def run(self, max_new: int = 16) -> dict[int, list[int]]:
        cfg = self.cfg
        while self.queue:
            batch = [self.queue.pop(0)
                     for _ in range(min(cfg.batch, len(self.queue)))]
            width = max(len(p) for _, p in batch)
            toks = jnp.zeros((cfg.batch, width), jnp.int32)
            for i, (_, p) in enumerate(batch):
                toks = toks.at[i, :len(p)].set(jnp.asarray(p, jnp.int32))
            logits, caches = self.model.prefill(
                self.params, {"tokens": toks}, cfg.max_len, dtype=cfg.dtype)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            outs = [[] for _ in batch]
            length = jnp.asarray(width, jnp.int32)
            for _ in range(max_new):
                for i in range(len(batch)):
                    outs[i].append(int(tok[i, 0]))
                logits, caches = self.model.decode_step(
                    self.params, tok, caches, length, dtype=cfg.dtype)
                tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                    jnp.int32)[:, None]
                length = length + 1
            for (rid, _), out in zip(batch, outs):
                self.results[rid] = out
        return self.results
