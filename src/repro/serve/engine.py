"""Serving engine: jitted prefill/decode steps and the device-resident
multi-token decode loop.

``decode_step`` is the paper's regime: one token against a deep KV cache is
a skinny, memory-bandwidth-bound op (op/byte ~= 1-2) — exactly what the
PIM-amenability test flags, and what the decode_attn Pallas kernel and the
roofline's memory term are about.  The §5 co-design lesson is that
orchestration, not kernel peak, decides delivered speed: a per-token Python
loop spends its time in host dispatch and host argmax, so ``decode_loop``
keeps everything — tokens, caches, per-slot lengths, done flags, sampling —
on device inside one jitted ``lax.scan`` and only syncs to host every
``sync_every`` steps.  Caches are donated throughout, so decode runs
in-place.

The slot-based continuous-batching scheduler that drives this loop lives in
:mod:`repro.serve.scheduler`; ``Batcher`` (the public entry point) is
re-exported from there.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..distributed import sharding as shd
from ..kernels.decode_attn import decode_attn_policy
from ..models.model_zoo import Model

PAD_TOKEN = -1    # emitted-slot sentinel: "slot was already retired"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    dtype: Any = jnp.bfloat16
    temperature: float = 0.0     # 0 = greedy
    sync_every: int = 8          # decode steps per host sync (scan length)
    attn_mode: str = "auto"      # decode attention: "kernel"|"xla"|"auto"
    attn_interpret: bool | None = None   # None -> off on TPU, on elsewhere
    # paged KV cache (repro.serve.kvpool): fixed-size pages in one pooled
    # allocation, per-slot page tables, admission on free-page capacity
    paged: bool = False
    page_size: int = 16          # KV rows per page
    total_pages: int | None = None   # pool size; None -> batch * max pages
    #   (i.e. the same token capacity as the dense slot table)
    # shared-prefix radix cache (repro.serve.prefixcache, needs paged):
    # full prompt pages are registered in a radix tree, later requests map
    # the matched pages via KVPool.share and prefill only their suffix
    prefix_cache: bool = False
    # admission policy: "fifo" keeps strict head-of-line order; the opt-in
    # "skip-ahead" scans up to ``admission_lookahead`` queued requests for
    # the first one whose pages fit when the head does not (higher slot
    # occupancy under mixed prompt sizes, bounded reorder window)
    admission: str = "fifo"
    # admission sizing (needs paged): "reserve" (default) maps the whole
    # worst case (prompt + max_new + speculation window) at admission, so
    # a slot can never run out of pages but the pool runs far under its
    # real capacity whenever outputs finish early.  "optimistic" maps only
    # the prompt's pages at admission and grows each slot's table
    # on demand between decode segments; when growth outruns the pool the
    # scheduler preempts a victim slot (lowest priority, then most pages,
    # then least progress), parks its dead pages in the pool's preempted
    # partition and re-queues it — resume recomputes the KV from the
    # host-mirrored history through the chunked-prefill join path, with
    # prefix-cache hits shortcutting the recompute.  Attention-only (a
    # recurrent state cannot be recomputed from a page-aligned resume).
    admission_mode: str = "reserve"
    admission_lookahead: int = 8
    # skip-ahead aging: a bypassed head's priority grows with every skip;
    # once it has been skipped ``admission_max_skips`` times it becomes a
    # barrier (nothing is admitted past it until it fits), so sustained
    # small-request load cannot starve a big prompt.  0 degenerates
    # skip-ahead to FIFO.
    admission_max_skips: int = 8
    # chunked prefill (needs paged): a joining prompt's uncached suffix is
    # prefilled at most ``prefill_chunk`` tokens per join round, the slot
    # parking in the PREFILLING state (device done-latch frozen) between
    # chunks so live slots' decode segments interleave with the remaining
    # chunks instead of stalling behind one long prompt.  Must be a
    # multiple of ``page_size`` (chunk boundaries then never land inside a
    # shared prefix page); None = whole suffix in one join (PR 3
    # behavior).
    prefill_chunk: int | None = None
    # decode-priority chunk budget: cap the *total* prefill tokens (chunk
    # continuations + new admissions) a single refill round may take, so
    # many PREFILLING slots cannot monopolize a round and starve decode
    # latency.  Admission stops once the cap is reached (the first piece
    # of a round always goes through, so progress is guaranteed); deferred
    # pieces ride the next round and are counted in ``join_stats()``.
    # None (default) keeps the one-chunk-per-slot-per-round behavior.
    prefill_round_tokens: int | None = None
    # self-speculative decoding (needs paged; greedy/attention-only): each
    # decode step drafts ``speculate_k`` candidate tokens from the slot's
    # own prompt+output history (on-device n-gram lookup, see
    # :func:`ngram_propose`) and verifies all k+1 tokens in ONE multi-token
    # paged attention call — the PR 4 flash-prefill kernel at Lq = k+1,
    # unchanged.  Greedy agreement decides the per-slot accepted length;
    # accepted tokens commit, ``lengths`` advances by exactly that many,
    # and the speculative K/V rows past the acceptance point are simply
    # overwritten by the next step's verify (rollback = don't advance).
    # Output is bit-identical to speculate-off greedy decode; only the
    # steps-per-token changes.  ``speculate_ngram`` is the match width of
    # the history lookup.
    speculate_k: int | None = None
    speculate_ngram: int = 2
    # unified telemetry (repro.serve.telemetry): when True the batcher
    # builds a Tracer recording per-request lifecycle events, per-round
    # scheduler spans and pool-partition gauges (exportable as Perfetto
    # trace_event JSON).  Off by default — the off path adds zero work
    # to the jitted closures (all instrumentation sits at host-sync /
    # scheduling-round boundaries, never inside lax.scan).
    telemetry: bool = False
    # SLO monitor (repro.serve.scheduler.slo_stats): per-request latency
    # targets.  None disables the check for that metric (attainment is
    # vacuously 1.0); with a target set, every observed TTFT/TPOT is
    # scored against it per priority class, and ``slo_target`` is the
    # attainment objective the windowed burn rate is normalized by
    # (burn rate 1.0 = violating exactly the error budget, > 1.0 =
    # burning it faster than the target allows).
    ttft_slo_s: float | None = None
    tpot_slo_s: float | None = None
    slo_target: float = 0.9
    # flight recorder: an always-on bounded ring buffer of lifecycle
    # events (cheap enough to run untraced — host dict appends at
    # scheduling-round boundaries, no device syncs, no pool gauge
    # callback).  When a PageError escapes the run loop (pool/prefix
    # invariant trip, allocator exhaustion with no victim), the batcher
    # dumps the last ``flight_events`` events + pool snapshot + slot
    # table + config as a debug bundle (``Batcher.last_flight_bundle``,
    # written to ``flight_path`` / $REPRO_FLIGHT_PATH when set) before
    # re-raising — every CI failure ships its own postmortem.
    flight_recorder: bool = True
    flight_events: int = 256
    flight_path: str | None = None
    # overload protection (repro.serve.overload): when True the batcher
    # runs a DegradationController — a hysteresis ladder HEALTHY ->
    # DEGRADED -> SHEDDING driven by the windowed SLO burn rate and the
    # pool-pressure gauge.  DEGRADED sheds speculation and shrinks the
    # prefill chunk; SHEDDING additionally freezes optimistic slot
    # growth (admission reverts to worst-case reservation) and sheds
    # lowest-priority queued work with a retryable RETRY_AFTER
    # rejection.  Degradation changes when/whether work runs, never its
    # tokens — completing requests stay bit-exact.  Deadline/timeout
    # cancellation (submit(deadline_s=..., timeout_s=...)) is always on;
    # the controller is the opt-in *load-shedding* half.
    overload: bool = False
    overload_degrade_burn: float = 1.0   # burn rate that enters DEGRADED
    overload_shed_burn: float = 2.0      # burn rate that enters SHEDDING
    overload_degrade_pressure: float = 0.9   # pool mapped+held fraction
    overload_shed_pressure: float = 1.0      # ... with work still queued
    overload_up_rounds: int = 2          # consecutive hot rounds to climb
    overload_down_rounds: int = 4        # consecutive cool rounds to drop
    # SHEDDING drains the queue down to this depth (None -> cfg.batch),
    # lowest-priority / latest-submitted first, never a preempted resume
    overload_queue_keep: int | None = None
    overload_retry_after_s: float = 1.0  # RETRY_AFTER hint on shed
    # progress watchdog (replaces the idle-spin guard): rounds without
    # any join / commit / retirement / preemption / cancellation before
    # the scheduler dumps the flight bundle and force-sheds the blocking
    # head instead of raising
    watchdog_rounds: int = 100_000

    @property
    def max_pages(self) -> int:
        """Page-table width: pages needed for a full-length slot."""
        return -(-self.max_len // self.page_size)

    @property
    def pool_pages(self) -> int:
        return (self.total_pages if self.total_pages is not None
                else self.batch * self.max_pages)


def sample_tokens(logits: jnp.ndarray, key: jax.Array,
                  temperature: float) -> jnp.ndarray:
    """logits [B, V] -> token ids [B] (on device; greedy when T == 0)."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# single-step factories (kept for the dry-run / sharding paths)
# ---------------------------------------------------------------------------

def make_decode_step(model: Model, cfg: ServeConfig):
    def step(params, tokens, caches, cache_len, extra):
        logits, caches = model.decode_step(params, tokens, caches, cache_len,
                                           dtype=cfg.dtype,
                                           extra=extra or None)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches
    return step


def jit_decode_step(model: Model, cfg: ServeConfig, mesh: Mesh,
                    input_specs: dict):
    step = make_decode_step(model, cfg)
    pshard = shd.param_shardings(model.abstract_ptree(), mesh)
    tok_shard = shd.data_shardings(input_specs["tokens"], mesh)
    cache_shard = shd.cache_shardings(input_specs["caches"], mesh)
    extra_shard = shd.data_shardings(input_specs.get("extra", {}), mesh)
    return jax.jit(
        step,
        in_shardings=(pshard, tok_shard, cache_shard,
                      shd.replicated(mesh), extra_shard),
        out_shardings=(tok_shard, cache_shard),
        donate_argnums=(2,))


def make_prefill(model: Model, cfg: ServeConfig):
    def prefill(params, batch):
        return model.prefill(params, batch, cfg.max_len, dtype=cfg.dtype)
    return prefill


# ---------------------------------------------------------------------------
# self-speculative drafting (on-device n-gram / prompt-lookup)
# ---------------------------------------------------------------------------

def ngram_propose(history: jnp.ndarray, lengths: jnp.ndarray, *,
                  k: int, n: int) -> jnp.ndarray:
    """Draft ``k`` continuation tokens per slot from the slot's own token
    history — no draft model, just prompt/output lookup.

    ``history`` [B, S] holds each slot's known tokens (prompt, then every
    committed output token); position ``lengths[b]`` is the current token,
    everything past it is unknown (stale values there are never read).
    The tail ``n``-gram ``history[b, L-n+1 .. L]`` is matched against every
    earlier window; the *most recent* match at start ``p`` gives a period
    estimate ``d = (L - n + 1) - p``, and the draft extrapolates that
    period: predicted position ``L + 1 + t`` copies position
    ``L + 1 + t - d`` (from history when that lands at or below ``L``,
    from an earlier draft of this very call otherwise — the unrolled
    ``t`` loop makes that self-reference static).  No match degenerates
    to ``d = 1``, i.e. repeat-the-current-token.

    Drafts are *proposals only*: the verify pass accepts exactly the
    prefix the model itself would have produced, so a bad draft costs
    speed, never correctness.  Work is O(S * n) integer compares per
    call — noise next to the attention sweep it amortizes.
    """
    b, s = history.shape
    ln = jnp.asarray(lengths, jnp.int32)
    idx = jnp.arange(s)
    match = jnp.ones((b, s), bool)
    for j in range(n):
        shifted = history[:, jnp.minimum(idx + j, s - 1)]          # [B, S]
        tail_j = jnp.take_along_axis(
            history, jnp.clip(ln - n + 1 + j, 0, s - 1)[:, None], axis=1)
        match &= shifted == tail_j
    # candidate starts: window fully below the tail's own window, so the
    # continuation position p + n is a known token (p <= L - n)
    valid = idx[None, :] <= (ln - n)[:, None]
    p = jnp.where(match & valid, idx[None, :], -1).max(axis=1)     # [B]
    d = jnp.where(p >= 0, ln - n + 1 - p, 1).astype(jnp.int32)     # >= 1
    drafts: list[jnp.ndarray] = []
    for t in range(k):
        src = ln + 1 + t - d                                       # [B]
        from_hist = jnp.take_along_axis(
            history, jnp.clip(src, 0, s - 1)[:, None], axis=1)[:, 0]
        if drafts:
            prev = jnp.stack(drafts, axis=1)                       # [B, t]
            from_draft = jnp.take_along_axis(
                prev, jnp.clip(t - d, 0, t - 1)[:, None], axis=1)[:, 0]
        else:
            from_draft = from_hist
        drafts.append(jnp.where(src <= ln, from_hist, from_draft))
    return jnp.stack(drafts, axis=1)                               # [B, k]


# ---------------------------------------------------------------------------
# device-resident decode loop
# ---------------------------------------------------------------------------

def make_decode_loop(model: Model, cfg: ServeConfig, *, steps: int,
                     eos_id: int | None, kv_cap: int | None = None,
                     paged: bool = False, speculate_k: int = 0):
    """Build the fused multi-token decode driver.

    Returns ``loop(params, tok, caches, lengths, done, remaining, key
    [, pages]) -> ((tok, caches, lengths, done, remaining, key), emitted)``
    where ``emitted`` is [steps, B] int32 with PAD_TOKEN in retired slots.
    All state stays on device across the scan; per-slot ``lengths`` drive
    the cache writes, RoPE positions and attention masks, ``done`` freezes
    retired slots (EOS or budget), and sampling happens on device.

    With ``paged`` the loop additionally takes ``pages`` — the [B, P_cap]
    slice of the device page table, held constant across the scan (the
    scheduler reserves every slot's worst case at admission, so a segment
    can never outgrow its pages).  ``P_cap`` then plays ``kv_cap``'s role,
    but the pruning is shape-driven instead of policy-driven: the
    scheduler buckets the deepest live slot's *page count* to a power of
    two and slices the table before the call, so the paged-attention grid
    (and the XLA gather width) is the bucket — dead pages are never
    launched.  One executable is cached per (steps, P_cap) bucket, exactly
    like the dense loop's (steps, kv_cap) keying.

    With ``speculate_k`` = k > 0 (paged + greedy only) each scan step is a
    draft-k **verify** step instead of a one-token decode: the carry grows
    a per-slot token ``history`` [B, max_len], :func:`ngram_propose`
    drafts k candidates from it, and one ``model.decode_step`` call with
    Lq = k+1 tokens (the current token + the drafts, at absolute depth
    ``lengths`` — the PR 4 paged flash-prefill kernel *is* the verify
    kernel) yields greedy outputs for every position.  The accepted length
    is the longest prefix where draft t equals the model's own output at
    position t-1; the step commits ``accepted + 1`` tokens (the +1 is the
    model's bonus token after the last accepted draft), clipped by EOS
    inside the window, the remaining budget and ``max_len``.  ``lengths``
    advances by exactly the committed count — the K/V rows the verify
    wrote past the acceptance point stay stale and are overwritten by the
    next step's verify, whose write window starts at the new ``lengths``
    (rollback by not advancing; admission reserved the k-token overhang).
    ``emitted`` becomes [steps, B, k+1] with PAD past each step's
    committed count.  Token-for-token this is bit-identical to the
    speculate-off greedy loop: every committed token is the argmax the
    plain loop would have produced at that position.
    """
    temp = cfg.temperature
    spec_n = cfg.speculate_ngram

    def loop(params, tok, caches, lengths, done, remaining, key,
             pages=None):
        def body(carry, _):
            tok, caches, lengths, done, remaining, key = carry
            with decode_attn_policy(mode=cfg.attn_mode,
                                    interpret=cfg.attn_interpret,
                                    kv_cap=None if paged else kv_cap):
                logits, caches = model.decode_step(
                    params, tok, caches, lengths, dtype=cfg.dtype,
                    pages=pages)
            key, sub = jax.random.split(key)
            nxt = sample_tokens(logits[:, -1], sub, temp)
            emit = jnp.where(done, PAD_TOKEN, nxt)
            if eos_id is None:
                is_eos = jnp.zeros_like(done)
            else:
                is_eos = nxt == eos_id
            remaining = remaining - jnp.where(done, 0, 1)
            lengths = lengths + jnp.where(done, 0, 1)
            new_done = (done | is_eos | (remaining <= 0)
                        | (lengths >= cfg.max_len))
            tok = jnp.where(done[:, None], tok, nxt[:, None])
            return (tok, caches, lengths, new_done, remaining, key), emit

        carry = (tok, caches, lengths, done, remaining, key)
        carry, emitted = jax.lax.scan(body, carry, None, length=steps)
        return carry, emitted

    if not speculate_k:
        return loop
    if not paged:
        raise ValueError("speculate_k requires the paged loop")
    k = speculate_k

    def spec_loop(params, tok, caches, lengths, done, remaining, key,
                  history, pages):
        def body(carry, _):
            tok, caches, lengths, done, remaining, key, history = carry
            drafts = ngram_propose(history, lengths, k=k, n=spec_n)
            qtok = jnp.concatenate([tok, drafts], axis=1)      # [B, k+1]
            with decode_attn_policy(mode=cfg.attn_mode,
                                    interpret=cfg.attn_interpret):
                # Lq = k+1 at per-slot depth ``lengths``: K/V scatters at
                # positions lengths..lengths+k, causal attention through
                # the page table — the flash-prefill verify call
                logits, caches = model.decode_step(
                    params, qtok, caches, lengths, dtype=cfg.dtype,
                    pages=pages)
            key, _ = jax.random.split(key)     # greedy: keep key moving
            out = jnp.argmax(logits.astype(jnp.float32),
                             axis=-1).astype(jnp.int32)        # [B, k+1]
            # accepted = longest prefix where the draft matches the
            # model's own greedy output one position earlier; commit the
            # accepted drafts plus the model's bonus token after them
            agree = (drafts == out[:, :-1]).astype(jnp.int32)  # [B, k]
            adv = jnp.cumprod(agree, axis=1).sum(axis=1) + 1   # [B] 1..k+1
            if eos_id is not None:
                hit = out == eos_id
                first_eos = jnp.argmax(hit, axis=1)
                adv = jnp.minimum(adv, jnp.where(hit.any(axis=1),
                                                 first_eos + 1, k + 1))
            adv = jnp.minimum(adv, remaining)              # token budget
            adv = jnp.minimum(adv, cfg.max_len - lengths)  # window cap
            adv = jnp.where(done, 0, adv)
            jidx = jnp.arange(k + 1)[None, :]
            commit = jidx < adv[:, None]                   # [B, k+1]
            emit = jnp.where(commit, out, PAD_TOKEN)
            last = jnp.take_along_axis(
                out, jnp.maximum(adv - 1, 0)[:, None], axis=1)  # [B, 1]
            # committed token j becomes known history at position
            # lengths + 1 + j (position lengths holds the current token);
            # non-committed columns scatter out of bounds and drop
            bi = jnp.arange(out.shape[0])[:, None]
            wpos = jnp.where(commit, lengths[:, None] + 1 + jidx,
                             history.shape[1])
            history = history.at[bi, wpos].set(out, mode="drop")
            if eos_id is None:
                eos_last = jnp.zeros_like(done)
            else:
                # an EOS inside the window truncated adv at itself, so if
                # it was committed at all it is the last committed token
                eos_last = (last[:, 0] == eos_id) & (adv > 0)
            remaining = remaining - adv
            lengths = lengths + adv
            new_done = (done | eos_last | (remaining <= 0)
                        | (lengths >= cfg.max_len))
            tok = jnp.where((adv > 0)[:, None], last, tok)
            return (tok, caches, lengths, new_done, remaining, key,
                    history), emit

        carry = (tok, caches, lengths, done, remaining, key, history)
        carry, emitted = jax.lax.scan(body, carry, None, length=steps)
        return carry, emitted                  # emitted [steps, B, k+1]
    return spec_loop


def jit_decode_loop(model: Model, cfg: ServeConfig, *, steps: int,
                    eos_id: int | None, kv_cap: int | None = None):
    """Jitted decode segment: the caches argument is donated so the KV
    cache is updated in place across the whole scan (the small carry
    arrays — tokens, lengths, flags, key — are copied)."""
    loop = make_decode_loop(model, cfg, steps=steps, eos_id=eos_id,
                            kv_cap=kv_cap)
    return jax.jit(loop, donate_argnums=(2,))


def jit_paged_decode_loop(model: Model, cfg: ServeConfig, *, steps: int,
                          eos_id: int | None):
    """Jitted paged decode segment — :func:`make_decode_loop` with
    ``paged=True`` (the call site passes the sliced page table)."""
    loop = make_decode_loop(model, cfg, steps=steps, eos_id=eos_id,
                            paged=True)
    return jax.jit(loop, donate_argnums=(2,))


def jit_spec_decode_loop(model: Model, cfg: ServeConfig, *, steps: int,
                         eos_id: int | None):
    """Jitted self-speculative verify segment — the paged loop with
    ``speculate_k`` drafts per step; takes ``(..., history, pages)`` and
    returns ``emitted`` [steps, B, k+1] (PAD past each step's committed
    count).  Caches are donated as usual; the history array is tiny
    ([B, max_len] int32) and returned in the carry."""
    loop = make_decode_loop(model, cfg, steps=steps, eos_id=eos_id,
                            paged=True, speculate_k=cfg.speculate_k or 0)
    return jax.jit(loop, donate_argnums=(2,))


def make_join(model: Model, cfg: ServeConfig, *, eos_id: int | None):
    """Build the slot-refill step: batch-prefill the joining prompts (padded
    to one width) and select them into the live slot state.

    ``join_mask`` [B] picks the slots being (re)filled; rows outside the
    mask keep their caches, token, length and flags bit-for-bit (the
    prefill computes for every row, but ``jnp.where`` on the batch axis
    discards the non-joining rows).  Returns the refreshed state plus each
    row's first sampled token.
    """
    temp = cfg.temperature

    def join(params, caches, tok, lengths, done, remaining,
             join_mask, prompts, plens, budgets, key):
        with decode_attn_policy(mode=cfg.attn_mode,
                                interpret=cfg.attn_interpret):
            logits, new_caches = model.prefill(
                params, {"tokens": prompts}, cfg.max_len, dtype=cfg.dtype,
                last_pos=plens - 1)
        key, sub = jax.random.split(key)
        first = sample_tokens(logits[:, -1], sub, temp)
        if eos_id is None:
            is_eos = jnp.zeros_like(join_mask)
        else:
            is_eos = first == eos_id
        rem_new = budgets - 1
        tok = jnp.where(join_mask[:, None], first[:, None], tok)
        lengths = jnp.where(join_mask, plens, lengths)
        remaining = jnp.where(join_mask, rem_new, remaining)
        done = jnp.where(join_mask, is_eos | (rem_new <= 0), done)

        def select(new, old):
            m = join_mask.reshape((1, join_mask.shape[0])
                                  + (1,) * (new.ndim - 2))
            return jnp.where(m, new.astype(old.dtype), old)

        caches = jax.tree_util.tree_map(select, new_caches, caches)
        return caches, tok, lengths, done, remaining, key, first
    return join


def jit_join(model: Model, cfg: ServeConfig, *, eos_id: int | None):
    join = make_join(model, cfg, eos_id=eos_id)
    return jax.jit(join, donate_argnums=(1, 2, 3, 4, 5))


def make_paged_join(model: Model, cfg: ServeConfig, *, eos_id: int | None):
    """Paged slot refill with a suffix-only prefill path.  For *attention*
    segments there is nothing to select afterwards: the batch prefill
    *writes through the page table*, and rows outside ``join_mask`` get an
    all-sentinel table so their scatters drop — occupied slots' pages stay
    bit-for-bit intact inside one shared pooled allocation.  SSM segments
    have per-slot recurrent state, not pages (init_paged_caches keeps them
    dense), so the prefill's recompute of every row must still be masked
    back with the dense join's batch-axis select — only joining rows take
    the fresh state.  ``pages`` is the full-width device page table; only
    its masked copy is handed to the prefill.

    Prefix sharing (repro.serve.prefixcache): ``prompts`` carries only
    each joining row's *uncached suffix* and ``prefix_lens`` [B] its
    cached-prefix depth (0 on a miss or with the cache off — then this is
    exactly the PR 2 full prefill).  The prefill runs at
    ``cache_len=prefix_lens``: suffix K/V scatters land at positions
    ``prefix_len + t`` (page-aligned prefixes mean the shared pages sit
    strictly below every write), RoPE continues at the absolute position,
    and the suffix queries attend *over the already-resident prefix pages*
    through the table gather — the prefix is neither recomputed nor
    restored.  Rows hitting a shared prefix in the same join as the row
    that first prefills it are still exact: per layer the pooled scatter
    precedes the gather, so the writer row's pages are visible to every
    reader row of the same call.

    Chunked prefill adds ``commit_mask`` [B]: the subset of joining rows
    whose prompt *completes* with this call.  Commit rows sample their
    first token and go live exactly as before.  Non-commit rows (a
    mid-prompt chunk) write their K/V and advance ``lengths`` to the new
    filled depth, but keep their token frozen, ``remaining`` at 0 and
    ``done`` latched True — the decode scan then treats them as retired
    slots (no sampling, no cache growth, PAD emissions) until a later
    join's chunk, at ``prefix_lens`` = the depth this one set, commits
    them.  With ``commit_mask == join_mask`` this is bit-for-bit the
    unchunked join.
    """
    from ..configs.base import BlockKind
    temp = cfg.temperature
    sentinel = cfg.pool_pages      # OOB page id (see kvpool.KVPool)
    seg_kinds = [s.kind for s in model.cfg.resolved_segments()]

    def join(params, caches, tok, lengths, done, remaining,
             join_mask, prompts, plens, budgets, key, pages, prefix_lens,
             commit_mask):
        write_tbl = jnp.where(join_mask[:, None], pages, sentinel)
        with decode_attn_policy(mode=cfg.attn_mode,
                                interpret=cfg.attn_interpret):
            logits, new_caches = model.prefill_paged(
                params, {"tokens": prompts}, caches, write_tbl,
                dtype=cfg.dtype, last_pos=plens - 1,
                cache_len=prefix_lens)

        def select(new, old):
            # leaves are [layers, B, ...]: mask on the batch axis
            m = join_mask.reshape((1, join_mask.shape[0])
                                  + (1,) * (new.ndim - 2))
            return jnp.where(m, new.astype(old.dtype), old)

        caches = [jax.tree_util.tree_map(select, nc, oc)
                  if kind is BlockKind.SSM else nc
                  for kind, nc, oc in zip(seg_kinds, new_caches, caches)]
        key, sub = jax.random.split(key)
        first = sample_tokens(logits[:, -1], sub, temp)
        if eos_id is None:
            is_eos = jnp.zeros_like(join_mask)
        else:
            is_eos = first == eos_id
        rem_new = budgets - 1
        tok = jnp.where(commit_mask[:, None], first[:, None], tok)
        lengths = jnp.where(join_mask, prefix_lens + plens, lengths)
        remaining = jnp.where(commit_mask, rem_new,
                              jnp.where(join_mask, 0, remaining))
        done = jnp.where(commit_mask, is_eos | (rem_new <= 0),
                         jnp.where(join_mask, True, done))
        return caches, tok, lengths, done, remaining, key, first
    return join


def jit_paged_join(model: Model, cfg: ServeConfig, *, eos_id: int | None):
    join = make_paged_join(model, cfg, eos_id=eos_id)
    return jax.jit(join, donate_argnums=(1, 2, 3, 4, 5))
