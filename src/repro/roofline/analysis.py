"""Three-term roofline from dry-run artifacts (§Roofline).

Per (arch x shape x mesh) cell, from the trip-count-corrected HLO stats:

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = collective_bytes / (links x link_bw)

HLO_FLOPs come from the analyzer (dots x while-trip multipliers — XLA's
own cost_analysis counts loop bodies once, see hlo_analyzer).  HLO_bytes
are estimated as dot operand+result traffic at the same multipliers
bounded below by one full pass over the per-device parameter bytes; the
raw (uncorrected) cost_analysis numbers are carried alongside.

MODEL_FLOPS = 6 * N_active * tokens for training cells (2x for MTP-less
inference) — the "useful work" yardstick; MODEL_FLOPS / HLO_FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from ..configs import get_config
from ..configs.base import SHAPES, ArchConfig, BlockKind
from ..core.hwspec import DEFAULT_TPU, TpuSpec


# ---------------------------------------------------------------------------
# analytical parameter / flops model
# ---------------------------------------------------------------------------

def param_counts(cfg: ArchConfig) -> dict[str, float]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    counts: dict[str, float] = {}
    counts["embed"] = cfg.vocab * d
    if not cfg.tied_embeddings:
        counts["lm_head"] = cfg.vocab * d
    attn = 0.0
    dense_ffn = 0.0
    moe_ffn = 0.0
    shared_ffn = 0.0
    ssm = 0.0
    n_attn = n_dense = n_moe = n_ssm = n_shared = 0
    for seg in cfg.resolved_segments():
        if seg.kind is BlockKind.SSM:
            n_ssm += seg.count
        elif seg.kind is BlockKind.MOE:
            n_moe += seg.count
            n_attn += seg.count
        elif seg.kind is BlockKind.SHARED_ATTN:
            n_shared += 1
        else:
            n_dense += seg.count
            n_attn += seg.count
    if cfg.mla:
        m = cfg.mla
        per_attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads
                    * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    + m.kv_lora_rank * cfg.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + cfg.n_heads * m.v_head_dim * d)
    else:
        per_attn = d * hd * (cfg.n_heads + 2 * cfg.kv_heads) \
            + cfg.n_heads * hd * d
    attn = per_attn * n_attn
    mlp_mult = 3 if cfg.gated_mlp else 2
    dense_ffn = n_dense * mlp_mult * d * cfg.d_ff
    if cfg.moe:
        m = cfg.moe
        moe_ffn = n_moe * (m.n_experts * 3 * d * m.d_ff_expert
                           + d * m.n_experts)
        if m.n_shared_experts:
            sf = m.d_ff_shared or m.d_ff_expert * m.n_shared_experts
            moe_ffn += n_moe * 3 * d * sf
    if cfg.ssm:
        s = cfg.ssm
        d_inner = s.expand * d
        h = d_inner // s.head_dim
        d_xbc = d_inner + 2 * s.n_groups * s.d_state
        ssm = n_ssm * (d * d_inner + d * d_xbc + d * h
                       + s.d_conv * d_xbc + d_inner * d)
    if n_shared:
        shared_ffn = per_attn + mlp_mult * d * cfg.d_ff   # one shared copy
    encoder = 0.0
    if cfg.enc_dec:
        # encoder blocks (full-head self-attn + MLP) + per-decoder-layer
        # cross-attention projections
        enc_attn = d * hd * cfg.n_heads * 4
        encoder = cfg.n_encoder_layers * (enc_attn + mlp_mult * d * cfg.d_ff)
        encoder += 2 * d * cfg.kv_heads * hd          # cross K/V projections
        attn += n_attn * 2 * d * hd * cfg.kv_heads    # cross-attn per block
    counts.update(attn=attn, dense_ffn=dense_ffn, moe_ffn=moe_ffn,
                  ssm=ssm, shared=shared_ffn, encoder=encoder)
    return counts


def n_params(cfg: ArchConfig) -> float:
    return sum(param_counts(cfg).values())


def n_active_params(cfg: ArchConfig) -> float:
    """Per-token active parameters (MoE: top_k + shared experts only)."""
    counts = param_counts(cfg)
    total = sum(v for k, v in counts.items() if k != "moe_ffn")
    if cfg.moe:
        m = cfg.moe
        n_moe = sum(s.count for s in cfg.resolved_segments()
                    if s.kind is BlockKind.MOE)
        d = cfg.d_model
        active = n_moe * (m.top_k * 3 * d * m.d_ff_expert + d * m.n_experts)
        if m.n_shared_experts:
            sf = m.d_ff_shared or m.d_ff_expert * m.n_shared_experts
            active += n_moe * 3 * d * sf
        total += active
    # shared attention blocks execute once per occurrence
    n_shared_sites = sum(1 for s in cfg.resolved_segments()
                         if s.kind is BlockKind.SHARED_ATTN)
    if n_shared_sites > 1:
        total += counts["shared"] * (n_shared_sites - 1)
    return total


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    """6·N_active·tokens for train, 2·N_active·tokens for inference, plus
    dense attention score flops where applicable (global, all devices)."""
    shape = SHAPES[shape_name]
    mult = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        tokens = shape.global_batch * 1
    else:
        tokens = shape.global_batch * shape.seq_len
    base = mult * n_active_params(cfg) * tokens
    # attention scores (dense archs): 2 * 2 * T * L_ctx * d_attn per layer
    n_attn = sum(s.count for s in cfg.resolved_segments()
                 if s.kind in (BlockKind.DENSE, BlockKind.MOE)) \
        + sum(1 for s in cfg.resolved_segments()
              if s.kind is BlockKind.SHARED_ATTN)
    if n_attn and cfg.attn.value != "none":
        hd = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
              if cfg.mla else cfg.resolved_head_dim)
        heads = cfg.n_heads
        if shape.kind == "decode":
            ctx = shape.seq_len
            per_tok = 2 * 2 * ctx * heads * hd
        else:
            ctx = shape.seq_len / 2          # causal average
            per_tok = 2 * 2 * ctx * heads * hd * (3 if shape.kind == "train"
                                                  else 1)
        base += n_attn * tokens * per_tok
    return base


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_ns: float
    memory_ns: float
    collective_ns: float
    hlo_flops_dev: float
    hlo_bytes_dev: float
    coll_bytes_dev: float
    model_flops_total: float
    useful_ratio: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_ns, "memory": self.memory_ns,
                 "collective": self.collective_ns}
        return max(terms, key=terms.get)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the step spent at the *compute* roofline if the
        dominant term were the only one (useful-compute / bound-time)."""
        useful_ns = (self.model_flops_total / self.n_devices
                     / DEFAULT_TPU.peak_flops_per_ns)
        bound = max(self.compute_ns, self.memory_ns, self.collective_ns)
        return useful_ns / bound if bound else 0.0

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},"
                f"{self.compute_ns / 1e6:.3f},{self.memory_ns / 1e6:.3f},"
                f"{self.collective_ns / 1e6:.3f},{self.bound},"
                f"{self.useful_ratio:.2f},{self.roofline_frac:.3f}")


def from_artifact(path: pathlib.Path, cfg: ArchConfig | None = None,
                  tpu: TpuSpec = DEFAULT_TPU) -> Roofline:
    rec = json.loads(path.read_text())
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    cfg = cfg or get_config(arch)
    n_dev = rec["n_devices"]
    hs = rec.get("hlo_stats", {}) or {}
    flops_dev = float(hs.get("flops") or rec.get("flops") or 0.0)
    coll = hs.get("collective_bytes") or rec.get("collective_bytes") or {}
    coll_bytes = float(sum(coll.values()))
    # memory bytes: params touched once + dot traffic estimate; lower-bound
    # by raw cost_analysis "bytes accessed" (uncorrected for trips).
    param_bytes_dev = n_params(cfg) * 2.0 / n_dev      # bf16 resident pass
    raw_bytes = float(rec.get("bytes_accessed") or 0.0)
    # dots stream operands from HBM at worst; assume operands ~ flops/(2*512)
    # (arithmetic intensity of a 512-tile matmul) as the HBM-traffic proxy.
    dot_bytes = flops_dev / (2.0 * 512.0) * 2.0
    mem_bytes_dev = max(param_bytes_dev, raw_bytes, dot_bytes)
    mflops = model_flops(cfg, shape)
    compute_ns = flops_dev / tpu.peak_flops_per_ns
    memory_ns = mem_bytes_dev / tpu.hbm_gbps
    coll_ns = coll_bytes / (tpu.ici_link_gbps * tpu.ici_links)
    useful = mflops / (flops_dev * n_dev) if flops_dev else 0.0
    return Roofline(arch=arch, shape=shape, mesh=mesh, n_devices=n_dev,
                    compute_ns=compute_ns, memory_ns=memory_ns,
                    collective_ns=coll_ns, hlo_flops_dev=flops_dev,
                    hlo_bytes_dev=mem_bytes_dev, coll_bytes_dev=coll_bytes,
                    model_flops_total=mflops, useful_ratio=useful)
