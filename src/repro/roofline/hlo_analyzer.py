"""Trip-count-aware analyzer for optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once, but
scan-over-layers/microbatches/chunks means nearly all of a step's work
lives inside while bodies.  This analyzer walks the computation graph,
derives每 while's trip count from its condition's bound constant, and
multiplies dots/collectives accordingly — giving honest per-device FLOPs
and collective-byte totals from the compiled artifact.

Accounting conventions (documented for §Roofline):
* dot flops = 2 x prod(result dims) x prod(lhs contracting dims);
* collective bytes = result-shape bytes (all-gather: gathered shape;
  all-reduce: payload counted once; reduce-scatter: operand shape —
  approximated by result x group_size), each x trip multiplier;
* elementwise/fusion flops are ignored (dots dominate every cell here);
* everything is per-device (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COMP_HEAD = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE = re.compile(r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_DOT = re.compile(
    r"dot\(%?([\w\.\-]+),\s*%?([\w\.\-]+)\).*?lhs_contracting_dims=\{([0-9,]*)\}")
_CONST = re.compile(r"constant\((\d+)\)")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(type_str: str) -> int:
    n_total = 0
    for m in _SHAPE.finditer(type_str):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    shapes: dict[str, str]          # op name -> result type string


def _split_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        head = _COMP_HEAD.match(line)
        if (head and line.rstrip().endswith("{") and "->" in line
                and "=" not in line.split("(")[0]):
            cur = Computation(head.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, rest = m.group(1), m.group(2)
            cur.lines.append(line)
            # result type = text before the op kind token
            cur.shapes[name] = rest
    return comps


def _trip_count(cond: Computation) -> int:
    """Bound constant in the while condition (max constant therein)."""
    best = 1
    for line in cond.lines:
        if "compare" in line or "constant" in line:
            for m in _CONST.finditer(line):
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    dot_flops: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_calls: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_product_max: int = 1

    def as_dict(self) -> dict:
        return {"flops": self.flops,
                "collective_bytes": dict(self.collective_bytes),
                "collective_calls": dict(self.collective_calls),
                "n_while": self.n_while,
                "max_trip_product": self.trip_product_max}


def analyze_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    stats = HloStats()
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or name == "main":
            entry = name
            break
    if entry is None:   # fall back: the last computation is usually ENTRY
        entry = list(comps)[-1]

    seen_stack: list[str] = []

    def walk(comp_name: str, mult: int) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        stats.trip_product_max = max(stats.trip_product_max, mult)
        for line in comp.lines:
            wm = _WHILE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond_name,
                                              Computation("", [], {})))
                stats.n_while += 1
                walk(body_name, mult * trips)
                continue
            dm = _DOT.search(line)
            if dm:
                opm = _OP_LINE.match(line)
                result_type = opm.group(2) if opm else line
                out_elems = _shape_elems(result_type.split(" dot(")[0])
                lhs_name = dm.group(1)
                lhs_type = comp.shapes.get(lhs_name, "")
                cdims = [int(x) for x in dm.group(3).split(",") if x]
                k = 1
                sm = _SHAPE.search(lhs_type.split(" ")[0]) or \
                    _SHAPE.search(lhs_type)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for cd in cdims:
                        if cd < len(dims):
                            k *= dims[cd]
                f = 2.0 * out_elems * k * mult
                stats.flops += f
                stats.dot_flops += f
                continue
            for kind in COLLECTIVES:
                if re.search(rf"\b{kind}\(", line):
                    opm = _OP_LINE.match(line)
                    result_type = (opm.group(2) if opm else line).split(
                        f" {kind}(")[0]
                    b = _shape_bytes(result_type)
                    gm = _GROUPS.search(line)
                    if kind == "reduce-scatter" and gm:
                        b *= int(gm.group(2))   # operand = result x group
                    stats.collective_bytes[kind] = \
                        stats.collective_bytes.get(kind, 0.0) + b * mult
                    stats.collective_calls[kind] = \
                        stats.collective_calls.get(kind, 0) + mult
                    break
            else:
                cm = _CALLS.search(line)
                if cm and ("fusion(" in line or "call(" in line):
                    walk(cm.group(1), mult)
        seen_stack.pop()

    walk(entry, 1)
    return stats
