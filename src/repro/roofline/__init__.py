from .hlo_analyzer import analyze_hlo  # noqa: F401
