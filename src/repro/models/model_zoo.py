"""Model zoo: one entry point per assigned architecture.

``build_model("deepseek-v3-671b")`` returns a :class:`Model` wrapping the
functional transformer with the arch's config: init / loss / forward /
decode-step / cache plumbing and ``input_specs`` (ShapeDtypeStruct
stand-ins for every model input at a given shape cell — the dry-run
contract; modality frontends contribute precomputed embeddings here).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import transformer
from .transformer import (forward, init_caches, init_lm, init_paged_caches,
                          lm_loss, logits_fn)
from ..configs import get_config
from ..configs.base import ArchConfig, Frontend, ShapeConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ----------------------------- params --------------------------------
    def init(self, key: jax.Array) -> dict:
        return init_lm(key, self.cfg)

    def abstract_ptree(self) -> dict:
        """Shape-only P-tree (values are ShapeDtypeStructs, axes kept) —
        feeds repro.distributed.sharding.param_shardings."""
        from .param import P

        def wrap(key):
            return init_lm(key, self.cfg)
        return jax.eval_shape(wrap, jax.random.key(0))

    def abstract_params(self, dtype=jnp.float32) -> dict:
        """Shape-only unwrapped params (no allocation) — dry-run inputs."""
        from . import param as pm
        out = pm.unwrap(self.abstract_ptree())
        if dtype != jnp.float32:
            out = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, dtype if s.dtype == jnp.float32 else s.dtype),
                out)
        return out

    # ----------------------------- training ------------------------------
    def loss(self, params, batch, *, dtype=jnp.bfloat16, remat: bool = False):
        return lm_loss(params, batch, self.cfg, dtype=dtype, remat=remat)

    # ----------------------------- inference -----------------------------
    def prefill(self, params, batch, max_len: int, *, dtype=jnp.bfloat16,
                last_pos=None):
        """Run the prompt, fill caches sized for ``max_len`` tokens.

        ``last_pos`` ([B] int32, optional) gathers each row's logits at its
        own final *prompt* position instead of the padded width — the
        slot-scheduler path, where prompts of mixed length share one padded
        prefill and padding keys are masked out (and later overwritten) by
        per-slot cache lengths during decode."""
        caches = init_caches(self.cfg, batch["tokens"].shape[0], max_len,
                             dtype)
        hidden, caches, _ = forward(params, batch, self.cfg, caches=caches,
                                    cache_len=jnp.zeros((), jnp.int32),
                                    dtype=dtype)
        if last_pos is None:
            h = hidden[:, -1:]
        else:
            lp = jnp.clip(jnp.asarray(last_pos, jnp.int32), 0,
                          hidden.shape[1] - 1)
            h = hidden[jnp.arange(hidden.shape[0]), lp][:, None]
        logits = logits_fn(params, h, self.cfg)
        return logits, caches

    def prefill_paged(self, params, batch, caches, pages, *,
                      dtype=jnp.bfloat16, last_pos=None, cache_len=None):
        """Paged prefill: write the prompt's K/V through ``pages`` ([B, P]
        page table) into the pooled ``caches`` (from ``init_paged_caches``)
        instead of allocating per-slot stripes.  Rows whose table entries
        are all sentinels write nothing (their scatters drop) — that is how
        the serving join prefills only the slots being refilled while the
        other slots' pages stay bit-for-bit intact.

        ``cache_len`` ([B] int32, default zeros) makes this a *suffix*
        prefill: row b's tokens are treated as sitting at positions
        ``cache_len[b] + t`` — K/V scatters, RoPE and the causal mask all
        continue at that depth, and attention reads the first
        ``cache_len[b]`` resident tokens through the table.  The
        prefix-cache join uses this to compute only the uncached tail of a
        prompt whose page-aligned prefix is already pooled."""
        b = batch["tokens"].shape[0]
        if cache_len is None:
            cache_len = jnp.zeros((b,), jnp.int32)
        hidden, caches, _ = forward(params, batch, self.cfg, caches=caches,
                                    cache_len=jnp.asarray(cache_len,
                                                          jnp.int32),
                                    dtype=dtype, pages=pages)
        if last_pos is None:
            h = hidden[:, -1:]
        else:
            lp = jnp.clip(jnp.asarray(last_pos, jnp.int32), 0,
                          hidden.shape[1] - 1)
            h = hidden[jnp.arange(hidden.shape[0]), lp][:, None]
        logits = logits_fn(params, h, self.cfg)
        return logits, caches

    def decode_step(self, params, tokens, caches, cache_len, *,
                    dtype=jnp.bfloat16, extra: dict | None = None,
                    pages=None):
        """One decode step: tokens [B, L] against filled caches (dense, or
        paged when ``pages`` carries the slots' page tables).

        Plain decode passes L = 1.  Speculative decode passes L = k+1
        (the current token plus k drafts): every token scatters its K/V
        at ``cache_len + t``, attends causally at its absolute position,
        and the returned logits cover **all L positions** — the verify
        needs the model's own greedy output after every draft, and the
        per-slot accepted advance is decided by the caller (the engine's
        spec loop), which rolls back by simply not advancing
        ``cache_len`` past the acceptance point."""
        batch = {"tokens": tokens}
        if extra:
            batch.update(extra)
        hidden, caches, _ = forward(params, batch, self.cfg, caches=caches,
                                    cache_len=cache_len, dtype=dtype,
                                    pages=pages)
        logits = logits_fn(params, hidden, self.cfg)
        return logits, caches

    def init_caches(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return init_caches(self.cfg, batch, max_len, dtype)

    def init_paged_caches(self, batch: int, n_pages: int, page_size: int,
                          dtype=jnp.bfloat16):
        return init_paged_caches(self.cfg, batch, n_pages, page_size, dtype)

    # ----------------------------- dry-run inputs ------------------------
    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every input of the step this
        shape cell lowers (train -> lm_loss batch; decode -> one-token
        step + caches)."""
        cfg = self.cfg
        b = shape.global_batch
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            batch = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
            if cfg.frontend is Frontend.VISION_STUB:
                batch["vision_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.vision_tokens, cfg.d_model), dtype)
            if cfg.enc_dec:
                batch["encoder_frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder_seq, cfg.d_model), dtype)
            return {"batch": batch}
        # decode: one new token against a seq_len cache
        caches = jax.eval_shape(
            lambda: init_caches(cfg, b, shape.seq_len, dtype))
        extra = {}
        if cfg.enc_dec:
            hd = cfg.resolved_head_dim
            extra["cross_kv"] = (
                jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.kv_heads, hd),
                                     dtype),
                jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.kv_heads, hd),
                                     dtype))
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "caches": caches,
                "cache_len": jax.ShapeDtypeStruct((), i32),
                "extra": extra}


def build_model(name_or_cfg: str | ArchConfig) -> Model:
    cfg = (name_or_cfg if isinstance(name_or_cfg, ArchConfig)
           else get_config(name_or_cfg))
    return Model(cfg)
