"""Model substrate: composable, functional JAX model definitions.

Parameters are pytrees of :class:`repro.models.param.P` leaves carrying
logical sharding axes; :mod:`repro.distributed.sharding` turns those into
NamedShardings for any mesh.  All model code is pure-functional
(init_fn -> params, apply_fn(params, inputs) -> outputs) and scan-friendly.
"""

from . import model_zoo  # noqa: F401
from .model_zoo import build_model  # noqa: F401
