"""Core layers: norms, projections, embeddings, RoPE, MLPs.

Convention: ``init_*`` returns a dict tree of :class:`repro.models.param.P`
(value + logical axes); ``*_apply`` functions take the *unwrapped* value
tree (plain arrays) — they run inside jit.  Logical axis names used here:

  vocab, embed, heads, kv_heads, head_dim, mlp, experts, q_lora, kv_lora,
  conv, state — mapped to mesh axes by repro.distributed.sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import param as pm


# ------------------------------ norms --------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": pm.ones((d,), ("embed",))}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": pm.ones((d,), ("embed",)),
            "bias": pm.zeros((d,), ("embed",))}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ------------------------------ dense --------------------------------------

def init_dense(key: jax.Array, shape: tuple[int, ...],
               axes: tuple[str | None, ...], *, bias: bool = False,
               bias_axes: tuple[str | None, ...] | None = None,
               scale: float | None = None) -> dict:
    scale = pm.fanin_scale(shape) if scale is None else scale
    out = {"w": pm.normal(key, shape, axes, stddev=scale)}
    if bias:
        bshape = shape[1:]
        out["b"] = pm.zeros(bshape, bias_axes or axes[1:])
    return out


def dense(params: dict, x: jnp.ndarray, spec: str) -> jnp.ndarray:
    """einsum-based projection, e.g. spec='btd,dhq->bthq'."""
    y = jnp.einsum(spec, x, params["w"].astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ------------------------------ embedding ----------------------------------

def init_embedding(key: jax.Array, vocab: int, d: int) -> dict:
    # "embed_r": replicated model dim — vocab carries all the sharding
    # (see distributed.sharding §Perf iter 2 note)
    return {"table": pm.normal(key, (vocab, d), ("vocab", "embed_r"),
                               stddev=0.02)}


def embed(params: dict, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied read-out: logits in f32 for loss stability."""
    return jnp.einsum("btd,vd->btv", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


# ------------------------------ RoPE ----------------------------------------

def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [..., L, H, D] (D even), positions: [..., L] (int)."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., L, D/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=dtype)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=dtype) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((length, d), dtype=dtype)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ------------------------------ activations --------------------------------

def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# ------------------------------ MLP ----------------------------------------

def init_mlp(key: jax.Array, d: int, d_ff: int, *, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    out = {"wi": init_dense(ks[0], (d, d_ff), ("embed", "mlp")),
           "wo": init_dense(ks[1], (d_ff, d), ("mlp", "embed"))}
    if gated:
        out["wg"] = init_dense(ks[2], (d, d_ff), ("embed", "mlp"))
    return out


def mlp(params: dict, x: jnp.ndarray, act_name: str = "silu") -> jnp.ndarray:
    act = activation(act_name)
    h = dense(params["wi"], x, "btd,df->btf")
    if "wg" in params:
        h = act(dense(params["wg"], x, "btd,df->btf")) * h
    else:
        h = act(h)
    return dense(params["wo"], h, "btf,fd->btd")
