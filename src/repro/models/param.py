"""Parameter leaves with logical sharding axes.

``P(value, axes)`` wraps an array with a tuple of logical axis names (one
per dim, ``None`` = replicated).  Model init functions build trees of ``P``;
:func:`unwrap` / :func:`axes_of` split them into a value tree and an axes
tree with identical structure (what pjit's in_shardings wants).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class P:
    value: Any
    axes: tuple[str | None, ...]


def _p_flatten(p: P):
    return (p.value,), tuple(p.axes)


def _p_unflatten(axes, children):
    return P(children[0], axes)


jax.tree_util.register_pytree_node(P, _p_flatten, _p_unflatten)


def is_param(x: Any) -> bool:
    return isinstance(x, P)


def unwrap(tree: Any) -> Any:
    """Tree of P -> tree of arrays."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def axes_of(tree: Any) -> Any:
    """Tree of P -> tree of logical-axes tuples."""
    return jax.tree_util.tree_map(lambda p: tuple(p.axes), tree,
                                  is_leaf=is_param)


def shapes_of(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.value.shape, p.value.dtype),
        tree, is_leaf=is_param)


def n_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(unwrap(tree))
    return int(sum(np.prod(l.shape) for l in leaves))


# ------------------------- initializers -----------------------------------

def normal(key: jax.Array, shape: tuple[int, ...], axes: tuple[str | None, ...],
           stddev: float = 0.02, dtype=jnp.float32) -> P:
    return P(stddev * jax.random.normal(key, shape, dtype=dtype), axes)


def zeros(shape: tuple[int, ...], axes: tuple[str | None, ...],
          dtype=jnp.float32) -> P:
    return P(jnp.zeros(shape, dtype=dtype), axes)


def ones(shape: tuple[int, ...], axes: tuple[str | None, ...],
         dtype=jnp.float32) -> P:
    return P(jnp.ones(shape, dtype=dtype), axes)


def abstract(shape: tuple[int, ...], axes: tuple[str | None, ...],
             dtype=jnp.float32) -> P:
    """ShapeDtypeStruct-valued P: for dry-run init without allocation."""
    return P(jax.ShapeDtypeStruct(shape, dtype), axes)


def fanin_scale(shape: tuple[int, ...]) -> float:
    return float(1.0 / np.sqrt(max(1, shape[0])))
