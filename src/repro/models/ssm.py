"""Mamba-2 SSD (state-space duality) blocks — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm formulated as a single
``lax.scan`` over chunks with the inter-chunk state as carry: intra-chunk
terms are matmul-friendly (MXU) while memory stays O(chunk) — the compiled
HLO is O(1) in sequence length, which is what lets the long_500k cells
lower.  Decode is the linear recurrence on a [B, H, P, N] state.

Block layout (mamba2): in_proj -> (z, xBC, dt); causal depthwise conv + silu
on xBC; SSD; gated RMSNorm (y * silu(z)); out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import param as pm
from .layers import dense, init_dense, init_rmsnorm, rmsnorm
from ..configs.base import ArchConfig


class SsmCache(NamedTuple):
    conv: jnp.ndarray     # [B, d_conv-1, d_xbc]
    state: jnp.ndarray    # [B, H, P, N]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_xbc = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, d_xbc


def init_ssm(key: jax.Array, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, d_xbc = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_z": init_dense(ks[0], (d, d_inner), ("embed", "mlp")),
        "in_xbc": init_dense(ks[1], (d, d_xbc), ("embed", "mlp")),
        "in_dt": init_dense(ks[2], (d, h), ("embed", "heads")),
        "conv_w": pm.normal(ks[3], (s.d_conv, d_xbc), ("conv", "mlp"),
                            stddev=0.2),
        "conv_b": pm.zeros((d_xbc,), ("mlp",)),
        "a_log": pm.P(jnp.log(jnp.linspace(1.0, 16.0, h)), ("heads",)),
        "dt_bias": pm.zeros((h,), ("heads",)),
        "d_skip": pm.ones((h,), ("heads",)),
        "norm": init_rmsnorm(d_inner),
        "out": init_dense(ks[4], (d_inner, d), ("mlp", "embed")),
    }


# ----------------------------- SSD core ------------------------------------

def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: [..., L] -> lower-triangular pairwise sums s[i,j] = sum(a[j+1..i])."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    l = a.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xdt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                c: jnp.ndarray, chunk: int,
                initial_state: jnp.ndarray | None = None):
    """Chunked SSD.

    xdt: [B, L, H, P] (inputs pre-scaled by dt), a: [B, L, H] (= dt * A,
    negative), b/c: [B, L, G, N].  Returns (y [B,L,H,P], final_state
    [B,H,P,N]).
    """
    bsz, l, h, p = xdt.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(t):
        return t.reshape((bsz, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    xc, ac, bc, cc = map(to_chunks, (xdt, a, b, c))    # leading axis = chunk

    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xk, ak, bk, ck = inp                 # [B,cl,H,P], [B,cl,H], [B,cl,G,N]
        ak = ak.astype(jnp.float32)
        a_cs = jnp.cumsum(ak, axis=1)                       # [B,cl,H]
        lmat = jnp.exp(_segsum(ak.swapaxes(1, 2)))          # [B,H,cl,cl]
        lmat = lmat.astype(xk.dtype)
        # group -> head expansion via reshape (no materialized repeat)
        lh = lmat.reshape(bsz, g, hg, chunk, chunk)
        xh = xk.reshape(bsz, chunk, g, hg, p)
        # intra-chunk
        scores = jnp.einsum("blgn,bsgn->bgls", ck, bk)      # [B,cl,cl] per g
        y_diag = jnp.einsum("bgls,bghls,bsghp->blghp", scores, lh, xh)
        # contribution of the incoming state
        decay_out = jnp.exp(a_cs).astype(xk.dtype)          # [B,cl,H]
        sh = state.astype(xk.dtype).reshape(bsz, g, hg, p, n)
        y_off = jnp.einsum("blgn,bghpn->blghp", ck, sh)
        y_off = y_off * decay_out.reshape(bsz, chunk, g, hg)[..., None]
        y = (y_diag + y_off).reshape(bsz, chunk, h, p)
        # state update
        decay_total = jnp.exp(a_cs[:, -1, :])               # [B,H]
        decay_in = jnp.exp(a_cs[:, -1:, :] - a_cs)          # [B,cl,H]
        contrib = jnp.einsum("bsgn,bsghp->bghpn",
                             bk, xh * decay_in.reshape(
                                 bsz, chunk, g, hg)[..., None])
        new_state = (state * decay_total[:, :, None, None]
                     + contrib.reshape(bsz, h, p, n).astype(jnp.float32))
        return new_state, y

    final, yc = jax.lax.scan(step, initial_state, (xc, ac, bc, cc))
    y = yc.swapaxes(0, 1).reshape(bsz, nc * chunk, h, p)[:, :l]
    return y, final


def ssd_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
             a_neg: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray):
    """One-token recurrence.  state [B,H,P,N], x [B,H,P], dt [B,H],
    a_neg [H], b/c [B,G,N]."""
    bsz, h, p, n = state.shape
    g = b.shape[1]
    hg = h // g
    da = jnp.exp(dt * a_neg[None, :])                       # [B,H]
    xdt = x * dt[..., None]
    bh = jnp.broadcast_to(b[:, :, None, :], (bsz, g, hg, n)).reshape(bsz, h, n)
    ch = jnp.broadcast_to(c[:, :, None, :], (bsz, g, hg, n)).reshape(bsz, h, n)
    new_state = (state * da[:, :, None, None]
                 + xdt[..., None] * bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return new_state.astype(state.dtype), y.astype(x.dtype)


# ----------------------------- block apply ----------------------------------

def _conv_train(params, xbc: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv over [B, L, C]."""
    k = params["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, params["conv_w"][:, None, :].astype(xbc.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1])
    return out + params["conv_b"].astype(xbc.dtype)


def ssm_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig, *,
              cache: SsmCache | None = None):
    """x: [B, L, D] -> (y, new_cache)."""
    s = cfg.ssm
    d_inner, h, d_xbc = _dims(cfg)
    bsz, l, _ = x.shape
    z = dense(params["in_z"], x, "btd,df->btf")
    xbc = dense(params["in_xbc"], x, "btd,df->btf")
    dt_raw = dense(params["in_dt"], x, "btd,df->btf")
    new_cache = None
    if cache is not None and l == 1:
        # decode: roll conv state
        window = jnp.concatenate([cache.conv, xbc], axis=1)   # [B,k,C]
        w = params["conv_w"].astype(x.dtype)
        conv_out = jnp.einsum("bkc,kc->bc", window, w)[:, None, :] \
            + params["conv_b"].astype(x.dtype)
        new_conv = window[:, 1:]
    else:
        conv_out = _conv_train(params, xbc)
        new_conv = None
        if cache is not None:
            k = s.d_conv
            tail = jnp.pad(xbc, ((0, 0), (max(0, k - 1 - l), 0), (0, 0)))
            new_conv = tail[:, -(k - 1):]
    xbc_act = jax.nn.silu(conv_out)
    x_ssm = xbc_act[..., :d_inner].reshape(bsz, -1, h, s.head_dim)
    bmat = xbc_act[..., d_inner:d_inner + s.n_groups * s.d_state]
    cmat = xbc_act[..., d_inner + s.n_groups * s.d_state:]
    bmat = bmat.reshape(bsz, -1, s.n_groups, s.d_state)
    cmat = cmat.reshape(bsz, -1, s.n_groups, s.d_state)
    # §Perf iter 8: keep the SSD contraction dims local — shard heads over
    # the model axis, replicate the (small) B/C state operands.  The xbc
    # channel sharding otherwise splits the state dim N across ranks and
    # every SSD einsum partial-sums per chunk trip (measured: ~5k
    # all-reduce calls / 80 GB per step on mamba2 train).
    from ..distributed.act_sharding import constrain
    x_ssm = constrain(x_ssm, ("batch", None, "heads", None))
    bmat = constrain(bmat, ("batch", None, None, None))
    cmat = constrain(cmat, ("batch", None, None, None))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"]).astype(jnp.float32)
    a_neg = -jnp.exp(params["a_log"].astype(jnp.float32))

    if cache is not None and l == 1:
        new_state, y = ssd_step(cache.state, x_ssm[:, 0], dt[:, 0], a_neg,
                                bmat[:, 0], cmat[:, 0])
        y = y[:, None]
        new_cache = SsmCache(conv=new_conv, state=new_state)
    else:
        xdt = x_ssm * dt[..., None].astype(x_ssm.dtype)
        a = dt * a_neg[None, None, :]
        init = cache.state if cache is not None else None
        y, final = ssd_chunked(xdt, a, bmat, cmat, s.chunk,
                               initial_state=init)
        if cache is not None:
            new_cache = SsmCache(conv=new_conv, state=final)

    y = y + x_ssm * params["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, -1, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(params["out"], y, "btf,fd->btd"), new_cache


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SsmCache:
    s = cfg.ssm
    d_inner, h, d_xbc = _dims(cfg)
    return SsmCache(
        conv=jnp.zeros((batch, s.d_conv - 1, d_xbc), dtype),
        state=jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32))
