"""Decoder-only LM (+ hybrid SSM / MoE / enc-dec variants) with
scan-over-layers.

Layer stacks are built as *segments* of identical blocks whose parameters
are stacked on a leading axis and applied with ``lax.scan`` — compiled HLO
is O(segments), not O(layers), which keeps 61-layer MoE and 48-layer hybrid
models lowerable for 512-device meshes.  Heterogeneous stacks (deepseek's
dense prefix, zamba2's shared attention) are sequences of homogeneous
segments; zamba2's shared block re-applies one weight set at every
occurrence.

The LM loss is computed chunked over the sequence (logits for a chunk are
formed, reduced against targets, and discarded) so the [tokens, vocab]
logits tensor never materializes — at vocab 256k that matters more than
any other activation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import param as pm
from .attention import (KVCache, PagedKVCache, attention_apply,
                        init_attention)
from .layers import (dense, embed, init_dense, init_embedding, init_layernorm,
                     init_mlp, init_rmsnorm, layernorm, mlp, rmsnorm, unembed)
from .moe import init_moe, moe_apply
from .ssm import SsmCache, init_cache as init_ssm_cache, init_ssm, ssm_apply
from ..configs.base import ArchConfig, AttnKind, BlockKind, Segment

LOSS_CHUNK = 1024


# ---------------------------------------------------------------------------
# norms (rms vs layer, config-driven)
# ---------------------------------------------------------------------------

def _init_norm(cfg: ArchConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    return init_layernorm(d) if cfg.enc_dec else init_rmsnorm(d)


def _norm(cfg: ArchConfig, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.enc_dec:
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, cfg: ArchConfig, kind: BlockKind, *,
               cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    if kind is BlockKind.SSM:
        return {"norm": _init_norm(cfg), "ssm": init_ssm(ks[0], cfg)}
    out = {"norm1": _init_norm(cfg), "attn": init_attention(ks[0], cfg),
           "norm2": _init_norm(cfg)}
    if cross:
        out["norm_x"] = _init_norm(cfg)
        out["cross"] = init_attention(ks[3], cfg)
    if kind is BlockKind.MOE:
        out["moe"] = init_moe(ks[1], cfg)
    else:
        out["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                              gated=cfg.gated_mlp)
    return out


def block_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                kind: BlockKind, *, positions, cache=None,
                cross_kv=None, causal: bool = True):
    """Returns (y, new_cache, aux_loss)."""
    from ..distributed.act_sharding import constrain_btd
    x = constrain_btd(x)   # §Perf iter 1: pin activations to batch sharding
    aux = jnp.zeros((), jnp.float32)
    if kind is BlockKind.SSM:
        h, new_cache = ssm_apply(params["ssm"],
                                 _norm(cfg, params["norm"], x), cfg,
                                 cache=cache)
        return x + h, new_cache, aux
    h, new_cache = attention_apply(params["attn"],
                                   _norm(cfg, params["norm1"], x), cfg,
                                   positions=positions, causal=causal,
                                   cache=cache)
    x = x + h
    if "cross" in params and cross_kv is not None:
        h, _ = attention_apply(params["cross"],
                               _norm(cfg, params["norm_x"], x), cfg,
                               positions=positions, causal=False,
                               kv_override=cross_kv)
        x = x + h
    z = _norm(cfg, params["norm2"], x)
    if kind is BlockKind.MOE:
        h, aux = moe_apply(params["moe"], z, cfg, cfg.activation)
    else:
        h = mlp(params["mlp"], z, cfg.activation)
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _attn_cache_shape(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.attn is AttnKind.MLA:
        return ((batch, max_len, cfg.mla.kv_lora_rank),
                (batch, max_len, cfg.mla.qk_rope_head_dim))
    hd = cfg.resolved_head_dim
    return ((batch, max_len, cfg.kv_heads, hd),
            (batch, max_len, cfg.kv_heads, hd))


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> list:
    """One cache pytree per segment (stacked over the segment's layers)."""
    caches = []
    kshape, vshape = _attn_cache_shape(cfg, batch, max_len)
    for seg in cfg.resolved_segments():
        n = seg.count
        if seg.kind is BlockKind.SSM:
            single = init_ssm_cache(cfg, batch, dtype)
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.zeros((n,) + a.shape, a.dtype), single))
        else:
            caches.append({
                "k": jnp.zeros((n,) + kshape, dtype),
                "v": jnp.zeros((n,) + vshape, dtype)})
    return caches


def init_paged_caches(cfg: ArchConfig, batch: int, n_pages: int,
                      page_size: int, dtype=jnp.bfloat16) -> list:
    """Paged variant of :func:`init_caches`: attention segments hold one
    pooled ``[layers, n_pages, page_size, ...]`` allocation shared by every
    slot through the page table (see repro.serve.kvpool); SSM segments keep
    their per-slot recurrent state — it is O(1) in sequence length, there
    is nothing to page (which is also why prefix sharing is
    attention-only: a recurrent state cannot resume from a cached page)."""
    caches = []
    kshape, vshape = _attn_cache_shape(cfg, n_pages, page_size)
    for seg in cfg.resolved_segments():
        n = seg.count
        if seg.kind is BlockKind.SSM:
            single = init_ssm_cache(cfg, batch, dtype)
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.zeros((n,) + a.shape, a.dtype), single))
        else:
            caches.append({
                "k": jnp.zeros((n,) + kshape, dtype),
                "v": jnp.zeros((n,) + vshape, dtype)})
    return caches


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_lm(key: jax.Array, cfg: ArchConfig) -> dict:
    ks = iter(jax.random.split(key, 64))
    params: dict[str, Any] = {"embed": init_embedding(next(ks), cfg.vocab,
                                                      cfg.d_model)}
    segments = []
    for seg in cfg.resolved_segments():
        if seg.kind is BlockKind.SHARED_ATTN:
            segments.append({})   # weights live in params["shared_block"]
            continue
        keys = jax.random.split(next(ks), seg.count)
        stacked = jax.vmap(
            lambda k: init_block(k, cfg, seg.kind, cross=cfg.enc_dec)
        )(keys)
        segments.append(stacked)
    params["segments"] = segments
    if cfg.shared_attn_every:
        params["shared_block"] = init_block(next(ks), cfg, BlockKind.DENSE)
    params["final_norm"] = _init_norm(cfg)
    if not cfg.tied_embeddings:
        params["lm_head"] = init_dense(next(ks), (cfg.d_model, cfg.vocab),
                                       ("embed_r", "vocab"))
    if cfg.mtp:
        params["mtp_block"] = init_block(next(ks), cfg, BlockKind.DENSE)
        params["mtp_norm"] = _init_norm(cfg)
    if cfg.enc_dec:
        enc_cfg = dataclasses.replace(cfg, attn=AttnKind.GQA,
                                      kv_heads=cfg.n_heads)
        keys = jax.random.split(next(ks), cfg.n_encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_block(k, enc_cfg, BlockKind.DENSE))(keys)
        params["enc_norm"] = _init_norm(cfg)
        params["cross_k"] = init_dense(
            next(ks), (cfg.d_model, cfg.kv_heads, cfg.resolved_head_dim),
            ("embed", "kv_heads", "head_dim"))
        params["cross_v"] = init_dense(
            next(ks), (cfg.d_model, cfg.kv_heads, cfg.resolved_head_dim),
            ("embed", "kv_heads", "head_dim"))
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _scan_segment(stacked, x, cfg, kind, *, positions, offset, cache,
                  cross_kv, causal, remat, pages=None):
    """cache: None | {"k","v"} stacked | SsmCache of stacked arrays.
    ``pages`` ([B, P] int32 page table) switches attention caches to the
    paged layout — the table is shared by every layer (same logical page
    geometry), only the pooled pages differ per layer."""
    is_ssm = kind is BlockKind.SSM

    def call(p, h, c):
        return block_apply(p, h, cfg, kind, positions=positions, cache=c,
                           cross_kv=cross_kv, causal=causal)

    if remat:
        call = jax.checkpoint(call)

    if cache is None:
        def body(carry, p):
            h, aux = carry
            y, _, a = call(p, h, None)
            return (y, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
        return x, None, aux

    def body(carry, xs):
        h, aux = carry
        p, craw = xs
        if is_ssm:
            c = craw
        elif pages is not None:
            c = PagedKVCache(craw["k"], craw["v"], pages, offset)
        else:
            c = KVCache(craw["k"], craw["v"], offset)
        y, new_c, a = call(p, h, c)
        if not is_ssm:
            new_c = {"k": new_c.k, "v": new_c.v}
        return (y, aux + a), new_c
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked, cache))
    return x, new_cache, aux


def encode(params: dict, frames: jnp.ndarray, cfg: ArchConfig):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    from .layers import sinusoidal_positions
    x = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(frames.shape[1])
    x, _, _ = _scan_segment(params["encoder"], x, cfg, BlockKind.DENSE,
                            positions=positions, offset=0, cache=None,
                            cross_kv=None, causal=False, remat=False)
    x = _norm(cfg, params["enc_norm"], x)
    k = dense(params["cross_k"], x, "btd,dhq->bthq")
    v = dense(params["cross_v"], x, "btd,dhq->bthq")
    return (k, v)


def forward(params: dict, batch: dict, cfg: ArchConfig, *,
            caches: list | None = None, cache_len: jnp.ndarray | None = None,
            dtype=jnp.bfloat16, remat: bool = False,
            pages: jnp.ndarray | None = None):
    """Returns (hidden [B,L,D], new_caches, aux_loss).

    batch: tokens [B, L]; optional vision_embeds [B, Tv, D] (prefix),
    encoder_frames [B, Te, D] or cross_kv (precomputed encoder output).
    ``pages`` ([B, P] int32): attention caches are the paged pools from
    :func:`init_paged_caches`, addressed through this per-slot page table
    (``cache_len`` must then be per-slot, [B] int32).

    Multi-token calls at nonzero per-slot ``cache_len`` are the
    suffix-only prefill (serve prefix cache): row b's L tokens sit at
    absolute positions ``cache_len[b] + t`` — positions drive RoPE and
    the causal mask, paged K/V scatters land past the resident prefix,
    and attention gathers the prefix pages through the table instead of
    recomputing them.  The speculative draft-k verify is the same call
    shape at decode time (L = k+1 at ``cache_len`` = the slot's live
    length): nothing in the stack distinguishes a prompt chunk from a
    draft window — the caller decides how far ``cache_len`` advances
    afterwards, which is what makes rollback free.
    """
    from ..distributed.act_sharding import constrain_btd
    tokens = batch["tokens"]
    x = constrain_btd(embed(params["embed"], tokens, dtype))
    if cfg.frontend.value == "vision" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(dtype)
        x = jnp.concatenate([ve, x[:, ve.shape[1]:]], axis=1)
    cross_kv = batch.get("cross_kv")
    if cfg.enc_dec and cross_kv is None and "encoder_frames" in batch:
        cross_kv = encode(params, batch["encoder_frames"].astype(dtype), cfg)

    length = x.shape[1]
    offset = cache_len if cache_len is not None else 0
    # per-slot cache depths (continuous batching): positions become [B, L]
    if getattr(offset, "ndim", 0) == 1:
        positions = offset[:, None] + jnp.arange(length)[None, :]
    else:
        positions = offset + jnp.arange(length)

    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    segs = cfg.resolved_segments()
    for i, seg in enumerate(segs):
        cache_i = caches[i] if caches is not None else None
        if seg.kind is BlockKind.SHARED_ATTN:
            c = None
            if cache_i is not None and pages is not None:
                c = PagedKVCache(cache_i["k"][0], cache_i["v"][0], pages,
                                 offset)
            elif cache_i is not None:
                c = KVCache(cache_i["k"][0], cache_i["v"][0], offset)
            y, nc, aux = block_apply(params["shared_block"], x, cfg,
                                     BlockKind.DENSE, positions=positions,
                                     cache=c, cross_kv=cross_kv)
            if cache_i is not None:
                nc = {"k": nc.k[None], "v": nc.v[None]}
            new_caches.append(nc)
        else:
            y, nc, aux = _scan_segment(
                params["segments"][i], x, cfg, seg.kind,
                positions=positions, offset=offset, cache=cache_i,
                cross_kv=cross_kv, causal=True, remat=remat, pages=pages)
            new_caches.append(nc)
        x = y
        aux_total = aux_total + aux
    x = _norm(cfg, params["final_norm"], x)
    return x, (new_caches if caches is not None else None), aux_total


def logits_fn(params: dict, hidden: jnp.ndarray, cfg: ArchConfig):
    if cfg.tied_embeddings:
        return unembed(params["embed"], hidden)
    return dense(params["lm_head"], hidden.astype(jnp.float32),
                 "btd,dv->btv")


# ---------------------------------------------------------------------------
# chunked LM loss
# ---------------------------------------------------------------------------

def chunked_xent(params: dict, hidden: jnp.ndarray, targets: jnp.ndarray,
                 cfg: ArchConfig, mask: jnp.ndarray | None = None,
                 chunk: int = LOSS_CHUNK, z_loss: float = 1e-4):
    """Cross-entropy without materializing [B, L, V]."""
    b, l, d = hidden.shape
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((b, l), bool), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, l), bool)
    hc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    tc = targets.reshape(b, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(carry, xs):
        from ..distributed.act_sharding import constrain
        loss_sum, count = carry
        h, t, m = xs
        h = constrain(h, ("batch", None, None))
        logits = logits_fn(params, h, cfg)              # [B, chunk, V] f32
        logits = constrain(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) + z_loss * jnp.square(lse)
        loss_sum = loss_sum + jnp.sum(nll * m)
        count = count + jnp.sum(m)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc, mc))
    return loss_sum / jnp.maximum(count, 1.0)


def lm_loss(params: dict, batch: dict, cfg: ArchConfig, *,
            dtype=jnp.bfloat16, remat: bool = False):
    """Next-token loss (+ optional deepseek-style MTP auxiliary loss)."""
    tokens = batch["tokens"]
    hidden, _, aux = forward(params, batch, cfg, dtype=dtype, remat=remat)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, dtype=bool).at[:, -1].set(False)
    loss = chunked_xent(params, hidden, targets, cfg, mask)
    if cfg.mtp:
        positions = jnp.arange(tokens.shape[1])
        h2, _, _ = block_apply(params["mtp_block"], hidden, cfg,
                               BlockKind.DENSE, positions=positions)
        h2 = _norm(cfg, params["mtp_norm"], h2)
        t2 = jnp.roll(tokens, -2, axis=1)
        m2 = mask.at[:, -2].set(False)
        loss = loss + 0.3 * chunked_xent(params, h2, t2, cfg, m2)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux
    return loss
