"""Mixture-of-Experts FFN with sort-based (MegaBlocks-style) dispatch.

Routing: softmax top-k with optional shared experts (DeepSeek-MoE style).
Dispatch is gather-based: token->expert assignments are sorted by expert,
each expert receives a fixed-capacity slice (overflow drops, standard
capacity-factor semantics), expert GEMMs run as one batched einsum over the
expert dimension (shardable on the "experts" logical axis = expert
parallelism), and outputs scatter-add back with routing weights.

This dispatch is exactly the paper's ss-gemm structure: a dense stationary
operand (expert weights) hit by a dynamically-sparse skinny operand (the
tokens routed to each expert).  The sparsity-aware PIM idea (§5.1.2 — skip
issuing work for zero operands) maps to skipping empty expert blocks; the
Pallas kernel in repro.kernels.moe_group_gemm implements that skip at tile
granularity, and the planner reports the expected win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import param as pm
from .layers import activation, init_dense, init_mlp, mlp
from ..configs.base import ArchConfig


def init_moe(key: jax.Array, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    out = {
        "router": init_dense(ks[0], (d, m.n_experts), ("embed", "experts"),
                             scale=0.02),
        "wi": pm.normal(ks[1], (m.n_experts, d, m.d_ff_expert),
                        ("experts", "embed", "mlp"),
                        stddev=pm.fanin_scale((d,))),
        "wg": pm.normal(ks[2], (m.n_experts, d, m.d_ff_expert),
                        ("experts", "embed", "mlp"),
                        stddev=pm.fanin_scale((d,))),
        "wo": pm.normal(ks[3], (m.n_experts, m.d_ff_expert, d),
                        ("experts", "mlp", "embed"),
                        stddev=pm.fanin_scale((m.d_ff_expert,))),
    }
    if m.n_shared_experts:
        shared_ff = m.d_ff_shared or m.d_ff_expert * m.n_shared_experts
        out["shared"] = init_mlp(ks[4], d, shared_ff, gated=True)
    return out


def route(params: dict, x2d: jnp.ndarray, cfg: ArchConfig):
    """x2d: [T, D] -> (weights [T,k], expert_ids [T,k], router probs)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)   # renormalize
    return w, ids, probs


def aux_load_balance_loss(probs: jnp.ndarray, ids: jnp.ndarray,
                          n_experts: int) -> jnp.ndarray:
    """Switch-style load-balance loss (density * router-prob product)."""
    density = jnp.mean(
        jax.nn.one_hot(ids, n_experts, dtype=jnp.float32), axis=(0, 1))
    prob_mass = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(density * prob_mass)


def _local_expert_ffn(x2d, ids, w, wi, wg, wo, *, e_local, top_k, capacity,
                      act, my_rank):
    """Per-device expert compute inside shard_map (§Perf iter 6).

    Activations are replicated across the model axis (batch-only
    sharding), so each model rank already holds every token: dispatch is
    *local* selection of the (token, choice) pairs that target this rank's
    experts — no data movement at all — followed by local expert GEMMs and
    a single psum combine.  This replaces the jit-auto plan whose combine
    and bookkeeping all-reduced terabytes per step (see EXPERIMENTS §Perf).
    """
    t, d = x2d.shape
    flat_ids = ids.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    flat_w = w.reshape(-1)
    mine = (flat_ids // e_local) == my_rank
    local_ids = jnp.where(mine, flat_ids % e_local, e_local)
    onehot = jax.nn.one_hot(local_ids, e_local, dtype=jnp.int32)
    cum = jnp.cumsum(onehot, axis=0)
    rank = jnp.take_along_axis(
        cum, jnp.minimum(local_ids, e_local - 1)[:, None], axis=1)[:, 0] - 1
    keep = mine & (rank < capacity)
    slot = jnp.where(keep, local_ids * capacity + rank, e_local * capacity)
    buf_tok = jnp.full((e_local * capacity + 1,), t, dtype=jnp.int32)
    buf_tok = buf_tok.at[slot].set(flat_tok.astype(jnp.int32),
                                   mode="drop")[:-1]
    buf_w = jnp.zeros((e_local * capacity + 1,), dtype=w.dtype)
    buf_w = buf_w.at[slot].set(flat_w, mode="drop")[:-1]
    xpad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xe = xpad[buf_tok].reshape(e_local, capacity, d)
    h = act(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wi)
    ye = jnp.einsum("ecf,efd->ecd", h, wo)
    y2d = jnp.zeros((t + 1, d), ye.dtype)
    y2d = y2d.at[buf_tok].add(
        ye.reshape(-1, d) * buf_w[:, None].astype(ye.dtype))
    return y2d[:t]


def _moe_shard_map(params, x, cfg, act_name, mesh):
    """shard_map MoE: local dispatch + expert GEMMs + one psum/layer."""
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    b, l, d = x.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    e_local = m.n_experts // sizes["model"]
    weights, ids, probs = route(params, x.reshape(-1, d), cfg)
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names and sizes[a] > 1)
    n_batch = 1
    for a in batch_axes:
        n_batch *= sizes[a]
    if b % max(1, n_batch):
        batch_axes, n_batch = (), 1          # replicate small batches
    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    t_local = (b // n_batch) * l
    capacity = max(1, int(t_local * m.top_k * m.capacity_factor
                          / m.n_experts))
    act = activation(act_name)

    def body(x_blk, ids_blk, w_blk, wi, wg, wo):
        t_loc = x_blk.shape[0] * x_blk.shape[1]
        y = _local_expert_ffn(
            x_blk.reshape(t_loc, d), ids_blk.reshape(t_loc, m.top_k),
            w_blk.reshape(t_loc, m.top_k), wi, wg, wo,
            e_local=e_local, top_k=m.top_k, capacity=capacity, act=act,
            my_rank=jax.lax.axis_index("model"))
        y = jax.lax.psum(y, axis_name="model")
        return y.reshape(x_blk.shape)

    sm = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(bspec, None, None),
        check_vma=False)
    y = sm(x, ids.reshape(b, l, m.top_k).astype(jnp.int32),
           weights.reshape(b, l, m.top_k).astype(x.dtype),
           params["wi"].astype(x.dtype), params["wg"].astype(x.dtype),
           params["wo"].astype(x.dtype))
    if "shared" in params:
        y = y + mlp(params["shared"], x, act_name)
    aux = aux_load_balance_loss(probs, ids, m.n_experts)
    return y.astype(x.dtype), aux


def moe_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
              act_name: str = "silu"):
    """x: [B, L, D] -> (y, aux_loss)."""
    m = cfg.moe
    b, l, d = x.shape
    t = b * l
    # §Perf iter 6: shard_map fast path when a mesh policy is active and
    # experts divide the model axis.  Token-count gate (iter 6 addendum):
    # at decode-sized batches the expert *weights* dominate the traffic —
    # shard_map's materialized [E_local, D, F] weights would force an
    # FSDP gather per step (measured +540 ms on deepseek decode), while
    # XLA's auto plan keeps the skinny GEMM distributed over both axes.
    from ..distributed.act_sharding import _ACTIVE
    mesh = _ACTIVE.get()
    if (mesh is not None and "model" in mesh.axis_names and t >= 4096
            and m.n_experts % dict(zip(mesh.axis_names,
                                       mesh.devices.shape))["model"] == 0):
        return _moe_shard_map(params, x, cfg, act_name, mesh)
    x2d = x.reshape(t, d)
    w, ids, probs = route(params, x2d, cfg)
    k = m.top_k
    e = m.n_experts
    capacity = max(1, int(t * k * m.capacity_factor / e))

    # --- rank-based dispatch (§Perf iter 4/5a) -------------------------------
    # Position-in-expert via a cumsum over token-major one-hot assignments:
    # sharding-friendly (a cumsum along the sharded token axis lowers to a
    # local scan + a tiny carry exchange), unlike the argsort dispatch,
    # whose global sort re-gathered activations every MoE layer in the
    # baseline dry-run.
    flat_ids = ids.reshape(-1)                        # [T*k], token-major
    flat_tok = jnp.repeat(jnp.arange(t), k)           # source token per slot
    flat_w = w.reshape(-1)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)   # [T*k, E]
    cum = jnp.cumsum(onehot, axis=0)
    rank = jnp.take_along_axis(cum, flat_ids[:, None], axis=1)[:, 0] - 1
    keep = rank < capacity                            # drop overflow
    slot = jnp.where(keep, flat_ids * capacity + rank, e * capacity)

    # token index per (expert, capacity) slot; padded slots point at a
    # zero row appended to x.
    buf_tok = jnp.full((e * capacity + 1,), t, dtype=jnp.int32)
    buf_tok = buf_tok.at[slot].set(flat_tok.astype(jnp.int32),
                                   mode="drop")[:-1]
    buf_w = jnp.zeros((e * capacity + 1,), dtype=w.dtype)
    buf_w = buf_w.at[slot].set(flat_w, mode="drop")[:-1]

    xpad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xe = xpad[buf_tok].reshape(e, capacity, d)        # gather  [E, C, D]
    # §Perf iter 4: pin dispatch buffers to expert (model-axis) sharding so
    # the token gather lowers to expert-parallel dispatch traffic
    # (tokens x top_k x d moving once) instead of re-gathering the full
    # activation per MoE layer (the collective-bound baseline).
    from ..distributed.act_sharding import constrain
    xe = constrain(xe, ("experts", None, None))

    # --- expert GEMMs (expert dim shardable -> EP) --------------------------
    act = activation(act_name)
    wi = params["wi"].astype(x.dtype)
    wg = params["wg"].astype(x.dtype)
    wo = params["wo"].astype(x.dtype)
    h = act(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wi)
    h = constrain(h, ("experts", None, "mlp"))
    ye = jnp.einsum("ecf,efd->ecd", h, wo)            # [E, C, D]
    ye = constrain(ye, ("experts", None, None))

    # --- weighted combine ----------------------------------------------------
    y2d = jnp.zeros((t + 1, d), ye.dtype)
    y2d = y2d.at[buf_tok].add(ye.reshape(e * capacity, d)
                              * buf_w[:, None].astype(ye.dtype))
    y = y2d[:t].reshape(b, l, d)

    if "shared" in params:
        y = y + mlp(params["shared"], x, act_name)
    aux = aux_load_balance_loss(probs, ids, e)
    return y.astype(x.dtype), aux
