"""Attention: GQA (train / prefill / decode with KV cache) and MLA.

The softmax attention core is blockwise (nested lax.scan over query and key
blocks with an online softmax) whenever the score matrix would be large —
the flash pattern keeps both compiled-HLO size and activation memory O(1)
in sequence length, which matters for the 32k prefill dry-run cells.

GQA never materializes repeated KV heads: queries are reshaped to
[B, L, kv_heads, group, D] and contracted against the unexpanded KV.

MLA (deepseek-v3) follows arXiv:2412.19437: low-rank compressed KV latent
(c_kv, plus a shared RoPE key), low-rank Q; the decode path uses the
*absorbed* form — queries are projected into latent space so the cache
holds only [L, kv_lora + rope_dim] per token.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import param as pm
from .layers import apply_rope, dense, init_dense, init_rmsnorm, rmsnorm
from ..configs.base import ArchConfig

BLOCK_Q = 512
BLOCK_K = 1024
_DENSE_LIMIT = 4096 * 4096   # score elems (per head) above which we go blockwise


class KVCache(NamedTuple):
    k: jnp.ndarray           # [B, S, Hkv, D] (or latent for MLA)
    v: jnp.ndarray           # [B, S, Hkv, D] (or rope-key for MLA)
    length: jnp.ndarray      # [] int32: tokens filled


class PagedKVCache(NamedTuple):
    """Paged layout: K/V pages live in one pooled allocation shared by all
    slots; ``table`` names each slot's pages in order (entries >= n_pages
    are unallocated — scatters through them drop, reads clamp + mask)."""
    k: jnp.ndarray           # [n_pages, page_size, Hkv, D] (latent for MLA)
    v: jnp.ndarray           # [n_pages, page_size, Hkv, D] (rope-key MLA)
    table: jnp.ndarray       # [B, P] int32 page ids
    length: jnp.ndarray      # [B] int32: tokens filled per slot


# --------------------------------------------------------------------------
# softmax attention cores
# --------------------------------------------------------------------------

def _dense_attn(q, k, v, *, causal: bool, q_offset, kv_len=None):
    """q: [B,Lq,Hkv,G,D], k/v: [B,Lk,Hkv,D].  ``q_offset``/``kv_len`` may
    be per-slot vectors ([B] int32) for continuous-batching decode, where
    each batch row sits at its own depth into the cache."""
    b, lq, hkv, g, d = q.shape
    lk = k.shape[1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) / math.sqrt(d)
    scores = scores.astype(jnp.float32)
    kpos = jnp.arange(lk)
    off = jnp.asarray(q_offset)
    vec = off.ndim == 1 or (kv_len is not None and jnp.ndim(kv_len) == 1)
    if vec:
        off_b = off if off.ndim == 1 else jnp.broadcast_to(off, (b,))
        qpos = off_b[:, None, None] + jnp.arange(lq)[:, None]   # [B,Lq,1]
        mask = jnp.ones((b, lq, lk), dtype=bool)
        if causal:
            mask &= kpos[None, None, :] <= qpos
        if kv_len is not None:
            kvl = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
            mask &= kpos[None, None, :] < kvl[:, None, None]
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    else:
        qpos = jnp.arange(lq)[:, None] + off
        mask = jnp.ones((lq, lk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def _blockwise_attn(q, k, v, *, causal: bool, q_offset):
    """Flash-style online-softmax attention, O(block) memory."""
    b, lq, hkv, g, d = q.shape
    lk = k.shape[1]
    dv = v.shape[-1]
    bq, bk = min(BLOCK_Q, lq), min(BLOCK_K, lk)
    nq, nk = -(-lq // bq), -(-lk // bk)
    qpad, kpad = nq * bq - lq, nk * bk - lk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    qb = q.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, bk, hkv, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, bk, hkv, dv).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / math.sqrt(d)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk

        def k_step(carry, ki_blk):
            m, l, acc = carry
            ki, kblk, vblk = ki_blk
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk) * scale
            s = s.astype(jnp.float32)
            qpos = qi * bq + jnp.arange(bq)[:, None] + q_offset
            kpos = ki * bk + jnp.arange(bk)[None, :]
            mask = kpos < lk
            if causal:
                mask &= kpos <= qpos
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(qblk.dtype), vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * bq, hkv, g, dv)
    return out[:, :lq]


def _cache_insert(buf: jnp.ndarray, vals: jnp.ndarray, length) -> jnp.ndarray:
    """Write ``vals`` [B, L, ...] into ``buf`` [B, S, ...] starting at
    ``length`` per row.  Scalar lengths use a dynamic slice (one shared
    offset); vector lengths ([B]) scatter per slot — the continuous-batching
    case where each slot is at its own depth.  Out-of-range rows drop."""
    vals = vals.astype(buf.dtype)
    ln = jnp.asarray(length)
    if ln.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, vals, ln, axis=1)
    b, l = vals.shape[:2]
    bidx = jnp.arange(b)[:, None]
    pos = ln[:, None] + jnp.arange(l)[None, :]
    return buf.at[bidx, pos].set(vals, mode="drop")


def _paged_insert(pool: jnp.ndarray, vals: jnp.ndarray,
                  table: jnp.ndarray, length: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``vals`` [B, L, ...] into the page pool [N, ps, ...]:
    row b's token at sequence position ``length[b] + t`` lands in page
    ``table[b, (length[b] + t) // ps]`` at offset ``% ps``.  Positions
    whose logical page is unallocated (sentinel id >= N) or beyond the
    table width drop — exactly the dense path's out-of-range semantics,
    and how a join masks non-joining rows out of a shared prefill.

    Because the write address is purely position-indexed, the insert is
    **rollback-safe** for speculative decoding: a verify writes k+1 rows
    at ``length .. length + k``, and if only ``a`` of them commit the
    caller simply advances ``length`` by ``a`` — the stale rows above
    the acceptance point are unreachable (every later read is causally
    masked at the new length) and the next verify's scatter, starting at
    the new length, overwrites them.  The scheduler reserves the k-row
    overhang at admission so these writes never land past the slot's
    pages (a dropped write would make a *accepted* draft read garbage)."""
    vals = vals.astype(pool.dtype)
    n, ps = pool.shape[0], pool.shape[1]
    b, l = vals.shape[:2]
    p_max = table.shape[1]
    pos = jnp.asarray(length, jnp.int32)[:, None] + jnp.arange(l)[None, :]
    logical = pos // ps                                        # [B, L]
    bidx = jnp.arange(b)[:, None]
    page = jnp.where(logical < p_max,
                     table[bidx, jnp.minimum(logical, p_max - 1)], n)
    flat_vals = vals.reshape((b * l,) + vals.shape[2:])
    return pool.at[page.reshape(-1), (pos % ps).reshape(-1)].set(
        flat_vals, mode="drop")


def _paged_gather(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Pages -> contiguous [B, P * ps, ...] view for the XLA attention
    path (sentinels clamp; callers mask by per-slot length)."""
    from ..kernels.paged_attn import gather_pages
    return gather_pages(pool, table)


def _paged_prefill_route(q, cache: "PagedKVCache", q_offset, kv_len):
    """Route multi-token GQA queries over paged KV through the kernel
    package's prefill path: each row's queries sit at its own depth
    ``q_offset`` (0 for a fresh prompt; the resident-prefix length for a
    suffix-only or chunked prefill, where the gather reads shared prefix
    pages — and earlier chunks — in place instead of recomputing them;
    the *decode-time* ``lengths`` for a speculative draft-k verify,
    whose Lq = k+1 block of current-token + drafts is the same causal
    query-block-at-depth — see ``kernels.paged_attn.paged_verify_attn``).
    The op resolves kernel-vs-XLA by the active DecodeAttnPolicy: the
    Pallas flash-prefill kernel on real TPU backends, the gather ref
    elsewhere."""
    from ..kernels.paged_attn import paged_prefill_attn
    return paged_prefill_attn(q, cache.k, cache.v, cache.table,
                              q_offset, kv_len)


def _paged_kernel_route(q, cache: "PagedKVCache", kv_len, dtype):
    """Route one-token GQA decode through the paged Pallas kernel.  The
    grid is the table width — the engine slices the table to its
    page-count bucket, so dead pages are never launched."""
    from ..kernels.paged_attn import paged_attn
    pol = _decode_policy()
    out = paged_attn(q[:, 0], cache.k.astype(dtype), cache.v.astype(dtype),
                     cache.table, kv_len,
                     interpret=pol.resolve_interpret())
    return out[:, None]


def _decode_kernel_route(q, kc, vc, kv_len, dtype):
    """Route one-token GQA decode attention through the Pallas kernel when
    the active policy asks for it.  q: [B,1,Hq,D] -> [B,1,Hq,D].  The
    caller has already applied the policy's kv_cap slice to kc/vc."""
    from ..kernels.decode_attn import decode_attn
    pol = _decode_policy()
    out = decode_attn(q[:, 0], kc.astype(dtype), vc.astype(dtype), kv_len,
                      bs=pol.block_size, interpret=pol.resolve_interpret())
    return out[:, None]


def _decode_policy():
    from ..kernels.decode_attn import active_policy
    return active_policy()


def attention_core(q, k, v, *, causal: bool, q_offset=0, kv_len=None):
    """q: [B,Lq,Hq,D], k: [B,Lk,Hkv,D], v: [B,Lk,Hkv,Dv] (Dv may differ,
    e.g. MLA latents); returns [B,Lq,Hq,Dv]."""
    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, lq, hkv, g, d)
    if kv_len is None and lq * k.shape[1] > _DENSE_LIMIT:
        out = _blockwise_attn(qg, k, v, causal=causal, q_offset=q_offset)
    else:
        out = _dense_attn(qg, k, v, causal=causal, q_offset=q_offset,
                          kv_len=kv_len)
    return out.reshape(b, lq, hq, v.shape[-1])


# --------------------------------------------------------------------------
# GQA layer
# --------------------------------------------------------------------------

def init_gqa(key: jax.Array, cfg: ArchConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        "q": init_dense(ks[0], (d, hq, hd), ("embed", "heads", "head_dim"),
                        bias=bias, bias_axes=("heads", "head_dim")),
        "k": init_dense(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim"),
                        bias=bias, bias_axes=("kv_heads", "head_dim")),
        "v": init_dense(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim"),
                        bias=bias, bias_axes=("kv_heads", "head_dim")),
        "o": init_dense(ks[3], (hq, hd, d), ("heads", "head_dim", "embed"),
                        scale=pm.fanin_scale((hq * hd,))),
    }


def gqa_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig, *,
              positions: jnp.ndarray, causal: bool = True,
              cache: KVCache | None = None,
              kv_override: tuple | None = None):
    """x: [B, L, D].  With ``cache``, appends this call's K/V at
    cache.length and attends over the filled prefix (decode/prefill-chunk).
    ``kv_override`` (k, v) turns this layer into cross-attention."""
    from ..distributed.act_sharding import (constrain, constrain_btd,
                                            context_shard_wanted)
    ctx_shard = context_shard_wanted(cfg.n_heads, x.shape[1])
    if ctx_shard:
        # context parallelism: q path seq-sharded; kv replicated (gathered)
        x = constrain(x, ("batch", "ctx", None))
    q = dense(params["q"], x, "btd,dhq->bthq")
    if kv_override is None:
        k = dense(params["k"], x, "btd,dhq->bthq")
        v = dense(params["v"], x, "btd,dhq->bthq")
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    new_cache = None
    if isinstance(cache, PagedKVCache) and kv_override is None:
        kp = _paged_insert(cache.k, k, cache.table, cache.length)
        vp = _paged_insert(cache.v, v, cache.table, cache.length)
        kv_len = cache.length + x.shape[1]
        new_cache = PagedKVCache(kp, vp, cache.table, kv_len)
        pol = _decode_policy()
        if x.shape[1] == 1 and not ctx_shard and pol.kernel_wanted():
            out = _paged_kernel_route(q, new_cache, kv_len, x.dtype)
        else:
            out = _paged_prefill_route(q, new_cache, cache.length, kv_len)
    elif cache is not None and kv_override is None:
        kc = _cache_insert(cache.k, k, cache.length)
        vc = _cache_insert(cache.v, v, cache.length)
        kv_len = cache.length + x.shape[1]
        new_cache = KVCache(kc, vc, kv_len)
        pol = _decode_policy()
        if pol.kv_cap is not None and pol.kv_cap < kc.shape[1]:
            # grid pruning: the engine bounds the deepest live slot between
            # scan segments, so dead KV blocks never enter the attention op
            kc, vc = kc[:, :pol.kv_cap], vc[:, :pol.kv_cap]
        if x.shape[1] == 1 and not ctx_shard and pol.kernel_wanted():
            out = _decode_kernel_route(q, kc, vc, kv_len, x.dtype)
        else:
            # causal w.r.t. absolute positions (needed for multi-token
            # prefill; no-op for single-token decode where the query is the
            # last position)
            out = attention_core(q, kc.astype(x.dtype), vc.astype(x.dtype),
                                 causal=True, q_offset=cache.length,
                                 kv_len=kv_len)
    else:
        if ctx_shard:
            q = constrain(q, ("batch", "ctx", None, None))
            k = constrain(k, ("batch", None, None, None))
            v = constrain(v, ("batch", None, None, None))
        out = attention_core(q, k, v, causal=causal)
    y = dense(params["o"], out, "bthq,hqd->btd")
    if ctx_shard:
        y = constrain_btd(y)
    return y, new_cache


# --------------------------------------------------------------------------
# MLA layer (deepseek-v3)
# --------------------------------------------------------------------------

def init_mla(key: jax.Array, cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "dq": init_dense(ks[0], (d, m.q_lora_rank), ("embed", "q_lora")),
        "dq_norm": init_rmsnorm(m.q_lora_rank),
        "uq": init_dense(ks[1], (m.q_lora_rank, h, qk + m.qk_rope_head_dim),
                         ("q_lora", "heads", "head_dim")),
        "dkv": init_dense(ks[2], (d, m.kv_lora_rank), ("embed", "kv_lora")),
        "dkv_norm": init_rmsnorm(m.kv_lora_rank),
        "kr": init_dense(ks[3], (d, m.qk_rope_head_dim),
                         ("embed", "head_dim")),
        "uk": init_dense(ks[4], (m.kv_lora_rank, h, qk),
                         ("kv_lora", "heads", "head_dim")),
        "uv": init_dense(ks[5], (m.kv_lora_rank, h, m.v_head_dim),
                         ("kv_lora", "heads", "head_dim")),
        "o": init_dense(ks[6], (h, m.v_head_dim, d),
                        ("heads", "head_dim", "embed"),
                        scale=pm.fanin_scale((h * m.v_head_dim,))),
    }


def _mla_qkr(params, x, cfg, positions):
    m = cfg.mla
    cq = rmsnorm(params["dq_norm"], dense(params["dq"], x, "btd,dr->btr"),
                 cfg.norm_eps)
    q = dense(params["uq"], cq, "btr,rhq->bthq")
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    c_kv = rmsnorm(params["dkv_norm"], dense(params["dkv"], x, "btd,dr->btr"),
                   cfg.norm_eps)
    k_rope = apply_rope(dense(params["kr"], x, "btd,dq->btq")[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig, *,
              positions: jnp.ndarray, causal: bool = True,
              cache: KVCache | None = None):
    """MLA with the absorbed decode path: the cache stores the compressed
    latent (c_kv) and the shared rope key only."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, cfg, positions)
    # absorb W_uk into the query: q_lat [B,L,H,kv_lora]
    q_lat = jnp.einsum("bthq,rhq->bthr", q_nope,
                       params["uk"]["w"].astype(x.dtype))
    new_cache = None
    if isinstance(cache, PagedKVCache):
        ckv_p = _paged_insert(cache.k, c_kv, cache.table, cache.length)
        kr_p = _paged_insert(cache.v, k_rope, cache.table, cache.length)
        kv_len = cache.length + x.shape[1]
        new_cache = PagedKVCache(ckv_p, kr_p, cache.table, kv_len)
        # MLA's absorbed decode is already a latent gather; the paged path
        # stays on the XLA gather (no per-head pages to walk in the kernel)
        c_kv_all = _paged_gather(ckv_p, cache.table).astype(x.dtype)
        k_rope_all = _paged_gather(kr_p, cache.table).astype(x.dtype)
        q_offset = cache.length
        causal_here = True
    elif cache is not None:
        ckv_c = _cache_insert(cache.k, c_kv, cache.length)
        kr_c = _cache_insert(cache.v, k_rope, cache.length)
        new_cache = KVCache(ckv_c, kr_c, cache.length + x.shape[1])
        pol = _decode_policy()
        if pol.kv_cap is not None and pol.kv_cap < ckv_c.shape[1]:
            ckv_c, kr_c = ckv_c[:, :pol.kv_cap], kr_c[:, :pol.kv_cap]
        c_kv_all, k_rope_all = ckv_c.astype(x.dtype), kr_c.astype(x.dtype)
        kv_len = cache.length + x.shape[1]
        q_offset = cache.length
        causal_here = True
    else:
        c_kv_all, k_rope_all = c_kv, k_rope
        kv_len = None
        q_offset = 0
        causal_here = causal
    # latent attention: keys are [c_kv ; k_rope], queries [q_lat ; q_rope]
    k_full = jnp.concatenate(
        [c_kv_all, k_rope_all], axis=-1)[:, :, None, :]     # [B,S,1,r+rope]
    q_full = jnp.concatenate([q_lat, q_rope], axis=-1)      # [B,L,H,r+rope]
    scale_fix = math.sqrt(q_full.shape[-1]) / math.sqrt(
        m.qk_nope_head_dim + m.qk_rope_head_dim)
    out_lat = attention_core(q_full * scale_fix, k_full,
                             c_kv_all[:, :, None, :],
                             causal=causal_here, q_offset=q_offset,
                             kv_len=kv_len)                  # [B,L,H,kv_lora]
    out = jnp.einsum("bthr,rhv->bthv", out_lat,
                     params["uv"]["w"].astype(x.dtype))
    y = dense(params["o"], out, "bthv,hvd->btd")
    return y, new_cache


def init_attention(key: jax.Array, cfg: ArchConfig) -> dict:
    from ..configs.base import AttnKind
    if cfg.attn is AttnKind.MLA:
        return init_mla(key, cfg)
    return init_gqa(key, cfg)


def attention_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig, **kw):
    from ..configs.base import AttnKind
    if cfg.attn is AttnKind.MLA:
        return mla_apply(params, x, cfg, **kw)
    return gqa_apply(params, x, cfg, **kw)
