from .pipeline import DataConfig, DataPipeline  # noqa: F401
