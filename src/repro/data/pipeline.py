"""Deterministic, sharded, checkpointable synthetic LM data pipeline.

Design constraints it satisfies (1000-node posture):

* **Determinism**: batch content is a pure function of (seed, step,
  shard) — any worker can reproduce any batch, so restarts and elastic
  re-sharding never replay or skip data.
* **Sharding**: each data-parallel rank draws only its slice; re-sharding
  to a different rank count re-partitions the same global stream.
* **Checkpointability**: pipeline state is just the step counter —
  persisted with the model checkpoint and restored exactly.
* **Prefetch**: a background thread keeps ``prefetch`` batches ready so
  host data work overlaps device steps.

The token stream is a mixture of zipf-distributed unigrams and repeated
n-gram motifs (so models have actual structure to learn in the examples —
loss decreases measurably, unlike uniform noise).
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    zipf_alpha: float = 1.1
    motif_len: int = 8
    n_motifs: int = 64


class DataPipeline:
    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.cfg = cfg
        self.step = 0
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        rng = np.random.default_rng(cfg.seed)
        # fixed motif table (deterministic across workers)
        self._motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_alpha
        self._p = p / p.sum()

    # ------------------------------------------------------------------
    def batch_at(self, step: int) -> dict:
        """The globally-agreed batch for ``step``, sliced to this shard."""
        cfg = self.cfg
        per_shard = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.shard))
        toks = rng.choice(cfg.vocab, size=(per_shard, cfg.seq_len),
                          p=self._p)
        # stamp motifs: learnable repeated structure
        n_stamps = cfg.seq_len // (cfg.motif_len * 4)
        for b in range(per_shard):
            ids = rng.integers(0, cfg.n_motifs, size=n_stamps)
            pos = rng.integers(0, cfg.seq_len - cfg.motif_len,
                               size=n_stamps)
            for m, p0 in zip(ids, pos):
                toks[b, p0:p0 + cfg.motif_len] = self._motifs[m]
        return {"tokens": toks.astype(np.int32)}

    # ------------------------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    # ------------------------------------------------------------------
    def start_prefetch(self) -> None:
        def worker():
            step = self.step
            while not self._stop.is_set():
                try:
                    self._queue.put((step, self.batch_at(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_prefetched(self) -> dict:
        step, batch = self._queue.get()
        self.step = step + 1
        return batch

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    # ------------------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])

    def reshard(self, n_shards: int, shard: int) -> "DataPipeline":
        """Elastic re-sharding: same stream, new partition."""
        cfg = dataclasses.replace(self.cfg, n_shards=n_shards, shard=shard)
        p = DataPipeline(cfg)
        p.step = self.step
        return p
