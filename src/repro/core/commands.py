"""pim-command intermediate representation.

The paper's execution model (§4.1): a *pim-kernel* issues *pim-instructions*
which become *pim-commands* enqueued at the memory controller.  Broadcast
(multi-bank) commands execute the same operation on every bank of an even or
odd subset of a pseudo-channel and are issued **in FIFO order** at half the
regular column-command rate (tCCDL, footnote 3).  Single-bank commands can be
freely reordered and issue at the regular rate (tCCDS).

Real streams for realistic problem sizes contain billions of commands, so the
IR is *loop-compressed*: a stream is a list of :class:`Seg` segments, each a
run of ``count`` identical-cost commands, wrapped into :class:`Loop` bodies
that the timing engine evaluates in steady state instead of unrolling.  This
keeps the analytical model exact for cyclic schedules while evaluating in
microseconds.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Sequence, Union


class Kind(enum.Enum):
    # Row management.  ACT covers precharge+activate of a *new* row.
    ACT = "act"                 # activate a row in every bank of `subset`
    # Broadcast (multi-bank) compute / data-movement commands: one command
    # drives all PIM units of `subset` (8 banks).  Covers pim-ld (DRAM->reg),
    # pim-op reg op= DRAM/imm, pim-st (reg->DRAM): identical cost.
    PIM_BCAST = "pim_bcast"
    # Single-bank pim-commands (push-primitive style).  `carries_data` tells
    # whether the command moves an operand over the data bus (pim-ADD does,
    # pim-store does not — §5.2.3's command-bandwidth discussion).
    PIM_SB = "pim_sb"
    # Regular (non-PIM) column read/write, one bank, 32 B.
    RD = "rd"
    WR = "wr"


class Subset(enum.Enum):
    EVEN = "even"
    ODD = "odd"
    ALL = "all"    # ACT only: the baseline all-bank activation


@dataclasses.dataclass(frozen=True)
class Seg:
    """``count`` consecutive commands of one kind/subset.

    For ``Kind.ACT``, ``count`` is the number of successive *row switches*
    performed by this segment (each to a fresh row).
    """

    kind: Kind
    subset: Subset = Subset.ALL
    count: int = 1
    carries_data: bool = True     # PIM_SB only
    row_hit_frac: float = 0.0     # PIM_SB only: fraction needing no ACT

    def __post_init__(self) -> None:
        if self.count < 0:
            raise ValueError("negative segment count")
        if self.kind is Kind.PIM_BCAST and self.subset is Subset.ALL:
            raise ValueError("broadcast commands target an even/odd subset")


@dataclasses.dataclass(frozen=True)
class Loop:
    """``body`` repeated ``trips`` times (steady-state evaluated)."""

    body: Sequence["Node"]
    trips: int

    def __post_init__(self) -> None:
        if self.trips < 0:
            raise ValueError("negative trip count")


Node = Union[Seg, Loop]


def total_commands(nodes: Iterable[Node]) -> int:
    """Exact command count of a compressed stream (ACT counts as issued)."""
    n = 0
    for node in nodes:
        if isinstance(node, Seg):
            n += node.count
        else:
            n += node.trips * total_commands(node.body)
    return n


def total_by_kind(nodes: Iterable[Node]) -> dict[Kind, int]:
    out: dict[Kind, int] = {k: 0 for k in Kind}

    def rec(ns: Iterable[Node], mult: int) -> None:
        for node in ns:
            if isinstance(node, Seg):
                out[node.kind] += mult * node.count
            else:
                rec(node.body, mult * node.trips)

    rec(nodes, 1)
    return out


def flatten(nodes: Iterable[Node], max_commands: int = 2_000_000) -> list[Seg]:
    """Fully unroll a stream (tests / small problems only)."""
    out: list[Seg] = []

    def rec(ns: Iterable[Node]) -> None:
        for node in ns:
            if isinstance(node, Seg):
                out.append(node)
            else:
                for _ in range(node.trips):
                    rec(node.body)
            if sum(s.count for s in out) > max_commands:
                raise ValueError("stream too large to flatten")

    rec(nodes)
    return out
