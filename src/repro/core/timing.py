"""Analytical PIM timing engine (paper §4.3.1, "PIM Performance Model").

The engine evaluates a loop-compressed pim-command stream
(:mod:`repro.core.commands`) against DRAM timing (:class:`PimSpec`) for one
pseudo-channel; primitives are data-parallel across pseudo-channels, so the
stream generators divide the problem by ``pch_per_stack`` and stack time
equals pCH time.

Semantics implemented (all from §2.2/§4.1/§4.3.1 of the paper):

* Broadcast (multi-bank) pim-commands issue **in order** at one per
  ``tCCDL`` — half the regular rate (footnote 3) — and cannot issue until
  the target even/odd bank-subset's row is open.  A blocked head-of-line
  command stalls everything behind it.
* ``ACT`` covers precharge+activate of a fresh row in all banks of its
  subset.  Precharge may not start until ``tRAS`` after that subset's
  previous activation; data is available ``tRP + tRCD`` later.  Issuing the
  ACT consumes one regular command slot (``tCCDS``); once issued, *younger
  commands to the other subset keep issuing* — this is what the
  architecture-aware schedule (§5.1.1) exploits by activating one subset
  while the other computes.
* Single-bank pim-commands are freely reorderable (§4.3.1) and are modeled
  in aggregate as the max of three throughput limits: command-bus slots
  (``tCCDS / command_bw_mult`` each — §5.1.4's limit-study knob applies to
  data-less commands such as pim-store), data-bus slots (``tCCDS`` per
  operand-carrying command), and per-bank row-activation throughput
  (``tRC / banks_per_pch`` per activating command).

Loops are evaluated in steady state: the body is simulated twice and the
per-trip delta of the second (warmed-up) iteration is extrapolated, which is
exact for cyclic schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .commands import Kind, Loop, Node, Seg, Subset
from .hwspec import PimSpec


@dataclasses.dataclass
class _State:
    t: float = 0.0                      # next free issue slot on the bus
    row_ready_even: float = 0.0         # when EVEN subset's open row is usable
    row_ready_odd: float = 0.0
    last_act_even: float = -1e18        # last ACT (for tRAS window)
    last_act_odd: float = -1e18

    def copy(self) -> "_State":
        return dataclasses.replace(self)


@dataclasses.dataclass
class TimingStats:
    """Execution-time breakdown for one pCH (== one stack, data-parallel)."""

    time_ns: float = 0.0
    act_stall_ns: float = 0.0           # compute head blocked on row-open
    bcast_issue_ns: float = 0.0         # broadcast command slots
    sb_time_ns: float = 0.0             # single-bank aggregate time
    n_cmds: int = 0
    n_acts: int = 0

    def add(self, other: "TimingStats", mult: float = 1.0) -> None:
        self.time_ns += mult * other.time_ns
        self.act_stall_ns += mult * other.act_stall_ns
        self.bcast_issue_ns += mult * other.bcast_issue_ns
        self.sb_time_ns += mult * other.sb_time_ns
        self.n_cmds += int(mult * other.n_cmds)
        self.n_acts += int(mult * other.n_acts)

    @property
    def act_stall_frac(self) -> float:
        return self.act_stall_ns / self.time_ns if self.time_ns else 0.0


class PimTimer:
    def __init__(self, spec: PimSpec):
        self.spec = spec

    # ------------------------------------------------------------------
    def simulate(self, stream: Sequence[Node]) -> TimingStats:
        state = _State()
        stats = TimingStats()
        self._run(list(stream), state, stats)
        stats.time_ns = state.t
        return stats

    # ------------------------------------------------------------------
    def _run(self, nodes: Sequence[Node], st: _State, stats: TimingStats) -> None:
        i = 0
        while i < len(nodes):
            node = nodes[i]
            if isinstance(node, Seg) and node.kind is Kind.PIM_SB:
                # Coalesce adjacent single-bank segments: they interleave
                # freely, so their three throughput limits combine.
                j = i
                segs = []
                while j < len(nodes) and isinstance(nodes[j], Seg) \
                        and nodes[j].kind is Kind.PIM_SB:
                    segs.append(nodes[j])
                    j += 1
                self._run_sb(segs, st, stats)
                i = j
            elif isinstance(node, Seg):
                self._run_seg(node, st, stats)
                i += 1
            else:
                self._run_loop(node, st, stats)
                i += 1

    # ------------------------------------------------------------------
    MAX_WARMUP = 8

    def _run_loop(self, loop: Loop, st: _State, stats: TimingStats) -> None:
        if loop.trips == 0:
            return
        if loop.trips <= 2:
            for _ in range(loop.trips):
                self._run(loop.body, st, stats)
            return
        # Warm up until the per-trip delta converges (tRAS window chains
        # can take a few trips to reach steady state), then extrapolate.
        done = 0
        prev_dt = None
        s_last = TimingStats()
        while done < min(self.MAX_WARMUP, loop.trips):
            before = st.copy()
            s_last = TimingStats()
            self._run(loop.body, st, s_last)
            s_last.time_ns = 0.0
            stats.add(s_last)
            done += 1
            dt = st.t - before.t
            if prev_dt is not None and abs(dt - prev_dt) < 1e-9:
                break
            prev_dt = dt
        remaining = loop.trips - done
        if remaining <= 0:
            return
        dt = st.t - before.t
        stats.add(s_last, mult=float(remaining))
        # advance the clock analytically; bank windows shift with it
        shift = dt * remaining
        st.t += shift
        st.row_ready_even += shift
        st.row_ready_odd += shift
        st.last_act_even += shift
        st.last_act_odd += shift

    # ------------------------------------------------------------------
    def _run_seg(self, seg: Seg, st: _State, stats: TimingStats) -> None:
        sp = self.spec
        if seg.kind is Kind.ACT:
            for _ in range(seg.count):
                self._activate(seg.subset, st)
            stats.n_acts += seg.count
            stats.n_cmds += seg.count
        elif seg.kind is Kind.PIM_BCAST:
            ready = (st.row_ready_even if seg.subset is Subset.EVEN
                     else st.row_ready_odd)
            # first command of the run may stall on the row; the rest stream
            start = max(st.t, ready)
            stall = start - st.t
            st.t = start + seg.count * sp.t_ccdl_ns
            stats.act_stall_ns += stall
            stats.bcast_issue_ns += seg.count * sp.t_ccdl_ns
            stats.n_cmds += seg.count
        elif seg.kind in (Kind.RD, Kind.WR):
            st.t += seg.count * sp.t_ccds_ns
            stats.n_cmds += seg.count
        else:  # pragma: no cover - PIM_SB handled by _run_sb
            raise AssertionError(seg.kind)

    # ------------------------------------------------------------------
    def _activate(self, subset: Subset, st: _State) -> None:
        sp = self.spec
        issue = st.t
        st.t = issue + sp.t_ccds_ns   # the ACT command's bus slot
        subsets = ([Subset.EVEN, Subset.ODD] if subset is Subset.ALL
                   else [subset])
        for s in subsets:
            last = st.last_act_even if s is Subset.EVEN else st.last_act_odd
            pre_start = max(issue, last + sp.t_ras_ns)
            ready = pre_start + sp.t_rp_ns + sp.t_rcd_ns
            act_t = pre_start + sp.t_rp_ns
            if s is Subset.EVEN:
                st.row_ready_even, st.last_act_even = ready, act_t
            else:
                st.row_ready_odd, st.last_act_odd = ready, act_t

    # ------------------------------------------------------------------
    def _run_sb(self, segs: Sequence[Seg], st: _State,
                stats: TimingStats) -> None:
        """Aggregate model for freely-reorderable single-bank commands."""
        sp = self.spec
        cmd_slots = 0.0     # command-bus occupancy (ns)
        data_slots = 0.0    # data-bus occupancy (ns)
        act_work = 0.0      # row-activation work (ns of bank-time)
        n = 0
        for seg in segs:
            n += seg.count
            cmd_slots += seg.count * sp.t_ccds_ns / sp.command_bw_mult
            if seg.carries_data:
                data_slots += seg.count * sp.t_ccds_ns
            act_work += (seg.count * (1.0 - seg.row_hit_frac)
                         * sp.row_cycle_ns / sp.banks_per_pch)
        dur = max(cmd_slots, data_slots, act_work)
        st.t += dur
        stats.sb_time_ns += dur
        stats.n_cmds += n


def simulate(stream: Sequence[Node], spec: PimSpec | None = None) -> TimingStats:
    return PimTimer(spec or PimSpec()).simulate(stream)
