"""Schedule construction + the paper's three co-design optimizations (§5.1).

Broadcast primitives execute as *row-group schedules*: a cyclic sequence of
phases, each phase being "switch to a row, then run N broadcast commands per
even/odd subset".  Two schedule flavors are generated:

* :func:`baseline_schedule` — Fig. 7a top: an **all-bank** activation on the
  critical path, followed by the even-subset then odd-subset compute
  commands of that phase.
* :func:`arch_aware_schedule` — Fig. 7a bottom (§5.1.1): activations are
  split per subset and issued *eagerly* so one subset activates while the
  other computes.  Compute order and per-subset dependencies are unchanged,
  so the schedule is functionally equivalent.

Register pressure shapes the phase structure: with ``R`` pim-registers per
ALU shared by a bank pair, a chunk processes ``R // 2`` columns per subset
before the schedule must revisit rows (§4.2.3's "considerable care ...
effectively utilize available registers").  More registers (the §5.1.4 limit
study) lengthen chunks, amortizing activations.

The sparsity-aware (§5.1.2) and cache-aware (§5.1.3) optimizations act on
command *counts* before schedule construction: sparsity thins the command
stream (commands for zero operands are never issued), and the cache split
routes reuse-heavy updates to the processor's cache.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .commands import Kind, Loop, Node, Seg, Subset


@dataclasses.dataclass(frozen=True)
class Phase:
    """One row visit within a chunk: ``cmds`` broadcast commands/subset.

    ``serial`` marks a visit whose row contents depend on the immediately
    preceding compute (e.g. register spills): its activation cannot be
    issued eagerly, so even the architecture-aware schedule takes it on the
    critical path.
    """

    cmds_per_subset: int
    serial: bool = False


def chunk_cols(regs: int, pipelined: bool = True) -> int:
    """Columns a subset can process per chunk before register recycling.

    Registers are per-ALU and an ALU serves a bank *pair*; both baseline
    (even/odd interleaved after ACTab) and arch-aware (even/odd pipelined)
    schedules have both subsets' values live at once, so each subset gets
    ``regs // 2`` registers.
    """
    return max(1, regs // 2)


def baseline_schedule(phases: Sequence[Phase], trips: int) -> list[Node]:
    body: list[Node] = []
    for ph in phases:
        if ph.cmds_per_subset <= 0:
            continue
        body.append(Seg(Kind.ACT, Subset.ALL))
        body.append(Seg(Kind.PIM_BCAST, Subset.EVEN, ph.cmds_per_subset))
        body.append(Seg(Kind.PIM_BCAST, Subset.ODD, ph.cmds_per_subset))
    return [Loop(tuple(body), trips)]


def arch_aware_schedule(phases: Sequence[Phase], trips: int) -> list[Node]:
    """Decoupled even/odd activation (§5.1.1).

    The cyclic body interleaves: activate ODD's row for phase *p*, compute
    EVEN's phase *p* (whose row was activated one half-step earlier),
    activate EVEN's row for phase *p+1*, compute ODD's phase *p*.  Each
    activation overlaps the opposite subset's compute window; whether the
    latency is fully hidden depends on commands-per-phase (hence on register
    count) — exactly the paper's wavesim-flux observation.
    """
    body: list[Node] = []
    live = [ph for ph in phases if ph.cmds_per_subset > 0]
    for ph in live:
        if ph.serial:
            body.append(Seg(Kind.ACT, Subset.ALL))
            body.append(Seg(Kind.PIM_BCAST, Subset.EVEN, ph.cmds_per_subset))
            body.append(Seg(Kind.PIM_BCAST, Subset.ODD, ph.cmds_per_subset))
        else:
            body.append(Seg(Kind.ACT, Subset.ODD))
            body.append(Seg(Kind.PIM_BCAST, Subset.EVEN, ph.cmds_per_subset))
            body.append(Seg(Kind.ACT, Subset.EVEN))
            body.append(Seg(Kind.PIM_BCAST, Subset.ODD, ph.cmds_per_subset))
    return [Loop(tuple(body), trips)]


def schedule(phases: Sequence[Phase], trips: int,
             arch_aware: bool) -> list[Node]:
    if arch_aware:
        return arch_aware_schedule(phases, trips)
    return baseline_schedule(phases, trips)


# ---------------------------------------------------------------------------
# §5.1.2 sparsity-aware: the host inspects operands and skips issuing
# commands whose multiplier is zero.  At stream level this thins command
# counts by the *element* sparsity — no format change, no metadata.
# ---------------------------------------------------------------------------

def sparsity_thin(cmds: int, density: float) -> int:
    """Commands surviving the host's zero-check."""
    if not 0.0 <= density <= 1.0:
        raise ValueError("density must be in [0, 1]")
    return int(math.ceil(cmds * density))


# ---------------------------------------------------------------------------
# §5.1.3 cache-aware: a locality predictor classifies each update as
# cache-resident (keep on the processor) or not (offload to PIM).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSplit:
    hot: int     # updates predicted to hit in cache -> processor
    cold: int    # updates predicted to miss -> PIM

    @property
    def total(self) -> int:
        return self.hot + self.cold

    @property
    def hot_frac(self) -> float:
        return self.hot / self.total if self.total else 0.0


def cache_split(n_updates: int, predicted_hit_rate: float) -> CacheSplit:
    hot = int(round(n_updates * predicted_hit_rate))
    return CacheSplit(hot=hot, cold=n_updates - hot)
