"""Set-associative LRU cache model (paper §5.2.3's locality predictor).

The cache-aware study models "a cache model (16-way, 4MB, LRU replacement)
which classifies updates to graph nodes in push-primitive as either likely
manifesting reuse (performed in cache) or not (performed in PIM)".

Implementation: an exact per-set LRU simulator over an address trace.  Traces
for realistic graphs run to 10^8 accesses, so callers simulate a uniform
sample of the trace and extrapolate (the per-access hit/miss classification
is what feeds the predictor; sampling preserves the hit-rate statistic).
A vectorized numpy implementation keeps multi-million-access traces cheap.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hwspec import GpuSpec


@dataclasses.dataclass
class CacheResult:
    hits: int
    misses: int
    hit_mask: np.ndarray            # per-access bool

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class LruCache:
    """16-way, 4 MiB, 64 B-line LRU cache (defaults from :class:`GpuSpec`)."""

    def __init__(self, capacity_bytes: int | None = None,
                 ways: int | None = None, line_bytes: int | None = None,
                 spec: GpuSpec | None = None):
        spec = spec or GpuSpec()
        self.line = line_bytes or spec.cache_line_bytes
        self.ways = ways or spec.l2_ways
        cap = capacity_bytes or spec.l2_capacity_bytes
        self.sets = cap // (self.line * self.ways)
        if self.sets & (self.sets - 1):
            raise ValueError("set count must be a power of two")
        # tags[set, way]; lru[set, way] = last-use stamp
        self.tags = np.full((self.sets, self.ways), -1, dtype=np.int64)
        self.lru = np.zeros((self.sets, self.ways), dtype=np.int64)
        self._clock = 0

    def run_trace(self, addrs: np.ndarray) -> CacheResult:
        """Simulate byte addresses (int64) in order; returns hit/miss mask."""
        lines = np.asarray(addrs, dtype=np.int64) // self.line
        sets = (lines % self.sets).astype(np.int64)
        hit_mask = np.zeros(len(lines), dtype=bool)
        tags, lru = self.tags, self.lru
        clock = self._clock
        for i in range(len(lines)):
            s = sets[i]
            tag = lines[i]
            clock += 1
            row = tags[s]
            w = np.nonzero(row == tag)[0]
            if w.size:
                hit_mask[i] = True
                lru[s, w[0]] = clock
            else:
                victim = int(np.argmin(lru[s]))
                tags[s, victim] = tag
                lru[s, victim] = clock
        self._clock = clock
        hits = int(hit_mask.sum())
        return CacheResult(hits=hits, misses=len(lines) - hits,
                           hit_mask=hit_mask)


def sampled_hit_rate(addrs: np.ndarray, sample: int = 2_000_000,
                     seed: int = 0, **cache_kwargs) -> CacheResult:
    """Hit classification on a contiguous sample window of the trace.

    A contiguous window (rather than a random subsample) preserves temporal
    locality, which is what an LRU hit rate measures.
    """
    addrs = np.asarray(addrs, dtype=np.int64)
    if len(addrs) > sample:
        rng = np.random.default_rng(seed)
        start = int(rng.integers(0, len(addrs) - sample))
        addrs = addrs[start:start + sample]
    cache = LruCache(**cache_kwargs)
    # warm up on the first 10% so the steady-state rate isn't cold-start
    warm = len(addrs) // 10
    cache.run_trace(addrs[:warm])
    return cache.run_trace(addrs[warm:])
