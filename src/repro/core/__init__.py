"""Inclusive-PIM core: the paper's contribution as an executable model.

- PIM-amenability-test (§3): :mod:`repro.core.amenability`
- pim-command IR + DRAM timing engine (§4.3.1): :mod:`repro.core.commands`,
  :mod:`repro.core.timing`
- GPU baseline + cache models (§4.3.1, §5.2.3): :mod:`repro.core.gpu_model`,
  :mod:`repro.core.cache_model`
- placement + schedules + optimizations (§4.2, §5.1):
  :mod:`repro.core.placement`, :mod:`repro.core.optimizations`
- primitives under study (§2.3): :mod:`repro.core.primitives`
- per-op offload planner for compiled LM steps: :mod:`repro.core.planner`
"""

from .hwspec import DEFAULT_GPU, DEFAULT_PIM, DEFAULT_TPU, GpuSpec, PimSpec, TpuSpec  # noqa: F401
from .amenability import (  # noqa: F401
    AmenabilityReport, Interaction, PrimitiveProfile, Verdict, run_test,
)
from .timing import TimingStats, simulate  # noqa: F401
