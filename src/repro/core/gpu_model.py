"""GPU baseline analytical model (paper §4.3.1, "GPU Performance Model").

Execution time is a function of memory bandwidth (90% of peak) and the data
each primitive must move, assuming perfect on-chip reuse except:

* *wavesim*: no inter-timestep reuse (65K elements x 729 points x 2 B per
  GPU does not fit in cache);
* *push-primitive*: cache locality from measured L2 hit rates (44% / 20% /
  57% for the three graph inputs);
* *ss-gemm*: an **optimized** baseline that skips loading and computing on
  the all-zero rows of the skinny matrix (row-level sparsity).

Primitive modules compute their own byte counts and call :func:`time_ns`.
"""
from __future__ import annotations

import dataclasses

from .hwspec import GpuSpec


def time_ns(bytes_moved: float, spec: GpuSpec) -> float:
    """Bandwidth-bound execution time for ``bytes_moved`` DRAM bytes."""
    return bytes_moved / spec.effective_gbps


@dataclasses.dataclass(frozen=True)
class GpuEstimate:
    bytes_moved: float
    time_ns: float
    note: str = ""


def estimate(bytes_moved: float, spec: GpuSpec, note: str = "") -> GpuEstimate:
    return GpuEstimate(bytes_moved=bytes_moved,
                       time_ns=time_ns(bytes_moved, spec), note=note)


def cached_traffic(accesses: int, hit_rate: float, line_bytes: int) -> float:
    """DRAM bytes for ``accesses`` line-granular accesses under a cache with
    the given hit rate (misses fetch a full line; hits are free)."""
    return accesses * (1.0 - hit_rate) * line_bytes
