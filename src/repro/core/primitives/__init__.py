"""Primitives under study (paper §2.3): functional JAX implementations,
GPU-baseline byte models, and PIM command-stream generators."""

from . import graphs, push, ss_gemm, vector_sum, wavesim  # noqa: F401
