"""vector-sum primitive (paper §3.2, §4.2.2) — the PIM "hello world".

Elementwise ``c = a + b`` over fp16 arrays.  Amenability: op/byte 0.17, no
reuse, localized operand interaction, co-alignable -> highly PIM-amenable.

Orchestration (§4.2.2): inputs/outputs co-aligned at allocation so element
*i* of a, b, c share a (bank, row, col).  Per register-sized chunk the
schedule visits three rows (a: pim-ld, b: pim-add, c: pim-st); pim-registers
stage data between row visits.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .. import gpu_model
from ..amenability import Interaction, PrimitiveProfile
from ..commands import Node
from ..hwspec import GpuSpec, PimSpec
from ..optimizations import Phase, chunk_cols, schedule
from ..placement import CoAligned
from ..timing import TimingStats, simulate

ELEM_BYTES = 2  # fp16 (§2.3)


@dataclasses.dataclass(frozen=True)
class Problem:
    n: int  # elements per stack

    @property
    def bytes_per_array(self) -> int:
        return self.n * ELEM_BYTES


# ------------------------- functional (JAX) -------------------------------

def reference(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b


# ------------------------- amenability ------------------------------------

def profile(problem: Problem) -> PrimitiveProfile:
    nbytes = 3 * problem.bytes_per_array
    return PrimitiveProfile(
        name="vector-sum",
        ops=float(problem.n),           # one add per element
        mem_bytes=float(nbytes),
        onchip_bytes=float(problem.n * ELEM_BYTES) * 0.0 + 1.0,  # ~none
        interaction=Interaction.LOCALIZED,
        alignable=True,
        notes="op/byte~0.17; co-align at allocation (§4.2.2)",
    )


# ------------------------- GPU baseline -----------------------------------

def gpu_time_ns(problem: Problem, gpu: GpuSpec) -> float:
    return gpu_model.time_ns(3.0 * problem.bytes_per_array, gpu)


# ------------------------- PIM stream -------------------------------------

def pim_stream(problem: Problem, pim: PimSpec, *, arch_aware: bool = False,
               regs: int | None = None) -> list[Node]:
    regs = regs or pim.pim_regs_per_alu
    place = CoAligned(n_bytes=problem.bytes_per_array, structures=3, spec=pim)
    cols = chunk_cols(regs)
    # One chunk: visit a-row (ld), b-row (add), c-row (st) — `cols` commands
    # per subset at each visit.
    phases = [Phase(cols), Phase(cols), Phase(cols)]
    trips = max(1, -(-place.words_per_bank // cols))
    return schedule(phases, trips, arch_aware)


def pim_time(problem: Problem, pim: PimSpec, *, arch_aware: bool = False,
             regs: int | None = None) -> TimingStats:
    return simulate(pim_stream(problem, pim, arch_aware=arch_aware,
                               regs=regs), pim)


def speedup(problem: Problem, pim: PimSpec, gpu: GpuSpec, *,
            arch_aware: bool = False, regs: int | None = None) -> float:
    return gpu_time_ns(problem, gpu) / pim_time(
        problem, pim, arch_aware=arch_aware, regs=regs).time_ns
