"""Wave-simulation primitives (paper §2.3.1, §4.2.3): wavesim-volume and
wavesim-flux from a Discontinuous Galerkin Method (DGM) solver.

Functional model
----------------
A simplified acoustic DGM step on a 3-D structured mesh of elements, p=2
basis (27 nodes/element), ``n_fields`` coupled fields (pressure + velocity).
``volume`` applies the per-element reference derivative operators;
``flux`` exchanges face values with the 6 neighbors and applies an upwind
penalty.  These are real computations (used as kernel oracles and for the
examples); the paper evaluates 729 data points per element and 65K elements
per GPU, which we keep as the default problem size.

PIM model
---------
Command streams follow the §4.2.3 orchestration: elements distributed
lane-and-bank parallel (aligned data parallelism over the regular grid),
reference-operator entries broadcast as immediates, pim-registers staging
rows.  Schedules are register-pressure-shaped (§4.2.3 "considerable care is
necessary to effectively utilize available registers"):

* *volume* visits 3 rows per chunk (field row in, operator-mix row,
  rhs row out) with a compute-rich middle phase;
* *flux* visits 6 rows per chunk (own faces, three neighbor-face rows,
  normals/penalty row, flux output row) with few commands per visit —
  which is why its activation overhead is ~2x volume's and why
  architecture-aware activation only pays off once registers grow
  (paper Fig. 8).

Face interactions that cross banks (GridPlacement.cross_bank_frac) cannot
execute in PIM (§3.2) and are charged to the GPU serially.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .. import gpu_model
from ..amenability import Interaction, PrimitiveProfile
from ..commands import Node
from ..hwspec import GpuSpec, PimSpec
from ..optimizations import Phase, chunk_cols, schedule
from ..placement import GridPlacement, grid_placement
from ..timing import TimingStats, simulate

ELEM_BYTES = 2
NODES_1D = 3                      # p = 2
NODES = NODES_1D ** 3             # 27 nodes / element
DEFAULT_FIELDS = 27               # 27 nodes x 27 values = 729 points/element


@dataclasses.dataclass(frozen=True)
class Problem:
    grid: tuple[int, int, int] = (40, 40, 40)   # ~65K elements (paper)
    n_fields: int = DEFAULT_FIELDS

    @property
    def n_elements(self) -> int:
        gx, gy, gz = self.grid
        return gx * gy * gz

    @property
    def points_per_element(self) -> int:
        return NODES * self.n_fields           # 729 for the default

    @property
    def volume_bytes(self) -> int:
        # read u, write rhs; no inter-timestep reuse (§4.3.1)
        return 2 * self.n_elements * self.points_per_element * ELEM_BYTES

    @property
    def face_points(self) -> int:
        return NODES_1D ** 2 * self.n_fields   # one face's trace

    @property
    def flux_bytes(self) -> int:
        # read own + neighbor traces for 6 faces, accumulate rhs faces
        per_elem = (2 * 6 * self.face_points + 6 * self.face_points)
        return self.n_elements * per_elem * ELEM_BYTES


# ------------------------- functional (JAX) -------------------------------

def reference_operator(dtype=jnp.float32) -> jnp.ndarray:
    """1-D nodal derivative matrix for the p=2 Legendre-Gauss-Lobatto basis
    on [-1, 1] (nodes -1, 0, 1)."""
    d = np.array([[-1.5, 2.0, -0.5],
                  [-0.5, 0.0, 0.5],
                  [0.5, -2.0, 1.5]], dtype=np.float64)
    return jnp.asarray(d, dtype=dtype)


def volume(u: jnp.ndarray, c: jnp.ndarray | float = 1.0) -> jnp.ndarray:
    """Volume term: rhs[e, f, i, j, k] = c * sum_d (D_d u)[e, f, i, j, k].

    u: [elements, fields, 3, 3, 3] nodal values.
    """
    d = reference_operator(u.dtype)
    du_i = jnp.einsum("il,efljk->efijk", d, u)
    du_j = jnp.einsum("jl,efilk->efijk", d, u)
    du_k = jnp.einsum("kl,efijl->efijk", d, u)
    return c * (du_i + du_j + du_k)


def _shift(u: jnp.ndarray, axis: int, direction: int) -> jnp.ndarray:
    """Neighbor element values along a grid axis (periodic boundary)."""
    return jnp.roll(u, shift=-direction, axis=axis)


def flux(u_grid: jnp.ndarray, alpha: float = 0.5) -> jnp.ndarray:
    """Face-flux term on the element grid.

    u_grid: [gx, gy, gz, fields, 3, 3, 3].  For each of the 6 faces, form
    the jump between the element's own face trace and the neighbor's
    opposing trace and accumulate the upwind penalty onto the face nodes.
    """
    rhs = jnp.zeros_like(u_grid)
    node_axes = {0: 4, 1: 5, 2: 6}   # grid axis -> nodal axis
    for axis in range(3):
        na = node_axes[axis]
        own_hi = jax.lax.index_in_dim(u_grid, 2, axis=na, keepdims=True)
        own_lo = jax.lax.index_in_dim(u_grid, 0, axis=na, keepdims=True)
        nb_hi = jax.lax.index_in_dim(_shift(u_grid, axis, +1), 0, axis=na,
                                     keepdims=True)
        nb_lo = jax.lax.index_in_dim(_shift(u_grid, axis, -1), 2, axis=na,
                                     keepdims=True)
        jump_hi = alpha * (nb_hi - own_hi)
        jump_lo = alpha * (nb_lo - own_lo)
        hi_update = jnp.zeros_like(u_grid).at[_face_index(na, 2)].set(
            jnp.squeeze(jump_hi, axis=na))
        lo_update = jnp.zeros_like(u_grid).at[_face_index(na, 0)].set(
            jnp.squeeze(jump_lo, axis=na))
        rhs = rhs + hi_update + lo_update
    return rhs


def _face_index(axis: int, idx: int):
    sl = [slice(None)] * 7
    sl[axis] = idx
    return tuple(sl)


def step(u_grid: jnp.ndarray, dt: float = 1e-3, c: float = 1.0,
         alpha: float = 0.5) -> jnp.ndarray:
    """One explicit-Euler DGM timestep (volume + flux)."""
    shape = u_grid.shape
    u_flat = u_grid.reshape((-1,) + shape[3:])
    rhs_v = volume(u_flat, c).reshape(shape)
    rhs_f = flux(u_grid, alpha)
    return u_grid + dt * (rhs_v + rhs_f)


# ------------------------- amenability ------------------------------------

def profile_volume(problem: Problem) -> PrimitiveProfile:
    # op count follows the hand-scheduled PIM stream (useful MACs per byte
    # staged), landing in the paper's stated 0.43-1.72 op/byte range —
    # DGM implementations fold operator symmetries, so the naive
    # 3 x 27 x 27 contraction overcounts.
    ops = problem.volume_bytes * 1.1
    return PrimitiveProfile(
        name="wavesim-volume", ops=float(ops),
        mem_bytes=float(problem.volume_bytes), onchip_bytes=1.0,
        interaction=Interaction.LOCALIZED, alignable=True,
        notes="regular grid; operators broadcast as immediates",
    )


def profile_flux(problem: Problem) -> PrimitiveProfile:
    ops = problem.flux_bytes * 0.5   # jump+penalty per face word (see above)
    return PrimitiveProfile(
        name="wavesim-flux", ops=float(ops),
        mem_bytes=float(problem.flux_bytes), onchip_bytes=1.0,
        interaction=Interaction.LOCALIZED, alignable=True,
        input_dependent_locality=False,
        notes="neighbor faces need same-bank placement; residual cross-bank "
              "faces stay on the GPU",
    )


# ------------------------- GPU baseline -----------------------------------

def gpu_time_volume_ns(problem: Problem, gpu: GpuSpec) -> float:
    return gpu_model.time_ns(problem.volume_bytes, gpu)


def gpu_time_flux_ns(problem: Problem, gpu: GpuSpec) -> float:
    return gpu_model.time_ns(problem.flux_bytes, gpu)


# ------------------------- PIM streams ------------------------------------
# Schedule shapes (see module docstring).  Command counts per chunk are
# expressed per 32 B word of data staged, with the compute phase's richness
# set by the primitive's op/byte (hand-scheduled, §4.2.3).

VOLUME_PHASE_SHAPE = (1.5, 1.75, 1.0)   # (ld u, operator MACs, st rhs) x cols
VOLUME_WORD_DIV = 2.0                   # staged words per accounting word
FLUX_PHASE_SHAPE = (0.75, 0.5, 0.5, 0.5, 0.56, 0.75)
# flux: own-face ld, 3 neighbor-face visits, normals/penalty, st flux
FLUX_WORD_DIV = 2.16
# Register spills (§4.3.3): below 32 registers the flux working set (own +
# neighbor traces + penalties + intermediates) does not fit, forcing two
# extra scratch-row visits per chunk — the "high intermediate results which
# also consume registers" effect that keeps arch-aware activation from
# paying off until registers grow (Fig. 8).
FLUX_SPILL_SHAPE = (0.25, 0.25)
FLUX_SPILL_REG_THRESHOLD = 32


def _stream(problem_words: int, shape: tuple[float, ...], pim: PimSpec,
            arch_aware: bool, regs: int,
            n_serial: int = 0) -> list[Node]:
    cols = chunk_cols(regs)
    phases = [Phase(max(1, round(s * cols)), serial=(i >= len(shape) - n_serial))
              for i, s in enumerate(shape)]
    words_per_bank = problem_words / (pim.banks_per_stack)
    trips = max(1, round(words_per_bank / cols))
    return schedule(phases, trips, arch_aware)


def _volume_words(problem: Problem, pim: PimSpec) -> int:
    return int(problem.volume_bytes / pim.dram_word_bytes / VOLUME_WORD_DIV)


def pim_stream_volume(problem: Problem, pim: PimSpec, *,
                      arch_aware: bool = False,
                      regs: int | None = None) -> list[Node]:
    regs = regs or pim.pim_regs_per_alu
    return _stream(_volume_words(problem, pim), VOLUME_PHASE_SHAPE, pim,
                   arch_aware, regs)


def pim_stream_flux(problem: Problem, pim: PimSpec, *,
                    arch_aware: bool = False,
                    regs: int | None = None) -> list[Node]:
    regs = regs or pim.pim_regs_per_alu
    words = int(problem.flux_bytes / pim.dram_word_bytes / FLUX_WORD_DIV)
    shape = FLUX_PHASE_SHAPE
    n_serial = 0
    if regs < FLUX_SPILL_REG_THRESHOLD:
        shape = shape + FLUX_SPILL_SHAPE
        n_serial = len(FLUX_SPILL_SHAPE)
    return _stream(words, shape, pim, arch_aware, regs, n_serial=n_serial)


def pim_time_volume(problem: Problem, pim: PimSpec, *,
                    arch_aware: bool = False,
                    regs: int | None = None) -> TimingStats:
    return simulate(pim_stream_volume(problem, pim, arch_aware=arch_aware,
                                      regs=regs), pim)


def pim_time_flux(problem: Problem, pim: PimSpec, *,
                  arch_aware: bool = False,
                  regs: int | None = None) -> TimingStats:
    return simulate(pim_stream_flux(problem, pim, arch_aware=arch_aware,
                                    regs=regs), pim)


def placement(problem: Problem, pim: PimSpec) -> GridPlacement:
    return grid_placement(problem.grid, pim)


def speedup_volume(problem: Problem, pim: PimSpec, gpu: GpuSpec, *,
                   arch_aware: bool = False, regs: int | None = None) -> float:
    return gpu_time_volume_ns(problem, gpu) / pim_time_volume(
        problem, pim, arch_aware=arch_aware, regs=regs).time_ns


def speedup_flux(problem: Problem, pim: PimSpec, gpu: GpuSpec, *,
                 arch_aware: bool = False, regs: int | None = None) -> float:
    """Flux speedup including cross-bank ghost faces.

    Faces crossing a bank boundary (GridPlacement.cross_bank_frac of face
    interactions) cannot interact inside PIM (§3.2); the host refreshes
    ghost copies of those neighbor traces concurrently with PIM execution
    (traffic: chi of the neighbor-trace third of flux bytes), so the slower
    of the two dominates.
    """
    pim_t = pim_time_flux(problem, pim, arch_aware=arch_aware,
                          regs=regs).time_ns
    chi = placement(problem, pim).cross_bank_frac
    ghost_t = gpu_model.time_ns(chi * problem.flux_bytes / 3.0, gpu)
    gpu_t = gpu_time_flux_ns(problem, gpu)
    return gpu_t / (max(pim_t, ghost_t) + 0.1 * min(pim_t, ghost_t))
