"""Sparse skinny GEMM (paper §2.3.2, §4.2.4, §5.1.2/§5.2.2).

``C[M,N] = A[M,K] @ B[K,N]`` with A large and dense (stationary in memory),
B skinny (N in {2,4,8,16}) and dynamically sparse — the DLRM small-batch
inference regime.

Data placement (Fig. 5): A in the blocked format — 16 contiguous M values
per DRAM word (SIMD dim), M blocks across banks/pCHs, K along columns
within a row.  B values are broadcast as *immediate* operands on the data
bus; C partials accumulate in pim-registers (N accumulators) and are
written once per M-block — avoiding inter-bank, intra-SIMD, and inter-row
operations.

Orchestration: per A-row (32 K-words), ``32*N`` broadcast MAC commands per
subset read A directly from the open row.  **Sparsity-aware** (§5.1.2): the
host inspects B[k, n] before issuing; zero values issue no command at all —
element-granular dynamic sparsity, no sparse format, no metadata.

GPU baseline (§4.3.1): optimized with *row-level* sparsity — all-zero rows
of B skip both loading A[:, k] and computing on it.  (Element-granular
sparsity on the GPU would require building a sparse format at runtime.)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .. import gpu_model
from ..amenability import Interaction, PrimitiveProfile
from ..commands import Kind, Loop, Node, Seg, Subset
from ..hwspec import GpuSpec, PimSpec
from ..placement import BlockedMatrix
from ..timing import TimingStats, simulate

ELEM_BYTES = 2


@dataclasses.dataclass(frozen=True)
class Problem:
    m: int = 16384
    k: int = 4096
    n: int = 4
    density: float = 0.55       # per-element nonzero probability target
                                # (DLRM/Criteo-like multi-hot batches)


# ------------------------- functional (JAX) -------------------------------

def reference(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def make_skinny(problem: Problem, seed: int = 0,
                dtype=np.float32) -> np.ndarray:
    """DLRM-like skinny matrix: row popularity is zipf-distributed (hot
    embedding rows recur across the batch), thinned to the target element
    density."""
    rng = np.random.default_rng(seed)
    k, n = problem.k, problem.n
    # Mild zipf row popularity, renormalized to the target mean density.
    rank = np.arange(1, k + 1, dtype=np.float64)
    rng.shuffle(rank)
    pop = 1.0 / rank ** 0.1
    pop *= problem.density * k / pop.sum()
    pop = np.clip(pop, 0.0, 1.0)
    mask = rng.random((k, n)) < pop[:, None]
    vals = rng.standard_normal((k, n))
    return (vals * mask).astype(dtype)


def measured_sparsity(b: np.ndarray) -> tuple[float, float]:
    """(element density, all-zero-row fraction) of a skinny matrix."""
    nz = b != 0
    density = float(nz.mean())
    row_zero = float((~nz.any(axis=1)).mean())
    return density, row_zero


# ------------------------- amenability ------------------------------------

def profile(problem: Problem) -> PrimitiveProfile:
    ops = 2.0 * problem.m * problem.k * problem.n
    nbytes = ELEM_BYTES * (problem.m * problem.k + problem.k * problem.n
                           + problem.m * problem.n)
    return PrimitiveProfile(
        name=f"ss-gemm-N{problem.n}", ops=ops, mem_bytes=float(nbytes),
        onchip_bytes=1.0, interaction=Interaction.INDUCIBLE,
        alignable=True, input_dependent_locality=True,
        notes="blocked A layout induces locality (Fig. 5); N drives reuse",
    )


# ------------------------- GPU baseline -----------------------------------

def gpu_time_ns(problem: Problem, gpu: GpuSpec, row_zero_frac: float) -> float:
    a_bytes = problem.m * problem.k * ELEM_BYTES * (1.0 - row_zero_frac)
    b_bytes = problem.k * problem.n * ELEM_BYTES
    c_bytes = problem.m * problem.n * ELEM_BYTES
    return gpu_model.time_ns(a_bytes + b_bytes + c_bytes, gpu)


# ------------------------- PIM stream -------------------------------------

def pim_stream(problem: Problem, pim: PimSpec, *,
               sparsity_aware: bool = False,
               density: float | None = None) -> list[Node]:
    """Per-pCH stream.  Every bank walks its M-blocks; for each block the
    K loop visits ``rows_per_mblock`` A-rows with ``32*N`` (dense) or
    ``~32*N*density`` (sparsity-aware) MACs per row per subset, then writes
    the N accumulators to the C region (one row visit)."""
    place = BlockedMatrix(problem.m, problem.k, pim)
    d = problem.density if density is None else density
    macs_per_row = place.k_words_per_row * problem.n
    if sparsity_aware:
        macs_per_row = max(1, math.ceil(macs_per_row * d))
    k_rows = place.rows_per_mblock
    body: list[Node] = [
        Loop((Seg(Kind.ACT, Subset.ALL),
              Seg(Kind.PIM_BCAST, Subset.EVEN, macs_per_row),
              Seg(Kind.PIM_BCAST, Subset.ODD, macs_per_row)), k_rows),
        # C write-back: one row visit, N store commands per subset
        Seg(Kind.ACT, Subset.ALL),
        Seg(Kind.PIM_BCAST, Subset.EVEN, problem.n),
        Seg(Kind.PIM_BCAST, Subset.ODD, problem.n),
    ]
    return [Loop(tuple(body), place.mblocks_per_bank)]


def pim_time(problem: Problem, pim: PimSpec, *, sparsity_aware: bool = False,
             density: float | None = None) -> TimingStats:
    return simulate(pim_stream(problem, pim, sparsity_aware=sparsity_aware,
                               density=density), pim)


def speedups(problem: Problem, pim: PimSpec, gpu: GpuSpec,
             seed: int = 0) -> dict[str, float]:
    """Baseline and sparsity-aware PIM speedups with *measured* sparsity
    statistics from a generated skinny matrix (the GPU row-sparsity and the
    PIM element-sparsity come from the same data, as in the paper)."""
    b = make_skinny(problem, seed)
    density, row_zero = measured_sparsity(b)
    gpu_t = gpu_time_ns(problem, gpu, row_zero)
    base = gpu_t / pim_time(problem, pim).time_ns
    sa = gpu_t / pim_time(problem, pim, sparsity_aware=True,
                          density=density).time_ns
    return {"baseline": base, "sparsity_aware": sa,
            "density": density, "row_zero_frac": row_zero}
