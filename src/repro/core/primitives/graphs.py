"""Graph generators + traces for the push-primitive study (paper §4.3.1).

The paper evaluates three inputs with measured L2 hit rates:
  * roadnet-usa                  — hit rate 44% (low-degree, spatially local)
  * power-law 1M nodes/10M edges — hit rate 20%
  * power-law 10M/100M           — hit rate 57%

We model structurally-similar synthetic graphs.  Full edge lists for these
sizes are hundreds of MB, and the locality statistics only need a trace
*window*, so :class:`Graph` stores counts plus a lazy window generator: a
contiguous run of destination accesses in push-traversal (source) order.
The LRU cache model replays windows to classify per-update locality for the
cache-aware study; the paper's measured hit rates calibrate the GPU
baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    name: str
    n_nodes: int
    n_edges: int
    measured_l2_hit: float     # paper's rocprof hit rate for the GPU model
    _window_fn: Callable[[int, int], np.ndarray]

    def trace_window(self, length: int, seed: int = 0) -> np.ndarray:
        """A contiguous window of destination-node accesses."""
        return self._window_fn(length, seed)

    def edges(self, length: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) arrays for a window — for the functional primitive."""
        rng = np.random.default_rng(seed + 7)
        dst = self.trace_window(length, seed)
        deg = max(1, self.n_edges // self.n_nodes)
        src = np.repeat(rng.integers(0, self.n_nodes, size=(len(dst) + deg - 1) // deg),
                        deg)[:len(dst)]
        return src.astype(np.int64), dst.astype(np.int64)


def powerlaw(n_nodes: int, n_edges: int, alpha: float = 1.2,
             name: str = "powerlaw", measured_l2_hit: float = 0.2,
             seed: int = 0) -> Graph:
    """Destination-preferential power-law graph: destination popularity is
    zipf-like; hot destinations recur throughout the trace (that recurrence
    is the cache's opportunity)."""
    base = np.random.default_rng(seed)
    perm_seed = int(base.integers(1 << 31))

    def window(length: int, wseed: int) -> np.ndarray:
        rng = np.random.default_rng((seed, wseed))
        # Draw zipf-distributed ranks via inverse-CDF on a truncated zipf.
        u = rng.random(length)
        if alpha == 1.0:
            ranks = np.exp(u * np.log(n_nodes))
        else:
            a = 1.0 - alpha
            ranks = ((n_nodes ** a - 1.0) * u + 1.0) ** (1.0 / a)
        ranks = np.clip(ranks.astype(np.int64), 1, n_nodes) - 1
        # decorrelate popularity from node index
        mix = np.random.default_rng(perm_seed)
        salt = int(mix.integers(1, n_nodes))
        return (ranks * salt + salt) % n_nodes

    return Graph(name=name, n_nodes=n_nodes, n_edges=n_edges,
                 measured_l2_hit=measured_l2_hit, _window_fn=window)


def roadnet(n_nodes: int, avg_degree: float = 2.4, far_frac: float = 0.42,
            name: str = "roadnet-usa", measured_l2_hit: float = 0.44,
            seed: int = 0) -> Graph:
    """Road-network-like graph: low degree, most neighbors index-local
    (spatial renumbering) with a long-range remainder (highways / imperfect
    renumbering), traversal sweeps sources in order."""
    n_edges = int(n_nodes * avg_degree)

    def window(length: int, wseed: int) -> np.ndarray:
        rng = np.random.default_rng((seed, wseed))
        start = int(rng.integers(0, n_nodes))
        deg = max(1, int(np.ceil(avg_degree)))
        srcs = (start + np.arange(length // deg + 1)) % n_nodes
        src = np.repeat(srcs, deg)[:length]
        offs = rng.integers(-64, 65, size=length)
        dst = (src + offs) % n_nodes
        far = rng.random(length) < far_frac
        dst[far] = rng.integers(0, n_nodes, size=int(far.sum()))
        return dst

    return Graph(name=name, n_nodes=n_nodes, n_edges=n_edges,
                 measured_l2_hit=measured_l2_hit, _window_fn=window)


def paper_inputs(seed: int = 0) -> list[Graph]:
    """The three paper inputs at full scale (traces are lazy windows)."""
    return [
        roadnet(24_000_000, seed=seed),
        powerlaw(1_000_000, 10_000_000, alpha=0.6,
                 name="powerlaw-1M-10M", measured_l2_hit=0.20, seed=seed),
        powerlaw(10_000_000, 100_000_000, alpha=1.02,
                 name="powerlaw-10M-100M", measured_l2_hit=0.57,
                 seed=seed + 1),
    ]
