"""push-primitive (paper §2.3.3, §4.2.5, §5.1.3/§5.2.3).

Push-based graph processing: for each source vertex, read its value and
update every neighbor (an atomic read-modify-write per edge).  Irregular
destinations preclude broadcast commands and co-location, so the offload
uses **single-bank** pim-commands: per edge a *pim-ADD* (loads the current
destination value, adds the operand supplied on the data bus, result to a
pim-register) plus a *pim-store* (writes the register back; carries no
data — the §5.1.4 command-bandwidth-limit protagonist).

GPU baseline: destination updates are line-granular with the measured L2
hit rates; source values and edge indices stream.

Cache-aware PIM (§5.1.3): a locality predictor (the LRU cache model)
classifies each update; predicted-hot updates are performed in cache by the
GPU, the cold remainder via PIM — both proceed concurrently.  Cache-aware
GPU: the same predictor lets the GPU drop to 32 B accesses for cold updates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .. import gpu_model
from ..amenability import Interaction, PrimitiveProfile
from ..cache_model import sampled_hit_rate
from ..commands import Kind, Node, Seg, Subset
from ..hwspec import GpuSpec, PimSpec
from ..timing import TimingStats, simulate
from .graphs import Graph

VALUE_BYTES = 2      # fp16 computational value (PIM operand width is 32 B)
PROP_BYTES = 32      # full vertex-property struct (graphBIG-style: value +
                     # degree + flags + padding) = one DRAM word, the
                     # granularity both the cache and pim-commands touch
INDEX_BYTES = 8      # (src, dst) 32-bit pair per edge in traversal order
COLD_ROW_HIT = 0.3   # row locality of cache-*missing* updates (scattered)
HOT_ROW_HIT = 0.85   # destination-bucketed full streams


# ------------------------- functional (JAX) -------------------------------

def reference(values: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
              n_nodes: int) -> jnp.ndarray:
    """One push iteration: out[d] += f(values[s]) for every edge (s, d).

    f is the typical push update (e.g. PageRank-style scaled contribution);
    we use f(x) = 0.85 * x.  Atomicity is by construction (segment-sum).
    """
    contrib = 0.85 * values[src]
    return values + jax.ops.segment_sum(contrib, dst, num_segments=n_nodes)


# ------------------------- amenability ------------------------------------

def profile(graph: Graph) -> PrimitiveProfile:
    e = graph.n_edges
    nbytes = e * (INDEX_BYTES + 2 * VALUE_BYTES)
    return PrimitiveProfile(
        name=f"push[{graph.name}]", ops=float(2 * e),
        mem_bytes=float(nbytes),
        onchip_bytes=float(e * VALUE_BYTES * graph.measured_l2_hit + 1),
        interaction=Interaction.IRREGULAR, alignable=False,
        input_dependent_locality=True,
        notes="single-bank commands only; command-bandwidth bound",
    )


# ------------------------- GPU baseline -----------------------------------

def gpu_time_ns(graph: Graph, gpu: GpuSpec, *, hit_rate: float | None = None,
                cache_aware: bool = False) -> float:
    """Edge stream + line-granular destination updates.

    Source properties are swept in order (cache-friendly, charged to
    neither side); the irregular destination updates dominate.  Baseline:
    each missing update fetches a 64 B line.  Cache-aware GPU (§5.2.3):
    the predictor lets cold updates use 32 B accesses instead.
    """
    h = graph.measured_l2_hit if hit_rate is None else hit_rate
    e = graph.n_edges
    stream = e * INDEX_BYTES
    gran = gpu.reduced_access_bytes if cache_aware else gpu.cache_line_bytes
    update = e * (1.0 - h) * gran
    return gpu_model.time_ns(stream + update, gpu)


# ------------------------- PIM -------------------------------------------

def pim_stream(graph: Graph, pim: PimSpec, *, n_updates: int | None = None,
               row_hit_frac: float = HOT_ROW_HIT) -> list[Node]:
    """Single-bank stream for ``n_updates`` edges (per stack; the engine
    models one pCH so counts are divided by pch_per_stack).

    ``row_hit_frac``: destination-bucketed processing (sorting updates by
    destination region, which the blocked layout encourages) gives most
    updates an already-open row; the remainder pay a bank activation.
    """
    e = (graph.n_edges if n_updates is None else n_updates)
    per_pch = max(1, e // pim.pch_per_stack)
    return [
        Seg(Kind.PIM_SB, Subset.ALL, per_pch, carries_data=True,
            row_hit_frac=row_hit_frac),                        # pim-ADD
        Seg(Kind.PIM_SB, Subset.ALL, per_pch, carries_data=False,
            row_hit_frac=1.0),                                 # pim-store
    ]


def pim_time(graph: Graph, pim: PimSpec, *, n_updates: int | None = None,
             row_hit_frac: float = HOT_ROW_HIT) -> TimingStats:
    return simulate(pim_stream(graph, pim, n_updates=n_updates,
                               row_hit_frac=row_hit_frac), pim)


def gpu_feed_time_ns(graph: Graph, gpu: GpuSpec,
                     n_updates: int | None = None) -> float:
    """GPU-side work to drive PIM: stream the edge list (source property
    reads sweep in order and stay cached, as in the baseline)."""
    e = graph.n_edges if n_updates is None else n_updates
    return gpu_model.time_ns(e * INDEX_BYTES, gpu)


@dataclasses.dataclass(frozen=True)
class PushResult:
    gpu_ns: float
    pim_baseline_ns: float
    pim_cache_aware_ns: float
    gpu_cache_aware_ns: float
    predictor_hit_rate: float

    @property
    def speedup_baseline(self) -> float:
        return self.gpu_ns / self.pim_baseline_ns

    @property
    def speedup_cache_aware(self) -> float:
        return self.gpu_ns / self.pim_cache_aware_ns

    @property
    def speedup_gpu_cache_aware(self) -> float:
        return self.gpu_ns / self.gpu_cache_aware_ns


def evaluate(graph: Graph, pim: PimSpec, gpu: GpuSpec, *,
             predictor_sample: int = 400_000, seed: int = 0) -> PushResult:
    """Full §5.2.3 comparison for one graph input."""
    # Locality predictor: classify updates with the LRU cache model on a
    # sampled window of the destination trace.
    window = graph.trace_window(predictor_sample, seed=seed)
    addrs = window.astype(np.int64) * PROP_BYTES
    cache = sampled_hit_rate(addrs, sample=predictor_sample, seed=seed,
                             spec=gpu)
    pred_hit = cache.hit_rate

    # The predictor's model hit rate is used consistently for the GPU
    # baseline too (our synthetic graphs are calibrated so it lands on the
    # paper's measured rocprof rates).
    gpu_ns = gpu_time_ns(graph, gpu, hit_rate=pred_hit)
    pim_base = pim_time(graph, pim).time_ns + gpu_feed_time_ns(graph, gpu)

    # Cache-aware PIM: hot updates in cache (on the GPU, ~free bandwidth),
    # cold via PIM; the GPU still streams the edge list.  GPU-side feed and
    # PIM-side execution overlap; the slower dominates (with a 15% residual
    # for the imperfect overlap).  Cold updates are the scattered ones, so
    # their row locality is poor (COLD_ROW_HIT).
    cold = int(graph.n_edges * (1.0 - pred_hit))
    pim_cold = pim_time(graph, pim, n_updates=max(1, cold),
                        row_hit_frac=COLD_ROW_HIT).time_ns
    feed = gpu_feed_time_ns(graph, gpu)
    pim_ca = max(pim_cold, feed) + 0.15 * min(pim_cold, feed)

    gpu_ca = gpu_time_ns(graph, gpu, hit_rate=pred_hit, cache_aware=True)
    return PushResult(gpu_ns=gpu_ns, pim_baseline_ns=pim_base,
                      pim_cache_aware_ns=pim_ca, gpu_cache_aware_ns=gpu_ca,
                      predictor_hit_rate=pred_hit)
