"""Data placement for PIM offload (paper §3.1.3/§3.1.4 + §4.2).

Placement determines whether broadcast pim-commands are usable: interacting
operands must live in the same bank (operand locality) at the same row/col
address across banks (aligned data parallelism).  The descriptors here are
consumed by the per-primitive command-stream generators and by the
functional JAX implementations (which use the same blocked reshapes so that
the layout the model charges for is the layout the arrays actually take).
"""
from __future__ import annotations

import dataclasses
import math

from .hwspec import PimSpec


@dataclasses.dataclass(frozen=True)
class CoAligned:
    """Elementwise co-alignment (§4.2.2): element *i* of every structure maps
    to the same (bank, row, col).  ``structures`` arrays of ``n_bytes``."""

    n_bytes: int
    structures: int
    spec: PimSpec

    @property
    def bytes_per_pch(self) -> float:
        return self.n_bytes / self.spec.pch_per_stack

    @property
    def rows_per_bank(self) -> int:
        """DRAM rows one structure occupies in each bank of a pCH."""
        per_bank = self.bytes_per_pch / self.spec.banks_per_pch
        return max(1, math.ceil(per_bank / self.spec.row_buffer_bytes))

    @property
    def words_per_bank(self) -> int:
        per_bank = self.bytes_per_pch / self.spec.banks_per_pch
        return max(1, math.ceil(per_bank / self.spec.dram_word_bytes))


@dataclasses.dataclass(frozen=True)
class BlockedMatrix:
    """ss-gemm blocked format (paper Fig. 5).

    The dense matrix A[M, K] is laid out so one DRAM word holds 16
    contiguous-M fp16 values (SIMD dim), M blocks spread across banks and
    pCHs (aligned data parallelism), and K runs along columns within a row
    (row locality).  One bank row therefore holds a 16 x ``cols_per_row``
    (M x K) tile.
    """

    m: int
    k: int
    spec: PimSpec

    @property
    def m_per_bank(self) -> int:
        lanes = self.spec.simd_lanes
        return max(1, math.ceil(self.m / (lanes * self.spec.banks_per_stack)))

    @property
    def k_words_per_row(self) -> int:
        return self.spec.cols_per_row

    @property
    def rows_per_mblock(self) -> int:
        """DRAM rows holding all K for one 16-wide M block."""
        return max(1, math.ceil(self.k / self.k_words_per_row))

    @property
    def mblocks_per_bank(self) -> int:
        return self.m_per_bank


@dataclasses.dataclass(frozen=True)
class GridPlacement:
    """wavesim mesh placement (§4.2.3): a 3-D grid of elements is linearized
    so that neighbors along the two minor dimensions stay inside a bank and
    only the major dimension crosses banks (Fig. 4b).  ``cross_bank_frac``
    is the fraction of face interactions that land in different banks and
    therefore cannot be offloaded (they stay on the GPU)."""

    grid: tuple[int, int, int]
    elems_per_bank: int
    spec: PimSpec

    @property
    def n_elements(self) -> int:
        gx, gy, gz = self.grid
        return gx * gy * gz

    @property
    def cross_bank_frac(self) -> float:
        """Fraction of face interactions crossing a bank boundary when each
        bank holds a cubic sub-grid of ``elems_per_bank`` elements: the
        surface-to-face ratio 1/s for an s^3 cube (optimal placement)."""
        side = max(1.0, self.elems_per_bank ** (1.0 / 3.0))
        return min(1.0 / side, 0.5)


def grid_placement(grid: tuple[int, int, int], spec: PimSpec) -> GridPlacement:
    n = grid[0] * grid[1] * grid[2]
    per_bank = max(1, math.ceil(n / spec.banks_per_stack))
    return GridPlacement(grid=grid, elems_per_bank=per_bank, spec=spec)
