"""Functional PIM simulator: *executes* broadcast command streams.

The timing engine (repro.core.timing) answers "how long"; this module
answers "does the orchestration compute the right thing".  It models the
strawman machine's visible state — per-bank DRAM rows, per-ALU register
files, an open-row buffer — and executes co-aligned elementwise programs
(the §4.2.2 class) command by command:

  ACT  (subset, row)        open a row in each bank of the subset
  LD   (subset, col, reg)   reg[bank] <- open_row[bank][col]
  OP   (subset, col, reg, fn) reg[bank] <- fn(reg[bank], open_row[bank][col])
  ST   (subset, col, reg)   open_row[bank][col] <- reg[bank] (write-through)

A program must respect the machine rules (registers per ALU, one open row
per bank, SIMD width) or the simulator raises — the same constraints the
paper's orchestration discussion is about.  Tests run the vector-sum
program produced by :func:`elementwise_program` against jnp oracles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .hwspec import PimSpec


@dataclasses.dataclass(frozen=True)
class Cmd:
    kind: str                  # act | ld | op | st
    subset: str                # even | odd | all (act only)
    row: int = 0               # act
    col: int = 0               # ld/op/st
    reg: int = 0
    fn: Callable | None = None


class PimMachine:
    """One pseudo-channel of the strawman machine."""

    def __init__(self, spec: PimSpec | None = None):
        self.spec = spec or PimSpec()
        sp = self.spec
        self.lanes = sp.simd_lanes
        self.banks = sp.banks_per_pch
        self.cols = sp.cols_per_row
        self.rows: dict[tuple[int, int], np.ndarray] = {}
        self.open_row = [-1] * self.banks
        # one ALU (register file) per bank *pair*
        self.regs = np.zeros((self.banks // 2, sp.pim_regs_per_alu,
                              self.lanes), np.float32)

    # ------------------------------------------------------------------
    def write_row(self, bank: int, row: int, data: np.ndarray) -> None:
        assert data.shape == (self.cols, self.lanes)
        self.rows[(bank, row)] = data.astype(np.float32).copy()

    def read_row(self, bank: int, row: int) -> np.ndarray:
        return self.rows.setdefault(
            (bank, row), np.zeros((self.cols, self.lanes), np.float32))

    def _banks(self, subset: str) -> range:
        if subset == "even":
            return range(0, self.banks, 2)
        if subset == "odd":
            return range(1, self.banks, 2)
        return range(self.banks)

    # ------------------------------------------------------------------
    def execute(self, program: Sequence[Cmd]) -> None:
        sp = self.spec
        for cmd in program:
            if cmd.kind == "act":
                for b in self._banks(cmd.subset):
                    self.open_row[b] = cmd.row
                continue
            if cmd.subset == "all":
                raise ValueError("compute commands target even/odd subsets")
            if not 0 <= cmd.reg < sp.pim_regs_per_alu:
                raise ValueError(f"register {cmd.reg} out of range")
            for b in self._banks(cmd.subset):
                if self.open_row[b] < 0:
                    raise RuntimeError(f"bank {b}: no open row")
                row = self.read_row(b, self.open_row[b])
                alu = b // 2
                if cmd.kind == "ld":
                    self.regs[alu, cmd.reg] = row[cmd.col]
                elif cmd.kind == "op":
                    self.regs[alu, cmd.reg] = cmd.fn(
                        self.regs[alu, cmd.reg], row[cmd.col])
                elif cmd.kind == "st":
                    row[cmd.col] = self.regs[alu, cmd.reg]
                else:
                    raise ValueError(cmd.kind)


# ---------------------------------------------------------------------------
# co-aligned elementwise programs (§4.2.2)
# ---------------------------------------------------------------------------

def place_coaligned(machine: PimMachine, arrays: dict[int, np.ndarray]):
    """Place equal-length arrays co-aligned: element i of every array in
    the same (bank, col, lane); array r lives in row r.  Returns the
    number of (col-chunk) iterations a program needs."""
    n = len(next(iter(arrays.values())))
    per_bank = machine.cols * machine.lanes
    need = machine.banks * per_bank
    if n > need:
        raise ValueError(f"array larger than one row-set ({need})")
    for row, arr in arrays.items():
        pad = np.zeros(need, np.float32)
        pad[:n] = arr
        for b in range(machine.banks):
            machine.write_row(
                b, row, pad[b * per_bank:(b + 1) * per_bank].reshape(
                    machine.cols, machine.lanes))


def gather_coaligned(machine: PimMachine, row: int, n: int) -> np.ndarray:
    per_bank = machine.cols * machine.lanes
    out = np.concatenate([machine.read_row(b, row).reshape(-1)
                          for b in range(machine.banks)])
    return out[:n]


def elementwise_program(spec: PimSpec, in_rows: Sequence[int], out_row: int,
                        fn: Callable, *, arch_aware: bool = False
                        ) -> list[Cmd]:
    """Generate the §4.2.2 schedule: per register-chunk, visit each input
    row (ld/op) then the output row (st), even/odd interleaved — the same
    phase structure the timing model charges for."""
    cols = spec.cols_per_row
    chunk = max(1, spec.pim_regs_per_alu // 2)
    program: list[Cmd] = []
    for c0 in range(0, cols, chunk):
        cspan = range(c0, min(c0 + chunk, cols))
        for phase, row in enumerate(list(in_rows) + [out_row]):
            program.append(Cmd("act", "all", row=row))
            for subset_i, subset in enumerate(("even", "odd")):
                for j, col in enumerate(cspan):
                    reg = subset_i * chunk + j
                    if phase == 0:
                        program.append(Cmd("ld", subset, col=col, reg=reg))
                    elif phase < len(in_rows):
                        program.append(Cmd("op", subset, col=col, reg=reg,
                                           fn=fn))
                    else:
                        program.append(Cmd("st", subset, col=col, reg=reg))
    return program
